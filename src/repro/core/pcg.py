"""Preconditioned conjugate gradient — the paper's downstream quality metric.

Sparsifier quality is measured by the PCG iteration count when using the
sparsifier Laplacian L_P as a preconditioner to solve L_G x = b to
``||L_G x - b|| <= tol * ||b||`` (paper: tol = 1e-3).

Two implementations:
  * :func:`pcg_host` — scipy CSR matvec + sparse LU of the grounded L_P
    (equivalent to MATLAB's ``pcg(..., M)`` direct preconditioner solve).
    Used by the quality benchmarks — scales to 1e5+ vertices.
  * :func:`pcg_jax` — pure-JAX PCG (jit, lax.while_loop) with a dense
    Cholesky preconditioner; the building block reused by the distributed
    solver demo and exercised on small graphs in tests.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class PCGResult(NamedTuple):
    x: np.ndarray
    iters: int
    relres: float
    converged: bool


def _ground(mat, idx: int = 0):
    """Remove row/col ``idx`` (grounding a node makes the Laplacian SPD)."""
    keep = np.ones(mat.shape[0], dtype=bool)
    keep[idx] = False
    return mat[keep][:, keep]


def pcg_host(L_G, b: np.ndarray, L_P=None, tol: float = 1e-3,
             maxiter: int = 10_000) -> PCGResult:
    """Host PCG on the grounded system; L_P preconditioner via sparse LU."""
    import scipy.sparse as sp
    import scipy.sparse.linalg as spla

    A = _ground(sp.csr_matrix(L_G)).tocsc()
    bg = np.asarray(b, dtype=np.float64)[1:]
    if L_P is not None:
        M = spla.splu(sp.csc_matrix(_ground(sp.csr_matrix(L_P))))
        msolve: Callable = M.solve
    else:
        msolve = lambda r: r  # noqa: E731

    x = np.zeros_like(bg)
    r = bg - A @ x
    z = msolve(r)
    p = z.copy()
    rz = float(r @ z)
    bnorm = float(np.linalg.norm(bg))
    if bnorm == 0:
        return PCGResult(x, 0, 0.0, True)
    for it in range(1, maxiter + 1):
        Ap = A @ p
        alpha = rz / float(p @ Ap)
        x += alpha * p
        r -= alpha * Ap
        relres = float(np.linalg.norm(r)) / bnorm
        if relres <= tol:
            full = np.concatenate([[0.0], x])
            return PCGResult(full, it, relres, True)
        z = msolve(r)
        rz_new = float(r @ z)
        p = z + (rz_new / rz) * p
        rz = rz_new
    full = np.concatenate([[0.0], x])
    return PCGResult(full, maxiter, relres, False)


def pcg_jax(A: jnp.ndarray, b: jnp.ndarray, M_chol: jnp.ndarray | None = None,
            tol: float = 1e-3, maxiter: int = 10_000):
    """Dense JAX PCG on a grounded SPD system.  Returns (x, iters, relres).

    ``M_chol`` is the lower Cholesky factor of the (grounded) preconditioner;
    the solve is two triangular substitutions.
    """
    n = b.shape[0]
    bnorm = jnp.linalg.norm(b)

    if M_chol is None:
        def msolve(r):
            return r
    else:
        def msolve(r):
            y = jax.scipy.linalg.solve_triangular(M_chol, r, lower=True)
            return jax.scipy.linalg.solve_triangular(M_chol.T, y, lower=False)

    def cond(state):
        _, r, _, _, it = state
        return (jnp.linalg.norm(r) > tol * bnorm) & (it < maxiter)

    def body(state):
        x, r, p, rz, it = state
        Ap = A @ p
        alpha = rz / (p @ Ap)
        x = x + alpha * p
        r = r - alpha * Ap
        z = msolve(r)
        rz_new = r @ z
        p = z + (rz_new / rz) * p
        return x, r, p, rz_new, it + 1

    x0 = jnp.zeros_like(b)
    r0 = b
    z0 = msolve(r0)
    state = (x0, r0, z0, r0 @ z0, jnp.int32(0))
    x, r, _, _, it = jax.lax.while_loop(cond, body, state)
    return x, it, jnp.linalg.norm(r) / bnorm


def quality_iters(graph, sparsifier, tol: float = 1e-3, seed: int = 0,
                  maxiter: int = 10_000) -> int:
    """Paper's quality metric: PCG iterations with L_P as preconditioner."""
    rng = np.random.default_rng(seed)
    b = rng.standard_normal(graph.n)
    b -= b.mean()  # keep b in range(L_G)
    res = pcg_host(graph.laplacian(), b, sparsifier.laplacian(),
                   tol=tol, maxiter=maxiter)
    return res.iters
