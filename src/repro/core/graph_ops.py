"""Reusable jit-safe device primitives for label/propose/accept graph work.

pdGRASS's core claim is that propose/accept-style parallelism removes the
serial data dependencies of greedy graph algorithms.  The repo uses that
pattern in two places — Boruvka spanning trees (``core/spanning_tree``) and
heavy-edge contraction (``solver/hierarchy``) — and both decompose into the
same handful of flat-array primitives, collected here:

  * :func:`segment_argmax`        — deterministic per-segment argmax with a
    (value, min element-id) total order, the "every component picks its best
    edge" step of Boruvka and the "every vertex picks its heaviest incident
    edge" step of matching.
  * :func:`handshake`             — the symmetric accept: an edge wins iff
    *both* of its endpoints proposed it.
  * :func:`propose_accept_matching` — locally-dominant heavy-edge matching
    built from the two above.  With a strict (weight, -edge id) total order
    this provably equals the *sequential* greedy matching, so the host
    oracle and the device path agree bit-for-bit.
  * :func:`pointer_jump`          — pointer-jumping label collapse
    (parent forest -> roots in O(log depth) doubling steps).
  * :func:`compact_labels`        — order-preserving dense relabel of a
    sparse label set (component roots -> 0..k-1).
  * :func:`coalesce_edges`        — segmented edge relabel + merge: push an
    edge list through a vertex labeling, drop intra-cluster edges, sum
    parallel edges — the contraction step, entirely on the device.

Everything here is shape-static ``jnp`` scatter/gather/sort work: safe
under ``jit``, free of host round-trips, and padded with explicit
sentinels rather than dynamic shapes.

Mesh-aware variants (for bodies running under ``shard_map`` with the
edge arrays row-sharded over a named axis) sit beside their single-device
counterparts: :func:`sharded_segment_argmax` combines per-shard argmaxes
with a ``pmax``/``pmin`` pair under the same (value, min element-id) total
order, :func:`sharded_matching` is :func:`propose_accept_matching` with
its per-round segment sweep distributed, and :func:`sharded_coalesce_edges`
is a two-phase (local combine, ``all_gather``, final merge) contraction.
All three are *bit-identical* to the single-device primitives on the same
input — the strict total order survives the collectives — which is what
lets the sharded hierarchy build serve as a drop-in for the device one.
:func:`shard_map_compat` is the version-portable ``shard_map`` entry point
every mesh consumer in the repo shares.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

try:  # jax >= 0.5 exposes shard_map at the top level
    shard_map_compat = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def shard_map_compat(f, **kw):
        # the experimental version can't prove replication across
        # while_loop bodies; callers are replication-safe by construction.
        return _exp_shard_map(f, check_rep=False, **kw)


def segment_argmax(values: jnp.ndarray, segment_ids: jnp.ndarray,
                   num_segments: int, *,
                   element_ids: Optional[jnp.ndarray] = None,
                   sentinel: Optional[int] = None,
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-segment argmax under the (value, minimal element id) total order.

    Returns ``(pick, best)`` with ``pick[s]`` the winning element id of
    segment ``s`` and ``best[s]`` its value.  Deterministic: among
    value-maximal elements the *smallest* element id wins.  ``element_ids``
    defaults to ``arange(len(values))``; passing custom ids lets duplicated
    entries (e.g. both directions of an undirected edge) resolve to one
    winner.  Segments that are empty — or whose values are all ``-inf``,
    the conventional "masked out" encoding — get ``pick == sentinel``
    (default: ``len(values)``) and ``best == -inf``.  Out-of-range
    ``segment_ids`` (e.g. ``-1`` padding) are dropped.
    """
    k = values.shape[0]
    if element_ids is None:
        element_ids = jnp.arange(k, dtype=jnp.int32)
    if sentinel is None:
        sentinel = k
    # Negative ids would *wrap* under jnp indexing; push them past the end
    # so the scatters genuinely drop them.
    segs = jnp.where(segment_ids < 0, num_segments, segment_ids)
    best = jnp.full((num_segments,), -jnp.inf, dtype=values.dtype)
    best = best.at[segs].max(values, mode="drop")
    # The gather clips out-of-range segs to the last segment, which can mark
    # a dropped element "best" — harmless: its pick scatter drops too.
    is_best = (values == best[segs]) & (values > -jnp.inf)
    # Only best elements scatter (non-best ones are routed out of bounds and
    # dropped) and the reduction starts from the dtype max, so the min never
    # mixes element ids with the sentinel — any sentinel value works,
    # including ones below the ids (e.g. -1).  Untouched segments are mapped
    # to the sentinel afterwards.
    big = jnp.iinfo(element_ids.dtype).max
    pick = jnp.full((num_segments,), big, dtype=element_ids.dtype)
    pick = pick.at[jnp.where(is_best, segs, num_segments)].min(
        element_ids, mode="drop")
    pick = jnp.where(pick == big, sentinel, pick)
    return pick, best


def handshake(prop: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray
              ) -> jnp.ndarray:
    """Symmetric accept round: edge ``e`` wins iff both endpoints propose it.

    ``prop[v]`` is the edge id vertex ``v`` proposes (any sentinel >= m for
    "no proposal").  Returns the ``[m]`` bool mask of mutually-proposed
    edges.  Accepted edges are vertex-disjoint by construction: a vertex
    proposes at most one edge.
    """
    e = jnp.arange(src.shape[0], dtype=prop.dtype)
    return (prop[src] == e) & (prop[dst] == e)


def pointer_jump(parent: jnp.ndarray) -> jnp.ndarray:
    """Collapse a parent forest to its roots: ``p[v] -> root(v)``.

    Doubling (``p = p[p]``) until fixpoint — O(log depth) gather sweeps.
    The forest must be cycle-free apart from root self-loops.
    """
    def body(p):
        return p[p]

    def cond(p):
        return jnp.any(p[p] != p)

    return jax.lax.while_loop(cond, body, parent)


def compact_labels(labels: jnp.ndarray, num_labels: int
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Order-preserving dense relabel: sparse ids in [0, num_labels) -> 0..k-1.

    Returns ``(dense, k)`` where ``k`` is the number of distinct labels and
    ``dense`` preserves the original ``<`` order (label compaction after
    pointer-jumping: component roots become consecutive coarse ids).
    """
    used = jnp.zeros((num_labels,), jnp.int32).at[labels].set(1, mode="drop")
    new_id = (jnp.cumsum(used) - 1).astype(labels.dtype)
    return new_id[labels], used.sum()


def propose_accept_matching(n: int, src: jnp.ndarray, dst: jnp.ndarray,
                            weight: jnp.ndarray) -> jnp.ndarray:
    """Heavy-edge maximal matching by propose/accept rounds; ``mate[v]`` or -1.

    Every round, each free vertex proposes its heaviest incident *alive*
    edge (both endpoints free) under the strict (weight, -edge id) total
    order; mutually-proposed (locally dominant) edges match.  The globally
    heaviest alive edge is always locally dominant, so every round makes
    progress and the loop terminates with a maximal matching.

    Because the total order is strict, the result is exactly the matching
    the *sequential* greedy scan over edges sorted by descending
    (weight, -edge id) produces — the host oracle in
    ``solver/hierarchy.heavy_edge_matching`` — with all serial data
    dependencies replaced by O(rounds) flat segment-argmax sweeps.
    """
    m = src.shape[0]
    eidx = jnp.arange(m, dtype=jnp.int32)
    heads = jnp.concatenate([src, dst])
    eids2 = jnp.concatenate([eidx, eidx])
    w2 = jnp.concatenate([weight, weight])

    def body(state):
        mate, _ = state
        free = mate < 0
        alive = free[src] & free[dst]
        alive2 = jnp.concatenate([alive, alive])
        vals = jnp.where(alive2, w2, -jnp.inf)
        prop, _ = segment_argmax(vals, heads, n, element_ids=eids2,
                                 sentinel=m)
        accept = handshake(prop, src, dst)
        mate = mate.at[jnp.where(accept, src, n)].set(
            jnp.where(accept, dst, 0), mode="drop")
        mate = mate.at[jnp.where(accept, dst, n)].set(
            jnp.where(accept, src, 0), mode="drop")
        return mate, jnp.any(alive)

    mate0 = jnp.full((n,), -1, dtype=jnp.int32)
    mate, _ = jax.lax.while_loop(lambda s: s[1], body,
                                 (mate0, jnp.bool_(True)))
    return mate


def coalesce_edges(src: jnp.ndarray, dst: jnp.ndarray, weight: jnp.ndarray,
                   labels: jnp.ndarray, num_labels: int
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                              jnp.ndarray]:
    """Relabel an edge list through ``labels`` and merge the result.

    Intra-cluster edges (both endpoints in the same label) drop; parallel
    coarse edges merge with their weights summed (Laplacian semantics).
    Shape-static: returns ``(csrc, cdst, cw, m_coarse)`` where the arrays
    keep the input length ``m`` and only the first ``m_coarse`` entries are
    valid (canonical ``csrc < cdst``, sorted by (csrc, cdst)); slots beyond
    that hold zeros.  ``num_labels`` bounds the label values (``n`` of the
    fine graph always works); it is accepted for interface symmetry with
    the other segment ops but the lexicographic sort never needs it.
    """
    del num_labels  # kept for API clarity; the sort is label-range-free
    m = src.shape[0]
    cu, cv = labels[src], labels[dst]
    valid = cu != cv
    big = jnp.iinfo(jnp.int32).max
    # Lexicographic (lo, hi) sort — int32-safe at any label range (a fused
    # lo * num_labels + hi key would overflow without x64).  Invalid edges
    # sort to the end via the sentinel.
    lo = jnp.where(valid, jnp.minimum(cu, cv).astype(jnp.int32), big)
    hi = jnp.where(valid, jnp.maximum(cu, cv).astype(jnp.int32), big)
    order = jnp.lexsort((hi, lo))
    lo_s, hi_s = lo[order], hi[order]
    w_s, valid_s = weight[order], valid[order]
    first = valid_s & jnp.concatenate(
        [jnp.ones((1,), bool),
         (lo_s[1:] != lo_s[:-1]) | (hi_s[1:] != hi_s[:-1])])
    uid = jnp.cumsum(first.astype(jnp.int32)) - 1   # coarse edge id per slot
    safe_uid = jnp.where(valid_s, uid, m)
    cw = jnp.zeros((m,), weight.dtype).at[safe_uid].add(
        jnp.where(valid_s, w_s, 0), mode="drop")
    first_uid = jnp.where(first, uid, m)
    csrc = jnp.zeros((m,), jnp.int32).at[first_uid].set(lo_s, mode="drop")
    cdst = jnp.zeros((m,), jnp.int32).at[first_uid].set(hi_s, mode="drop")
    return csrc, cdst, cw, first.sum()


# ---------------------------------------------------------------------------
# Mesh-aware variants: same semantics, edges row-sharded over a named axis.
# Every function below runs INSIDE a shard_map body; its array arguments are
# the local shard slices and its outputs are replicated across the axis.
# ---------------------------------------------------------------------------

def sharded_segment_argmax(values: jnp.ndarray, segment_ids: jnp.ndarray,
                           num_segments: int, *, axis: str,
                           element_ids: jnp.ndarray,
                           sentinel: Optional[int] = None,
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """:func:`segment_argmax` with the elements sharded over mesh ``axis``.

    Each shard reduces its local elements, then two collectives combine the
    shards under the same (value, minimal element id) total order: a
    ``pmax`` settles the per-segment best value, a ``pmin`` over the element
    ids that attain it settles the winner.  ``element_ids`` is mandatory and
    must carry *global* ids (unique across shards) — local ``arange`` ids
    would collide between shards and corrupt the tie-break.  The result is
    replicated: every shard holds the full ``[num_segments]`` pick/best.
    """
    big = jnp.iinfo(element_ids.dtype).max
    pick_l, best_l = segment_argmax(values, segment_ids, num_segments,
                                    element_ids=element_ids, sentinel=big)
    best = jax.lax.pmax(best_l, axis)
    cand = jnp.where((best_l == best) & (best > -jnp.inf), pick_l, big)
    pick = jax.lax.pmin(cand, axis)
    if sentinel is None:
        sentinel = big
    return jnp.where(pick == big, sentinel, pick), best


def sharded_matching(n: int, src: jnp.ndarray, dst: jnp.ndarray,
                     weight: jnp.ndarray, edge_ids: jnp.ndarray, *,
                     axis: str) -> jnp.ndarray:
    """:func:`propose_accept_matching` with the edge list sharded over
    ``axis``; returns the replicated ``[n]`` ``mate`` array.

    ``edge_ids`` carries the global edge id of every local slot, ``-1`` for
    padding (shards are padded to equal length).  Each round the proposal
    sweep runs as a :func:`sharded_segment_argmax` (one ``pmax`` + one
    ``pmin``), every shard tests the handshake on its own edges, and the
    accepted writes merge with a ``pmax`` (accepted edges are vertex-
    disjoint across the *whole* mesh, so at most one shard writes a
    vertex).  The strict (weight, -edge id) total order is preserved end to
    end, so the matching is bit-identical to the single-device rounds and
    therefore to the sequential greedy oracle.
    """
    valid = edge_ids >= 0
    heads = jnp.concatenate([src, dst])
    eids2 = jnp.concatenate([edge_ids, edge_ids])
    w2 = jnp.concatenate([weight, weight])
    big = jnp.iinfo(jnp.int32).max

    def body(state):
        mate, _ = state
        free = mate < 0
        alive = valid & free[src] & free[dst]
        alive2 = jnp.concatenate([alive, alive])
        vals = jnp.where(alive2, w2, -jnp.inf)
        prop, _ = sharded_segment_argmax(vals, heads, n, axis=axis,
                                         element_ids=eids2, sentinel=big)
        accept = alive & (prop[src] == edge_ids) & (prop[dst] == edge_ids)
        upd = jnp.full((n,), -1, jnp.int32)
        upd = upd.at[jnp.where(accept, src, n)].set(
            jnp.where(accept, dst, 0), mode="drop")
        upd = upd.at[jnp.where(accept, dst, n)].set(
            jnp.where(accept, src, 0), mode="drop")
        upd = jax.lax.pmax(upd, axis)
        mate = jnp.where(upd >= 0, upd, mate)
        n_alive = jax.lax.psum(jnp.sum(alive.astype(jnp.int32)), axis)
        return mate, n_alive > 0

    mate0 = jnp.full((n,), -1, dtype=jnp.int32)
    mate, _ = jax.lax.while_loop(lambda s: s[1], body,
                                 (mate0, jnp.bool_(True)))
    return mate


def sharded_coalesce_edges(src: jnp.ndarray, dst: jnp.ndarray,
                           weight: jnp.ndarray, labels: jnp.ndarray,
                           num_labels: int, *, axis: str
                           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                                      jnp.ndarray]:
    """:func:`coalesce_edges` with the edge list sharded over ``axis``.

    Two phases, the classic combiner/reduce split: every shard coalesces
    its *local* slice first (one local lexsort — this is where parallel
    duplicates within a shard collapse), then one ``all_gather`` of the
    locally-merged lists feeds a final replicated merge.  Padding slots
    (``src == dst``) drop in phase one.  Output layout matches
    :func:`coalesce_edges` over the gathered length ``n_sh * m_loc``:
    canonical, sorted, first ``m_coarse`` entries valid — replicated on
    every shard.  Coarse weights equal the single-device result up to f32
    summation order (partial sums happen per shard first).
    """
    csrc, cdst, cw, _ = coalesce_edges(src, dst, weight, labels, num_labels)
    g_src = jax.lax.all_gather(csrc, axis, tiled=True)
    g_dst = jax.lax.all_gather(cdst, axis, tiled=True)
    g_w = jax.lax.all_gather(cw, axis, tiled=True)
    # phase two relabels through the identity: entries are already coarse
    # ids; empty slots came out of phase one as (0, 0) and drop again.
    ident = jnp.arange(num_labels, dtype=jnp.int32)
    return coalesce_edges(g_src, g_dst, g_w, ident, num_labels)
