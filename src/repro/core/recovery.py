"""Step 4 of pdGRASS: strict-similarity off-tree edge recovery.

Two engines, bit-identical on the same input (property-tested):

  * :func:`recover_serial` — numpy oracle, a direct transcription of the
    paper's sequential per-subtask greedy (Algorithm 1, step 4).
  * :func:`recover_rounds` — the JAX/TPU engine.  Each *round* picks, for
    every active subtask, the first ``block_size`` unprocessed edges
    (globally capped at ``max_candidates``), resolves ordering *inside*
    the candidate block with a tiny sequential scan (Lemma 8:
    non-commutativity forces in-order processing), then marks the rest of
    each subtask against the newly recovered edges in one flat vectorized
    pass.  This is the paper's "mixed parallel strategy": the outer
    parallelism over subtasks (Lemma 7: disjointness) and the inner
    blocked parallelism within large subtasks both become flat vector
    work over the whole edge array.

Similarity checks use the ancestor-signature reduction from
``lifting.ancestor_signatures`` — (c+1)^2 integer equality tests instead
of BFS — which is what the Pallas kernel in ``repro.kernels.similarity``
accelerates on TPU.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

STATUS_OPEN = 0       # not yet processed
STATUS_RECOVERED = 1  # recovered into the sparsifier
STATUS_SKIPPED = 2    # marked strictly similar to an earlier recovered edge


class RecoveryProblem(NamedTuple):
    """Flat per-off-tree-edge arrays, sorted by (subtask id asc, score desc).

    Padding rows (to a multiple of the chunk size) carry ``seg == -1``.
    """

    sig_u: jnp.ndarray   # [m, c+1] int32 ancestor signature of endpoint u
    sig_v: jnp.ndarray   # [m, c+1] int32 ancestor signature of endpoint v
    beta: jnp.ndarray    # [m] int32  beta* = min(d(u,lca), d(v,lca), c)
    seg: jnp.ndarray     # [m] int32  contiguous subtask ids (-1 = padding)
    score: jnp.ndarray   # [m] float32 spectral criticality (w * R_T)

    @property
    def m(self) -> int:
        return int(self.sig_u.shape[0])


# ---------------------------------------------------------------------------
# Similarity predicate (shared by both engines)
# ---------------------------------------------------------------------------

def _apb_table(c1: int) -> np.ndarray:
    a = np.arange(c1)
    return (a[:, None] + a[None, :]).astype(np.int32)  # [c1, c1]


def match_table(sig_a: jnp.ndarray, sig_b: jnp.ndarray, beta_a: jnp.ndarray):
    """``[..., I, c1]`` x ``[..., J, c1]`` -> ``[..., I, J]`` bool.

    Entry (i, j) is True iff tree-dist(a_i, b_j) <= beta_a[i]; i.e. b_j lies
    in the beta_a[i]-hop neighborhood of a_i.
    """
    c1 = sig_a.shape[-1]
    apb = jnp.asarray(_apb_table(c1))
    eq = sig_a[..., :, None, :, None] == sig_b[..., None, :, None, :]
    ok = eq & (apb <= beta_a[..., :, None, None, None])
    return jnp.any(ok, axis=(-1, -2))


def strict_similarity_matrix(sig_u_a, sig_v_a, beta_a, sig_u_b, sig_v_b):
    """[I, J] bool: edge a_i (recovered) marks edge b_j (Definition 5).

    sim = (u_j in S_{u_i}  and  v_j in S_{v_i})
       or (u_j in S_{v_i}  and  v_j in S_{u_i})
    """
    m_uu = match_table(sig_u_a, sig_u_b, beta_a)
    m_vv = match_table(sig_v_a, sig_v_b, beta_a)
    m_uv = match_table(sig_u_a, sig_v_b, beta_a)
    m_vu = match_table(sig_v_a, sig_u_b, beta_a)
    return (m_uu & m_vv) | (m_uv & m_vu)


# ---------------------------------------------------------------------------
# Serial oracle (numpy) — faithful transcription of the paper's step 4
# ---------------------------------------------------------------------------

def recover_serial(prob: RecoveryProblem) -> np.ndarray:
    """Greedy in-order recovery per subtask; returns status[m] (numpy)."""
    sig_u = np.asarray(prob.sig_u)
    sig_v = np.asarray(prob.sig_v)
    beta = np.asarray(prob.beta)
    seg = np.asarray(prob.seg)
    m = seg.shape[0]
    status = np.full(m, STATUS_SKIPPED, dtype=np.int8)
    status[seg >= 0] = STATUS_OPEN

    # segments are contiguous
    bounds = np.flatnonzero(np.diff(np.concatenate([[-2], seg])) != 0)
    bounds = np.concatenate([bounds, [m]])
    c1 = sig_u.shape[1]
    apb = _apb_table(c1)

    def in_hood(sig_x, sig_ys, b):
        # sig_x [c1], sig_ys [k, c1] -> [k] bool
        eq = sig_x[None, :, None] == sig_ys[:, None, :]
        return np.any(eq & (apb[None] <= b), axis=(1, 2))

    for s in range(len(bounds) - 1):
        lo, hi = bounds[s], bounds[s + 1]
        if lo >= m or seg[lo] < 0:
            continue
        for i in range(lo, hi):
            if status[i] != STATUS_OPEN:
                continue
            status[i] = STATUS_RECOVERED
            rest = np.arange(i + 1, hi)
            rest = rest[status[rest] == STATUS_OPEN]
            if rest.size == 0:
                continue
            b = beta[i]
            uu = in_hood(sig_u[i], sig_u[rest], b)
            vv = in_hood(sig_v[i], sig_v[rest], b)
            uv = in_hood(sig_u[i], sig_v[rest], b)
            vu = in_hood(sig_v[i], sig_u[rest], b)
            sim = (uu & vv) | (uv & vu)
            status[rest[sim]] = STATUS_SKIPPED
    return status


# ---------------------------------------------------------------------------
# JAX round engine
# ---------------------------------------------------------------------------

class RoundStats(NamedTuple):
    rounds: jnp.ndarray           # int32 number of rounds executed
    candidates: jnp.ndarray       # int32 total candidates examined
    killed_in_block: jnp.ndarray  # int32 candidates killed inside blocks


@functools.partial(
    jax.jit,
    static_argnames=("block_size", "max_candidates", "stop_at_target", "chunk",
                     "use_kernel"))
def recover_rounds(
    prob: RecoveryProblem,
    target: jnp.ndarray | int = 2**31 - 1,
    *,
    block_size: int = 16,
    max_candidates: int = 128,
    stop_at_target: bool = False,
    chunk: int = 2048,
    use_kernel: bool = False,
):
    """Round-based parallel recovery.  Returns (status[m] int8, RoundStats).

    With ``stop_at_target=False`` the result is bit-identical to
    :func:`recover_serial`.  With ``stop_at_target=True`` rounds stop as
    soon as the number of recovered edges reaches ``target`` (the paper's
    stopping rule); the final sparsifier then truncates to the top
    ``target`` recovered edges by score either way.
    """
    m = prob.m
    K = max_candidates
    B = block_size
    seg, beta = prob.seg, prob.beta
    sig_u, sig_v = prob.sig_u, prob.sig_v
    is_edge = seg >= 0
    status0 = jnp.where(is_edge, STATUS_OPEN, STATUS_SKIPPED).astype(jnp.int8)

    # Exclusive prefix count of rows per segment, for in-segment ranks.
    # seg ids are contiguous ascending; seg_first[s] = first row of segment s.
    arange_m = jnp.arange(m, dtype=jnp.int32)

    def cond(state):
        status, stats = state
        open_left = jnp.any(status == STATUS_OPEN)
        if stop_at_target:
            n_rec = jnp.sum((status == STATUS_RECOVERED).astype(jnp.int32))
            return open_left & (n_rec < target)
        return open_left

    def body(state):
        status, stats = state
        avail = status == STATUS_OPEN
        ones = avail.astype(jnp.int32)
        cums = jnp.cumsum(ones)
        # in-segment rank among available rows
        seg_ids = jnp.where(is_edge, seg, 0)
        first_of_seg = jnp.concatenate(
            [jnp.array([True]), seg[1:] != seg[:-1]]) & is_edge
        seg_base = jnp.zeros((m,), jnp.int32).at[
            jnp.where(first_of_seg, seg_ids, m)
        ].set(jnp.where(first_of_seg, cums - ones, 0), mode="drop")
        rank = cums - ones - seg_base[seg_ids]
        cand = avail & (rank < B)
        crank = jnp.cumsum(cand.astype(jnp.int32)) - cand.astype(jnp.int32)
        cand = cand & (crank < K)

        # gather candidate rows (ascending index = processing order)
        cidx = jnp.sort(jnp.where(cand, arange_m, m))[:K]
        cvalid = cidx < m
        ci = jnp.where(cvalid, cidx, 0)
        csu, csv = sig_u[ci], sig_v[ci]
        cbeta = jnp.where(cvalid, beta[ci], -1)
        cseg = jnp.where(cvalid, seg[ci], -2)

        # K x K in-block ordering resolution (Lemma 8: strictly in order)
        sim = strict_similarity_matrix(csu, csv, cbeta, csu, csv)
        same = cseg[:, None] == cseg[None, :]
        later = jnp.arange(K)[None, :] > jnp.arange(K)[:, None]
        sim = sim & same & later & cvalid[:, None] & cvalid[None, :]

        def scan_body(killed, row):
            sim_row, idx = row
            alive = ~killed[idx]
            killed = killed | jnp.where(alive, sim_row, False)
            return killed, alive

        # NB: zeros_like(sim[0]) (not zeros((K,))) so the carry inherits the
        # varying-manual-axes type when running inside shard_map.
        killed, _ = jax.lax.scan(
            scan_body, jnp.zeros_like(sim[0]),
            (sim, jnp.arange(K)))
        recovered_c = cvalid & ~killed

        new_status = jnp.where(recovered_c, STATUS_RECOVERED, STATUS_SKIPPED)
        status = status.at[jnp.where(cvalid, cidx, m)].set(
            new_status.astype(jnp.int8), mode="drop")

        # Flat marking pass: every still-open row vs the recovered candidates
        # of *its own* segment, chunked over rows to bound VMEM/RAM.
        # (use_kernel=True routes through the Pallas tile kernel instead.)
        mark_beta = jnp.where(recovered_c, cbeta, -1)  # -1 disables the row

        if use_kernel:
            from repro.kernels import ops as kops

            kill = kops.similarity_mark(csu, csv, mark_beta, cseg,
                                        sig_u, sig_v, seg, tile_m=chunk)
        else:
            def mark_chunk(start):
                c1 = sig_u.shape[1]
                eseg = jax.lax.dynamic_slice(seg, (start,), (chunk,))

                # Chunk pruning (§Perf): segments are contiguous ascending,
                # so a chunk can only contain marks if some *recovered*
                # candidate's subtask id falls inside its [lo, hi] range.
                # Most subtasks close after a few rounds — this turns the
                # per-round marking pass from O(m*K) into O(active*K).
                lo, hi = eseg[0], jnp.max(eseg)  # tail padding rows are -1
                rec_rows = recovered_c & (cseg >= lo) & (cseg <= hi)

                def do_mark(_):
                    esu = jax.lax.dynamic_slice(sig_u, (start, 0), (chunk, c1))
                    esv = jax.lax.dynamic_slice(sig_v, (start, 0), (chunk, c1))
                    sim_mk = strict_similarity_matrix(csu, csv, mark_beta,
                                                      esu, esv)
                    same_mk = cseg[:, None] == eseg[None, :]
                    return jnp.any(sim_mk & same_mk, axis=0)

                # zeros_like(eseg) (not zeros((chunk,))) so the carry type
                # matches under shard_map's varying-manual-axes tracking
                return jax.lax.cond(jnp.any(rec_rows), do_mark,
                                    lambda _: jnp.zeros_like(eseg, bool), 0)

            n_chunks = m // chunk
            kill = jax.lax.map(
                mark_chunk, jnp.arange(n_chunks, dtype=jnp.int32) * chunk
            ).reshape(m)
        kill = kill & (status == STATUS_OPEN)
        status = jnp.where(kill, STATUS_SKIPPED, status).astype(jnp.int8)

        stats = RoundStats(
            rounds=stats.rounds + 1,
            candidates=stats.candidates + jnp.sum(cvalid.astype(jnp.int32)),
            killed_in_block=stats.killed_in_block
            + jnp.sum((cvalid & killed).astype(jnp.int32)),
        )
        return status, stats

    # varying-typed zero (plain 0 outside shard_map)
    zero = jnp.sum(jnp.zeros_like(seg, jnp.int32))
    stats0 = RoundStats(zero, zero, zero)
    status, stats = jax.lax.while_loop(cond, body, (status0, stats0))
    return status, stats


def select_top(status, score, target):
    """Keep the ``target`` highest-score recovered edges (deterministic)."""
    recovered = status == STATUS_RECOVERED
    order = jnp.argsort(-jnp.where(recovered, score, -jnp.inf))
    taken_in_order = jnp.cumsum(recovered[order].astype(jnp.int32))
    keep_sorted = recovered[order] & (taken_in_order <= target)
    keep = jnp.zeros_like(recovered).at[order].set(keep_sorted)
    return keep
