"""Binary lifting over the rooted spanning tree, in JAX.

Provides:
  * skip tables ``up[k][v]`` = 2^k-th ancestor (root saturates to itself),
  * resistive prefix sums ``rw[k][v]`` = sum of 1/w along those 2^k hops,
  * O(log V) vectorized LCA queries (vmapped over edges),
  * exact resistance distance R_T(u,v) via root prefix sums,
  * c-hop *ancestor signatures* used by the strict-similarity check.

TPU adaptation (see DESIGN.md): feGRASS/pdGRASS compute beta-hop
neighborhoods with BFS queues.  On a tree, dist_T(x,y) <= beta iff there
exist a+b <= beta with anc_a(x) == anc_b(y); since pdGRASS caps beta at a
small constant c (default 8), each vertex carries a fixed (c+1)-entry
ancestor signature and every similarity check becomes a dense (c+1)^2
integer-equality reduction — no BFS, no gathers in the inner loop, pure
VPU work.  Saturation at the root keeps the check exact (matches through
saturated entries still witness true tree distance <= a+b).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Lifting(NamedTuple):
    up: jnp.ndarray        # [L, n] int32 ancestors at power-of-two hops
    rw: jnp.ndarray        # [L, n] float32 resistive length of those hops
    depth: jnp.ndarray     # [n] int32
    rdist_root: jnp.ndarray  # [n] float32 resistive distance to root


def num_levels(n: int) -> int:
    return max(int(np.ceil(np.log2(max(n, 2)))) + 1, 1)


@functools.partial(jax.jit, static_argnums=(0,))
def build_lifting(n: int, parent, parent_w, depth) -> Lifting:
    L = num_levels(n)
    up0 = parent.astype(jnp.int32)
    rw0 = jnp.where(parent == jnp.arange(n), 0.0, 1.0 / parent_w.clip(1e-30))

    def step(carry, _):
        up_k, rw_k = carry
        up_n = up_k[up_k]
        rw_n = rw_k + rw_k[up_k]
        return (up_n, rw_n), (up_n, rw_n)

    (_, _), (ups, rws) = jax.lax.scan(step, (up0, rw0), None, length=L - 1)
    up = jnp.concatenate([up0[None], ups], axis=0)
    rw = jnp.concatenate([rw0[None], rws], axis=0)
    # rw saturates at the root (root self-loop adds 0), so the top level IS
    # the resistive root distance.
    rdist_root = rw[-1]
    return Lifting(up=up, rw=rw, depth=depth.astype(jnp.int32),
                   rdist_root=rdist_root)


def lca(lift: Lifting, u: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Vectorized LCA for equal-shaped index arrays ``u``/``v``."""
    up, depth = lift.up, lift.depth
    L = up.shape[0]
    du, dv = depth[u], depth[v]
    a = jnp.where(du >= dv, u, v)   # deeper
    b = jnp.where(du >= dv, v, u)
    diff = jnp.abs(du - dv)
    for k in range(L - 1, -1, -1):
        lift_it = (diff >> k) & 1
        a = jnp.where(lift_it.astype(bool), up[k][a], a)
    eq = a == b
    for k in range(L - 1, -1, -1):
        differs = up[k][a] != up[k][b]
        go = (~eq) & differs
        a = jnp.where(go, up[k][a], a)
        b = jnp.where(go, up[k][b], b)
    return jnp.where(eq, a, up[0][a])


def resistance_distance(lift: Lifting, u, v, lca_uv) -> jnp.ndarray:
    """R_T(u, v) = rdist(u, root) + rdist(v, root) - 2 * rdist(lca, root)."""
    r = lift.rdist_root
    return r[u] + r[v] - 2.0 * r[lca_uv]


def ancestor_signatures(parent: jnp.ndarray, c: int) -> jnp.ndarray:
    """[n, c+1] int32: sig[v, j] = j-th ancestor of v (saturating at root)."""
    n = parent.shape[0]
    cur = jnp.arange(n, dtype=jnp.int32)
    rows = [cur]
    for _ in range(c):
        cur = parent[cur]
        rows.append(cur)
    return jnp.stack(rows, axis=1)
