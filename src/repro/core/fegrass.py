"""feGRASS baseline: loose (vertex-cover) similarity, multi-pass recovery.

This is the comparison target of the paper (its Table II).  It shares steps
1-2 with pdGRASS (same spanning tree, same criticality order — the paper
does the same for an apples-to-apples recovery comparison) and differs in
step 4:

  * similarity is the *loose* condition (Definition 4 / Eq. 7): an edge is
    skipped if **either** endpoint is inside the union of the covered
    beta-hop neighborhoods of previously recovered edges;
  * the covered set is a vertex bitmap rebuilt each pass; if a pass ends
    with fewer than ``alpha * |V|`` recovered edges, the remaining edges are
    re-scanned in another pass (this is the multi-pass pathology that
    pdGRASS eliminates — thousands of passes on hub-dominated graphs).

In the unified API this is just the ``multipass`` recovery engine
(:mod:`repro.pipeline.stages`): feGRASS == pdGRASS with a different
``recovery`` stage config.  :func:`fegrass` below is the back-compat
wrapper over ``Pipeline(fegrass_config(...))``.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import Graph
from repro.core.sparsify import Prepared, Sparsifier


def _tree_csr(graph: Graph, tree_mask: np.ndarray):
    """CSR adjacency of the spanning tree (host side)."""
    s = graph.src[tree_mask]
    d = graph.dst[tree_mask]
    heads = np.concatenate([s, d])
    tails = np.concatenate([d, s])
    order = np.argsort(heads, kind="stable")
    heads, tails = heads[order], tails[order]
    indptr = np.zeros(graph.n + 1, dtype=np.int64)
    np.add.at(indptr, heads + 1, 1)
    return np.cumsum(indptr), tails


def _bfs_ball(indptr, adj, start: int, beta: int, out: np.ndarray):
    """Mark all vertices within ``beta`` tree hops of ``start`` in ``out``."""
    frontier = [start]
    seen = {start}
    out[start] = True
    for _ in range(beta):
        nxt = []
        for u in frontier:
            for v in adj[indptr[u]:indptr[u + 1]]:
                if v not in seen:
                    seen.add(v)
                    out[v] = True
                    nxt.append(v)
        if not nxt:
            break
        frontier = nxt


def loose_multipass_recover(prep: Prepared, target: int, *, c: int = 8,
                            max_passes: int = 200_000):
    """The feGRASS recovery engine: loose-similarity multi-pass (numpy).

    Returns ``(recovered_mask [graph.m] bool, stats)`` — the recovery-engine
    contract of :mod:`repro.pipeline.stages`.
    """
    graph = prep.graph
    tree_mask = np.asarray(prep.tree.in_tree)
    indptr, adj = _tree_csr(graph, tree_mask)

    # Off-tree edges in global criticality order (score desc).
    score = np.asarray(prep.problem.score)[: prep.m_off]
    order = np.argsort(-score, kind="stable")
    eids = prep.off_edge_id[order]
    eu = graph.src[eids]
    ev = graph.dst[eids]

    recovered: list[int] = []
    remaining = np.arange(eids.shape[0])
    passes = 0
    while len(recovered) < target and remaining.size and passes < max_passes:
        passes += 1
        covered = np.zeros(graph.n, dtype=bool)
        keep_for_next = []
        progress = False
        for idx in remaining:
            if len(recovered) >= target:
                break
            u, v = int(eu[idx]), int(ev[idx])
            if covered[u] or covered[v]:
                keep_for_next.append(idx)
                continue
            recovered.append(idx)
            progress = True
            _bfs_ball(indptr, adj, u, c, covered)
            _bfs_ball(indptr, adj, v, c, covered)
        if not progress:
            break
        remaining = np.asarray(keep_for_next, dtype=remaining.dtype)

    recovered_mask = np.zeros(graph.m, dtype=bool)
    recovered_mask[eids[np.asarray(recovered, dtype=np.int64)]] = True
    return recovered_mask, {"passes": passes}


def fegrass(
    graph: Graph,
    alpha: float = 0.02,
    *,
    c: int = 8,
    max_passes: int = 200_000,
    prepared: Prepared | None = None,
) -> Sparsifier:
    """Loose-similarity multi-pass recovery — back-compat wrapper over
    ``Pipeline(fegrass_config(...))``."""
    from repro.pipeline import Pipeline, fegrass_config

    cfg = fegrass_config(alpha=alpha, c=c, max_passes=max_passes)
    return Pipeline(cfg).run(graph, prepared=prepared)
