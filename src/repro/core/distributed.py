"""Distributed pdGRASS recovery: the paper's mixed parallel strategy on a mesh.

The paper parallelizes over OpenMP threads; here the same two-level
decomposition maps onto a JAX device mesh with shard_map:

  * **Outer parallelism** (Lemma 7 — subtasks are disjoint): subtasks are
    greedily bin-packed (LPT) onto devices; every device runs the local
    round engine on its own bucket with *zero* communication.  This is the
    embarrassingly-parallel regime the paper exploits on uniform inputs.
  * **Inner parallelism** (skewed inputs — e.g. the com-Youtube giant
    subtask holding >99% of off-tree edges): the edges of one huge subtask
    are sharded contiguously across all devices of the group.  Each round,
    devices select their local candidate prefix, exchange candidate rows
    with a single ``all_gather`` (the only collective), replicate the tiny
    in-block resolution, and mark their local slice.  The loop condition is
    a ``psum`` so all devices agree on termination.
  * **Mixed strategy**: subtasks above ``cutoff`` (paper: 1e5 edges or 10%
    of off-tree edges) go through the inner engine one at a time; the rest
    are bucketed for the outer engine — exactly the heuristic in §IV.A.

The same code paths lower on the production (multi-pod) mesh for the
dry-run: see ``repro.launch.dryrun`` with ``--arch pdgrass_graph``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.graph_ops import shard_map_compat as _shard_map
from repro.obs import get_metrics, get_tracer

from repro.core import recovery as rec_mod
from repro.core.recovery import (STATUS_OPEN, STATUS_RECOVERED,
                                 STATUS_SKIPPED, RecoveryProblem,
                                 strict_similarity_matrix)


# ---------------------------------------------------------------------------
# Host-side partitioning (outer parallelism)
# ---------------------------------------------------------------------------

def pad_fill_value(dtype, *, lowest: bool = False):
    """Per-dtype sentinel for padding slots in the shard builders.

    ``lowest=True`` asks for the most-negative representable value (the
    "never wins an argmax" encoding for score arrays): ``-inf`` for floats,
    ``iinfo.min`` for signed integers.  ``lowest=False`` asks for the
    conventional ``-1`` invalid marker (checked via ``x >= 0`` downstream).
    Unsigned integers cannot represent either sentinel — ``np.full`` would
    silently wrap ``-1`` to the *maximum*, turning padding into live data —
    so they are rejected loudly.
    """
    dtype = np.dtype(dtype)
    if np.issubdtype(dtype, np.floating):
        return -np.inf if lowest else dtype.type(-1.0)
    if np.issubdtype(dtype, np.unsignedinteger):
        raise TypeError(
            f"cannot pad unsigned dtype {dtype}: the -1/-inf sentinels "
            f"would wrap to live values — use a signed or float array")
    if np.issubdtype(dtype, np.integer):
        return np.iinfo(dtype).min if lowest else dtype.type(-1)
    raise TypeError(f"no pad sentinel for dtype {dtype}")


def partition_subtasks(sizes: np.ndarray, n_shards: int,
                       cutoff: int | None = None,
                       cutoff_frac: float = 0.10):
    """LPT bin-packing of subtasks onto shards.

    Returns (shard_of_subtask [S] with -1 = "inner" giant task,
             giant_subtask_ids list, per-shard load).
    """
    total = int(sizes.sum())
    if cutoff is None:
        cutoff = int(min(1e5, max(1, cutoff_frac * total)))
    giants = np.flatnonzero(sizes >= cutoff)
    shard_of = np.full(sizes.shape[0], -1, dtype=np.int32)
    load = np.zeros(n_shards, dtype=np.int64)
    order = np.argsort(-sizes)
    for s in order:
        if sizes[s] >= cutoff:
            continue
        tgt = int(np.argmin(load))
        shard_of[s] = tgt
        load[tgt] += int(sizes[s])
    return shard_of, giants.tolist(), load


class ShardedProblem(NamedTuple):
    """[n_shards, m_loc] stacked per-device recovery problems."""

    sig_u: jnp.ndarray
    sig_v: jnp.ndarray
    beta: jnp.ndarray
    seg: jnp.ndarray
    score: jnp.ndarray
    # maps local rows back to rows of the flat (sorted) problem; -1 = pad
    src_row: jnp.ndarray


def build_outer_shards(problem: RecoveryProblem, seg_sizes: np.ndarray,
                       shard_of: np.ndarray, n_shards: int,
                       chunk: int = 2048) -> ShardedProblem:
    """Materialize per-shard edge buckets (host side, one-time cost)."""
    seg = np.asarray(problem.seg)
    m = seg.shape[0]
    rows_per_shard: list[list[np.ndarray]] = [[] for _ in range(n_shards)]
    # segments are contiguous: locate them once
    starts = np.flatnonzero(np.concatenate([[True], seg[1:] != seg[:-1]]))
    starts = starts[seg[starts] >= 0]
    for st in starts:
        sid = seg[st]
        tgt = shard_of[sid]
        if tgt < 0:
            continue
        rows_per_shard[tgt].append(np.arange(st, st + seg_sizes[sid]))
    m_loc = max([chunk] + [
        int(np.ceil(sum(len(r) for r in rows) / chunk)) * chunk
        for rows in rows_per_shard])

    def gather(x, *, lowest=False):
        x = np.asarray(x)
        fill = pad_fill_value(x.dtype, lowest=lowest)
        out = np.full((n_shards, m_loc) + x.shape[1:], fill, dtype=x.dtype)
        for sh, rows in enumerate(rows_per_shard):
            if rows:
                idx = np.concatenate(rows)
                out[sh, : idx.shape[0]] = x[idx]
        return jnp.asarray(out)

    src_row = np.full((n_shards, m_loc), -1, dtype=np.int64)
    for sh, rows in enumerate(rows_per_shard):
        if rows:
            idx = np.concatenate(rows)
            src_row[sh, : idx.shape[0]] = idx
    return ShardedProblem(
        sig_u=gather(problem.sig_u),
        sig_v=gather(problem.sig_v),
        beta=gather(problem.beta),
        seg=gather(problem.seg),
        score=gather(problem.score, lowest=True),
        src_row=jnp.asarray(src_row),
    )


# ---------------------------------------------------------------------------
# Outer engine: shard_map over the stacked buckets (no collectives)
# ---------------------------------------------------------------------------

def recover_outer(sharded: ShardedProblem, mesh, axis: str = "data",
                  block_size: int = 16, max_candidates: int = 128,
                  chunk: int = 2048):
    """Run the local round engine on every shard (embarrassingly parallel)."""

    def local(sig_u, sig_v, beta, seg, score):
        prob = RecoveryProblem(sig_u[0], sig_v[0], beta[0], seg[0], score[0])
        status, stats = rec_mod.recover_rounds(
            prob, block_size=block_size, max_candidates=max_candidates,
            stop_at_target=False, chunk=chunk)
        return status[None], stats.rounds[None]

    fn = _shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis)),
        out_specs=(P(axis), P(axis)))
    status, rounds = fn(sharded.sig_u, sharded.sig_v, sharded.beta,
                        sharded.seg, sharded.score)
    return status, rounds


# ---------------------------------------------------------------------------
# Inner engine: one giant subtask sharded across devices
# ---------------------------------------------------------------------------

def _inner_round_engine(sig_u, sig_v, beta, seg, axis: str, n_sh: int,
                        block_size: int, chunk: int):
    """Round engine for one segment sharded over ``axis``.

    Local shapes: sig_u/sig_v [m_loc, c1]; beta/seg [m_loc].
    One all_gather of candidate rows per round; psum for termination.

    ``n_sh`` is the *static* shard count along ``axis``, supplied by the
    :func:`recover_inner` wrapper (which reads ``mesh.shape[axis]``).  It
    must be static: the engine builds ``jnp.arange(n_sh)`` and reshapes
    gathered blocks by it, neither of which traces from a dynamic value.
    (A ``jax.lax.psum(1, axis)`` fallback — used before jax grew
    ``jax.lax.axis_size`` — yields a *traced* value on those builds and
    broke exactly there.)
    """
    m_loc = seg.shape[0]
    c1 = sig_u.shape[1]
    B = block_size
    my = jax.lax.axis_index(axis)
    is_edge = seg >= 0
    status0 = jnp.where(is_edge, STATUS_OPEN, STATUS_SKIPPED).astype(jnp.int8)
    arange = jnp.arange(m_loc, dtype=jnp.int32)

    def cond(state):
        status, _ = state
        n_open = jnp.sum((status == STATUS_OPEN).astype(jnp.int32))
        return jax.lax.psum(n_open, axis) > 0

    def body(state):
        status, rounds = state
        avail = status == STATUS_OPEN
        ones = avail.astype(jnp.int32)
        local_cum = jnp.cumsum(ones)
        local_tot = local_cum[-1]
        # exclusive prefix over shards of open counts
        all_tot = jax.lax.all_gather(local_tot, axis)          # [n_sh]
        base = jnp.sum(jnp.where(jnp.arange(n_sh) < my, all_tot, 0))
        rank = base + local_cum - ones                         # global rank
        cand = avail & (rank < B)

        # collect local candidates (<= B), then all_gather
        cidx = jnp.sort(jnp.where(cand, arange, m_loc))[:B]
        cvalid = cidx < m_loc
        ci = jnp.where(cvalid, cidx, 0)
        crank = jnp.where(cvalid, rank[ci], B)
        pack = (sig_u[ci], sig_v[ci],
                jnp.where(cvalid, beta[ci], -1), crank)
        g_su, g_sv, g_beta, g_rank = jax.lax.all_gather(pack, axis)  # [n_sh, B, ...]
        g_su = g_su.reshape(n_sh * B, c1)
        g_sv = g_sv.reshape(n_sh * B, c1)
        g_beta = g_beta.reshape(n_sh * B)
        g_rank = g_rank.reshape(n_sh * B)
        # order by global rank; invalid slots have rank == B -> sorted last
        order = jnp.argsort(g_rank, stable=True)[:B]
        k_su, k_sv = g_su[order], g_sv[order]
        k_beta, k_rank = g_beta[order], g_rank[order]
        k_valid = k_beta >= 0

        # replicated in-block resolution (deterministic on every shard)
        sim = strict_similarity_matrix(k_su, k_sv, k_beta, k_su, k_sv)
        later = jnp.arange(B)[None, :] > jnp.arange(B)[:, None]
        sim = sim & later & k_valid[:, None] & k_valid[None, :]

        def scan_body(killed, row):
            sim_row, idx = row
            alive = ~killed[idx]
            return killed | jnp.where(alive, sim_row, False), None

        killed, _ = jax.lax.scan(scan_body, jnp.zeros_like(sim[0]),
                                 (sim, jnp.arange(B)))
        recovered_k = k_valid & ~killed

        # write back statuses for MY candidates (match by global rank)
        my_new = jnp.zeros((B,), jnp.int8)
        # k_rank -> status; map each of my cand slots to its rank row
        hit = crank[:, None] == k_rank[None, :]      # [B_my, B_k]
        rec_my = jnp.any(hit & recovered_k[None, :], axis=1)
        status = status.at[jnp.where(cvalid, cidx, m_loc)].set(
            jnp.where(rec_my, STATUS_RECOVERED, STATUS_SKIPPED).astype(jnp.int8),
            mode="drop")

        # mark local open rows vs recovered block rows
        mark_beta = jnp.where(recovered_k, k_beta, -1)

        def mark_chunk(start):
            esu = jax.lax.dynamic_slice(sig_u, (start, 0), (chunk, c1))
            esv = jax.lax.dynamic_slice(sig_v, (start, 0), (chunk, c1))
            sim_mk = strict_similarity_matrix(k_su, k_sv, mark_beta, esu, esv)
            return jnp.any(sim_mk, axis=0)

        kill = jax.lax.map(
            mark_chunk, jnp.arange(m_loc // chunk, dtype=jnp.int32) * chunk
        ).reshape(m_loc)
        kill = kill & (status == STATUS_OPEN) & is_edge
        status = jnp.where(kill, STATUS_SKIPPED, status).astype(jnp.int8)
        return status, rounds + 1

    status, rounds = jax.lax.while_loop(
        cond, body, (status0, jnp.int32(0)))
    return status, rounds


def recover_inner(sig_u, sig_v, beta, seg, mesh, axis: str = "data",
                  block_size: int = 32, chunk: int = 2048):
    """shard_map wrapper for one giant segment sharded over ``axis``.

    The wrapper knows the mesh, so the shard count goes in as a static
    Python int — the engine never derives it from collectives."""
    fn = _shard_map(
        functools.partial(_inner_round_engine, axis=axis,
                          n_sh=int(mesh.shape[axis]),
                          block_size=block_size, chunk=chunk),
        mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis), P(axis)),
        out_specs=(P(axis), P()),
    )
    return fn(sig_u, sig_v, beta, seg)


# ---------------------------------------------------------------------------
# Mixed strategy driver
# ---------------------------------------------------------------------------

def recover_mixed(prepared, mesh, axis: str = "data",
                  block_size: int = 16, max_candidates: int = 128,
                  chunk: int = 2048, cutoff: int | None = None):
    """Full distributed recovery; returns status aligned with prepared order.

    Exactly equivalent to the serial oracle (property-tested): giant
    subtasks via the inner engine, the rest via LPT outer buckets.
    """
    prob = prepared.problem
    n_shards = int(np.prod([mesh.shape[a] for a in ([axis] if isinstance(axis, str) else axis)]))
    shard_of, giants, _ = partition_subtasks(
        prepared.subtask_sizes, n_shards, cutoff=cutoff)

    m = prob.m
    status_global = np.full(m, STATUS_SKIPPED, dtype=np.int8)
    seg_np = np.asarray(prob.seg)
    tracer = get_tracer()
    metrics = get_metrics()
    metrics.inc("dist.recoveries")
    with tracer.span("dist.recover_mixed", n_shards=n_shards,
                     giants=len(giants), m=m) as msp:
        # --- inner engine for each giant subtask, one at a time ---
        starts = np.flatnonzero(
            np.concatenate([[True], seg_np[1:] != seg_np[:-1]]))
        start_of = {int(seg_np[s]): int(s) for s in starts if seg_np[s] >= 0}
        inner_rounds = 0
        for sid in giants:
            st = start_of[sid]
            sz = int(prepared.subtask_sizes[sid])
            m_loc = int(np.ceil(sz / (n_shards * chunk))) * chunk
            m_tot = m_loc * n_shards
            sl = slice(st, st + sz)

            def pad(x):
                x = np.asarray(x[sl])
                out = np.full((m_tot,) + x.shape[1:],
                              pad_fill_value(x.dtype), dtype=x.dtype)
                out[:sz] = x
                return jnp.asarray(out)

            bs = max(block_size, 32)
            with tracer.span("dist.inner", subtask=int(sid), edges=sz,
                             m_tot=m_tot) as isp:
                status, rounds = recover_inner(
                    pad(np.asarray(prob.sig_u)), pad(np.asarray(prob.sig_v)),
                    pad(np.asarray(prob.beta)), pad(seg_np),
                    mesh, axis=axis, block_size=bs, chunk=chunk)
                status_global[sl] = np.asarray(status)[:sz]
                rounds = int(np.asarray(rounds).reshape(-1)[0])
                # per-round collective payload: one all_gather of the
                # candidate pack (two signature blocks + beta + rank) from
                # every shard — the engine's only communication
                c1 = int(np.asarray(prob.sig_u).shape[1])
                pack_bytes = n_shards * bs * (2 * c1 * 4 + 4 + 4)
                isp.set(rounds=rounds,
                        collective_bytes=rounds * pack_bytes)
                metrics.inc("dist.inner_rounds", rounds)
                metrics.inc("dist.collective_bytes", rounds * pack_bytes)
            inner_rounds += rounds

        # --- outer engine for everything else ---
        outer_rounds = 0
        if np.any(shard_of >= 0):
            with tracer.span("dist.outer", n_shards=n_shards) as osp:
                sharded = build_outer_shards(prob, prepared.subtask_sizes,
                                             shard_of, n_shards, chunk=chunk)
                status, rounds = recover_outer(
                    sharded, mesh, axis=axis, block_size=block_size,
                    max_candidates=max_candidates, chunk=chunk)
                status = np.asarray(status).reshape(-1)
                src = np.asarray(sharded.src_row).reshape(-1)
                ok = src >= 0
                status_global[src[ok]] = status[ok]
                outer_rounds = int(np.max(np.asarray(rounds))) if np.asarray(
                    rounds).size else 0
                osp.set(rounds=outer_rounds)
                metrics.inc("dist.outer_rounds", outer_rounds)
        msp.set(inner_rounds=inner_rounds, outer_rounds=outer_rounds)
    return status_global
