"""pdGRASS data structures + back-compat entry points.

The pipeline orchestration (the paper's Algorithm 1: tree -> lifting ->
scores -> subtasks -> recovery) lives in :mod:`repro.pipeline`, where each
step is a named, pluggable stage.  This module keeps

  * the shared data structures — :class:`Prepared` (steps 1-3 output) and
    :class:`Sparsifier` (the result, with device-resident Laplacian views),
  * :func:`prepare` / :func:`pdgrass` — thin wrappers over
    ``repro.pipeline`` preserving the original loose-kwargs signatures.

    sparsifier = pdgrass(graph, alpha=0.05)      # unchanged

is exactly ``Pipeline(pdgrass_config(alpha=0.05)).run(graph)``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import numpy as np

from repro.core import lifting as lift_mod
from repro.core import recovery as rec_mod
from repro.core import spanning_tree as st_mod
from repro.core.graph import Graph


@dataclasses.dataclass(frozen=True)
class Prepared:
    """Everything up to (and excluding) edge recovery — shared by engines."""

    graph: Graph
    tree: st_mod.TreeResult           # device arrays
    lift: lift_mod.Lifting
    off_edge_id: np.ndarray           # [m_off] undirected edge id (sorted order)
    problem: rec_mod.RecoveryProblem  # padded to chunk multiple
    n_subtasks: int
    subtask_sizes: np.ndarray         # [n_subtasks] int64, desc not guaranteed

    @property
    def m_off(self) -> int:
        return int(self.off_edge_id.shape[0])


@dataclasses.dataclass(frozen=True)
class Sparsifier:
    graph: Graph
    tree_mask: np.ndarray       # [m] bool — spanning tree edges
    recovered_mask: np.ndarray  # [m] bool — recovered off-tree edges
    stats: dict

    @property
    def edge_mask(self) -> np.ndarray:
        return self.tree_mask | self.recovered_mask

    @functools.cached_property
    def device_graph(self):
        """Device-resident view of the sparsifier (kept edges only).

        Cached: the upload + diagonal build happens once per sparsifier.
        """
        from repro.core.device_graph import DeviceGraph

        return DeviceGraph.from_graph(self.graph, edge_mask=self.edge_mask)

    def to_ell(self):
        """Sparsifier Laplacian as device ELL [n, L] slabs (no scipy) —
        what ``solver/hierarchy`` levels and the Pallas SpMV kernel consume."""
        return self.device_graph.to_ell()

    def laplacian_matvec(self, x):
        """jit-safe ``y = L_P x`` on the device ([n] or [n, k])."""
        return self.device_graph.laplacian_matvec(x)

    def laplacian(self):
        """Sparsifier Laplacian as scipy CSR (host-side reference path)."""
        import scipy.sparse as sp

        g = self.graph
        keep = self.edge_mask
        s, d, w = g.src[keep], g.dst[keep], g.weight[keep].astype(np.float64)
        i = np.concatenate([s, d, np.arange(g.n)])
        j = np.concatenate([d, s, np.arange(g.n)])
        deg = np.zeros(g.n)
        np.add.at(deg, s, w)
        np.add.at(deg, d, w)
        v = np.concatenate([-w, -w, deg])
        return sp.csr_matrix((v, (i, j)), shape=(g.n, g.n))


def prepare(graph: Graph, c: int = 8, chunk: int = 2048,
            score_mode: str = "w_times_r") -> Prepared:
    """Steps 1-3: tree, lifting, scores, subtask grouping (host+device)."""
    from repro.pipeline import Pipeline, pdgrass_config

    return Pipeline(
        pdgrass_config(c=c, chunk=chunk, score_mode=score_mode)
    ).prepare(graph)


def pdgrass(
    graph: Graph,
    alpha: float = 0.02,
    *,
    c: int = 8,
    engine: str = "rounds",
    score_mode: str = "w_times_r",
    block_size: int = 16,
    max_candidates: int = 128,
    stop_at_target: bool = True,
    chunk: int = 2048,
    prepared: Optional[Prepared] = None,
) -> Sparsifier:
    """Run the full pdGRASS pipeline and return the sparsifier.

    Back-compat wrapper over :class:`repro.pipeline.Pipeline`; every kwarg
    maps onto a :class:`repro.pipeline.PipelineConfig` field (``score_mode``
    included — it is forwarded end to end, see ``ScoreConfig``).
    """
    from repro.pipeline import Pipeline, pdgrass_config

    cfg = pdgrass_config(
        alpha=alpha, c=c, chunk=chunk, engine=engine, score_mode=score_mode,
        block_size=block_size, max_candidates=max_candidates,
        stop_at_target=stop_at_target)
    return Pipeline(cfg).run(graph, prepared=prepared)
