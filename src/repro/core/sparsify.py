"""End-to-end pdGRASS pipeline: the paper's Algorithm 1 as a public API.

    sparsifier = pdgrass(graph, alpha=0.05)

Steps (paper section IV.B):
  1. resistance distance per off-tree edge (binary lifting, JAX),
  2. sort off-tree edges by spectral criticality,
  3. subtasks keyed by LCA (Lemma 6/7: disjoint across LCAs),
  4. strict-similarity recovery (round engine or serial oracle).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lifting as lift_mod
from repro.core import recovery as rec_mod
from repro.core import spanning_tree as st_mod
from repro.core.graph import Graph


@dataclasses.dataclass(frozen=True)
class Prepared:
    """Everything up to (and excluding) edge recovery — shared by engines."""

    graph: Graph
    tree: st_mod.TreeResult           # device arrays
    lift: lift_mod.Lifting
    off_edge_id: np.ndarray           # [m_off] undirected edge id (sorted order)
    problem: rec_mod.RecoveryProblem  # padded to chunk multiple
    n_subtasks: int
    subtask_sizes: np.ndarray         # [n_subtasks] int64, desc not guaranteed

    @property
    def m_off(self) -> int:
        return int(self.off_edge_id.shape[0])


@dataclasses.dataclass(frozen=True)
class Sparsifier:
    graph: Graph
    tree_mask: np.ndarray       # [m] bool — spanning tree edges
    recovered_mask: np.ndarray  # [m] bool — recovered off-tree edges
    stats: dict

    @property
    def edge_mask(self) -> np.ndarray:
        return self.tree_mask | self.recovered_mask

    def laplacian(self):
        import scipy.sparse as sp

        g = self.graph
        keep = self.edge_mask
        s, d, w = g.src[keep], g.dst[keep], g.weight[keep].astype(np.float64)
        i = np.concatenate([s, d, np.arange(g.n)])
        j = np.concatenate([d, s, np.arange(g.n)])
        deg = np.zeros(g.n)
        np.add.at(deg, s, w)
        np.add.at(deg, d, w)
        v = np.concatenate([-w, -w, deg])
        return sp.csr_matrix((v, (i, j)), shape=(g.n, g.n))


def prepare(graph: Graph, c: int = 8, chunk: int = 2048,
            score_mode: str = "w_times_r") -> Prepared:
    """Steps 1–3: tree, lifting, scores, subtask grouping (host+device)."""
    n, m = graph.n, graph.m
    src = jnp.asarray(graph.src)
    dst = jnp.asarray(graph.dst)
    w = jnp.asarray(graph.weight)

    tree = st_mod.build_spanning_tree(n, src, dst, w)
    lift = lift_mod.build_lifting(n, tree.parent, tree.parent_w, tree.depth)

    in_tree = np.asarray(tree.in_tree)
    off_ids = np.flatnonzero(~in_tree)
    ou = jnp.asarray(graph.src[off_ids])
    ov = jnp.asarray(graph.dst[off_ids])
    ow = jnp.asarray(graph.weight[off_ids])

    l = lift_mod.lca(lift, ou, ov)
    r_t = lift_mod.resistance_distance(lift, ou, ov, l)
    if score_mode == "w_times_r":
        score = ow * r_t   # spectral criticality w(e) * R_T(e) (feGRASS)
    elif score_mode == "r":
        score = r_t
    else:
        raise ValueError(score_mode)
    depth = lift.depth
    beta = jnp.minimum(
        jnp.minimum(depth[ou] - depth[l], depth[ov] - depth[l]), c
    ).astype(jnp.int32)

    sig = lift_mod.ancestor_signatures(tree.parent, c)
    sig_u = sig[ou]
    sig_v = sig[ov]

    # Host-side ordering: LCA ascending, score descending (stable).
    l_np = np.asarray(l)
    score_np = np.asarray(score)
    order = np.lexsort((-score_np, l_np))
    l_sorted = l_np[order]
    seg_change = np.concatenate([[True], l_sorted[1:] != l_sorted[:-1]])
    seg_ids = np.cumsum(seg_change) - 1
    n_subtasks = int(seg_ids[-1]) + 1 if len(seg_ids) else 0
    sizes = np.bincount(seg_ids, minlength=max(n_subtasks, 1))

    m_off = off_ids.shape[0]
    m_pad = max(chunk, int(math.ceil(m_off / chunk)) * chunk)
    pad = m_pad - m_off

    def pad_rows(x, fill, reorder=True):
        x = np.asarray(x)
        if reorder:
            x = x[order]
        if pad:
            shape = (pad,) + x.shape[1:]
            x = np.concatenate([x, np.full(shape, fill, dtype=x.dtype)])
        return jnp.asarray(x)

    problem = rec_mod.RecoveryProblem(
        sig_u=pad_rows(sig_u, -1),
        sig_v=pad_rows(sig_v, -1),
        beta=pad_rows(beta, -1),
        # seg_ids are already in sorted order (built from l_sorted)
        seg=pad_rows(seg_ids.astype(np.int32), -1, reorder=False),
        score=pad_rows(score_np, -np.inf),
    )
    return Prepared(
        graph=graph, tree=tree, lift=lift,
        off_edge_id=off_ids[order],
        problem=problem, n_subtasks=n_subtasks,
        subtask_sizes=sizes,
    )


def pdgrass(
    graph: Graph,
    alpha: float = 0.02,
    *,
    c: int = 8,
    engine: str = "rounds",
    block_size: int = 16,
    max_candidates: int = 128,
    stop_at_target: bool = True,
    chunk: int = 2048,
    prepared: Optional[Prepared] = None,
) -> Sparsifier:
    """Run the full pdGRASS pipeline and return the sparsifier."""
    prep = prepared if prepared is not None else prepare(graph, c=c, chunk=chunk)
    target = int(math.ceil(alpha * graph.n))
    target = min(target, prep.m_off)

    if engine == "rounds":
        status, stats = rec_mod.recover_rounds(
            prep.problem, jnp.int32(target),
            block_size=block_size, max_candidates=max_candidates,
            stop_at_target=stop_at_target, chunk=chunk)
        status = np.asarray(status)
        stats_d = {
            "rounds": int(stats.rounds),
            "candidates": int(stats.candidates),
            "killed_in_block": int(stats.killed_in_block),
        }
    elif engine == "serial":
        status = rec_mod.recover_serial(prep.problem)
        stats_d = {"rounds": -1}
    else:
        raise ValueError(engine)

    keep = np.asarray(
        rec_mod.select_top(jnp.asarray(status), prep.problem.score, target))
    keep = keep[: prep.m_off]

    tree_mask = np.asarray(prep.tree.in_tree)
    recovered_mask = np.zeros(graph.m, dtype=bool)
    recovered_mask[prep.off_edge_id[keep]] = True

    stats_d.update(
        n_recovered=int(recovered_mask.sum()),
        target=target,
        n_subtasks=prep.n_subtasks,
        max_subtask=int(prep.subtask_sizes.max()) if prep.n_subtasks else 0,
        passes=1,  # pdGRASS always completes in a single pass (paper claim)
    )
    return Sparsifier(graph=graph, tree_mask=tree_mask,
                      recovered_mask=recovered_mask, stats=stats_d)
