"""pdGRASS core: parallel density-aware graph spectral sparsification in JAX.

Public API:
    build_graph / generators      (repro.core.graph)
    DeviceGraph                   (repro.core.device_graph)  -- device pytree
    graph_ops primitives          (repro.core.graph_ops)  -- jit-safe
        segment_argmax / handshake / propose_accept_matching /
        pointer_jump / compact_labels / coalesce_edges
    prepare, pdgrass, Sparsifier  (repro.core.sparsify)
    fegrass                       (repro.core.fegrass)  -- baseline
    pcg_host, pcg_jax, quality_iters (repro.core.pcg)

The staged, configurable pipeline these entry points wrap lives in
:mod:`repro.pipeline` (Pipeline / PipelineConfig).
"""
from repro.core.graph import (Graph, build_graph, grid2d, mesh2d,
                              barabasi_albert, watts_strogatz, random_regular,
                              star_hub, suite)
from repro.core.device_graph import DeviceGraph
from repro.core.graph_ops import (coalesce_edges, compact_labels, handshake,
                                  pointer_jump, propose_accept_matching,
                                  segment_argmax)
from repro.core.sparsify import Prepared, Sparsifier, prepare, pdgrass
from repro.core.fegrass import fegrass
from repro.core.pcg import pcg_host, pcg_jax, quality_iters

__all__ = [
    "Graph", "DeviceGraph", "build_graph", "grid2d", "mesh2d",
    "barabasi_albert", "watts_strogatz", "random_regular", "star_hub",
    "suite",
    "segment_argmax", "handshake", "propose_accept_matching",
    "pointer_jump", "compact_labels", "coalesce_edges",
    "Prepared", "Sparsifier", "prepare", "pdgrass", "fegrass",
    "pcg_host", "pcg_jax", "quality_iters",
]
