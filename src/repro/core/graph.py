"""Graph substrate for pdGRASS.

Host-side (numpy) graph construction, validation and synthetic generators.
The device-side algorithm (BFS, Boruvka MST, binary lifting, recovery) lives
in the sibling modules and consumes the flat edge arrays defined here.

All graphs are undirected, weighted, connected, simple (no self loops, no
multi-edges).  Edges are stored once with ``src < dst``; a CSR adjacency over
both directions is kept for host-side reference algorithms (feGRASS baseline,
PCG assembly).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """A weighted undirected graph in flat-array form.

    Attributes:
      n:       number of vertices.
      src/dst: ``[m]`` int32 endpoints with ``src < dst``.
      weight:  ``[m]`` float32 positive edge weights.
      indptr/adj/adj_w/adj_edge: CSR over both edge directions; ``adj_edge``
        maps a directed slot back to the undirected edge id.
    """

    n: int
    src: np.ndarray
    dst: np.ndarray
    weight: np.ndarray
    indptr: np.ndarray
    adj: np.ndarray
    adj_w: np.ndarray
    adj_edge: np.ndarray

    @property
    def m(self) -> int:
        return int(self.src.shape[0])

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int32)

    def laplacian(self):
        """Graph Laplacian as a scipy CSR matrix (host side)."""
        import scipy.sparse as sp

        i = np.concatenate([self.src, self.dst, np.arange(self.n)])
        j = np.concatenate([self.dst, self.src, np.arange(self.n)])
        deg_w = np.zeros(self.n, dtype=np.float64)
        np.add.at(deg_w, self.src, self.weight)
        np.add.at(deg_w, self.dst, self.weight)
        v = np.concatenate([-self.weight, -self.weight, deg_w])
        return sp.csr_matrix((v, (i, j)), shape=(self.n, self.n))

    def laplacian_matvec(self, x: np.ndarray) -> np.ndarray:
        """``y = L x`` in float64 over the CSR arrays — numpy only, no scipy.

        ``x`` may be [n] or [n, k].  Used by the solver's f64 refinement
        residual checks; graphs here are connected (every row non-empty),
        which ``np.add.reduceat`` over ``indptr`` relies on.
        """
        x = np.asarray(x, dtype=np.float64)
        w = self.adj_w.astype(np.float64)
        wdeg = np.add.reduceat(w, self.indptr[:-1])
        if x.ndim == 2:
            nbr = np.add.reduceat(w[:, None] * x[self.adj],
                                  self.indptr[:-1], axis=0)
            return wdeg[:, None] * x - nbr
        nbr = np.add.reduceat(w * x[self.adj], self.indptr[:-1])
        return wdeg * x - nbr


def build_graph(n: int, src, dst, weight) -> Graph:
    """Validate + canonicalize an edge list into a :class:`Graph`."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    weight = np.asarray(weight, dtype=np.float32)
    if src.shape != dst.shape or src.shape != weight.shape:
        raise ValueError("src/dst/weight shape mismatch")
    if np.any(src == dst):
        raise ValueError("self loops are not allowed")
    lo = np.minimum(src, dst)
    hi = np.maximum(src, dst)
    # Deduplicate multi-edges by summing weights (standard Laplacian semantics).
    key = lo * n + hi
    order = np.argsort(key, kind="stable")
    key, lo, hi, weight = key[order], lo[order], hi[order], weight[order]
    uniq, start = np.unique(key, return_index=True)
    if uniq.shape[0] != key.shape[0]:
        wsum = np.add.reduceat(weight, start)
        lo, hi, weight = lo[start], hi[start], wsum.astype(np.float32)
    if np.any(weight <= 0):
        raise ValueError("edge weights must be positive")

    m = lo.shape[0]
    # CSR over both directions.
    heads = np.concatenate([lo, hi])
    tails = np.concatenate([hi, lo])
    eids = np.concatenate([np.arange(m), np.arange(m)])
    ws = np.concatenate([weight, weight])
    order = np.argsort(heads, kind="stable")
    heads, tails, eids, ws = heads[order], tails[order], eids[order], ws[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, heads + 1, 1)
    indptr = np.cumsum(indptr)

    g = Graph(
        n=n,
        src=lo.astype(np.int32),
        dst=hi.astype(np.int32),
        weight=weight.astype(np.float32),
        indptr=indptr.astype(np.int64),
        adj=tails.astype(np.int32),
        adj_w=ws.astype(np.float32),
        adj_edge=eids.astype(np.int32),
    )
    if not is_connected(g):
        raise ValueError("graph must be a single connected component")
    return g


def is_connected(g: Graph) -> bool:
    seen = np.zeros(g.n, dtype=bool)
    stack = [0]
    seen[0] = True
    while stack:
        u = stack.pop()
        nbrs = g.adj[g.indptr[u]:g.indptr[u + 1]]
        new = nbrs[~seen[nbrs]]
        seen[new] = True
        stack.extend(new.tolist())
    return bool(seen.all())


# ---------------------------------------------------------------------------
# Synthetic generators (stand-ins for the SuiteSparse suite; no network access)
# ---------------------------------------------------------------------------

def _rand_weights(rng: np.random.Generator, m: int) -> np.ndarray:
    # Paper: "random positive weights uniformly sampled between 1 and 10".
    return rng.uniform(1.0, 10.0, size=m).astype(np.float32)


def grid2d(rows: int, cols: int, seed: int = 0) -> Graph:
    """2D grid — analog of the road/census graphs (mi2010 .. tx2010)."""
    rng = np.random.default_rng(seed)
    idx = np.arange(rows * cols).reshape(rows, cols)
    right = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], 1)
    down = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], 1)
    e = np.concatenate([right, down])
    return build_graph(rows * cols, e[:, 0], e[:, 1], _rand_weights(rng, len(e)))


def mesh2d(rows: int, cols: int, seed: int = 0) -> Graph:
    """Triangulated grid — analog of the FEM meshes (NACA0015, M6, 333SP...)."""
    rng = np.random.default_rng(seed)
    idx = np.arange(rows * cols).reshape(rows, cols)
    right = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], 1)
    down = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], 1)
    diag = np.stack([idx[:-1, :-1].ravel(), idx[1:, 1:].ravel()], 1)
    e = np.concatenate([right, down, diag])
    return build_graph(rows * cols, e[:, 0], e[:, 1], _rand_weights(rng, len(e)))


def barabasi_albert(n: int, k: int = 3, seed: int = 0) -> Graph:
    """Preferential attachment — skewed degrees, analog of com-Youtube/DBLP.

    These are the worst-case inputs for feGRASS (few high-degree hubs).
    """
    import networkx as nx

    rng = np.random.default_rng(seed)
    gx = nx.barabasi_albert_graph(n, k, seed=seed)
    e = np.asarray(gx.edges(), dtype=np.int64)
    return build_graph(n, e[:, 0], e[:, 1], _rand_weights(rng, len(e)))


def watts_strogatz(n: int, k: int = 6, p: float = 0.1, seed: int = 0) -> Graph:
    import networkx as nx

    rng = np.random.default_rng(seed)
    gx = nx.connected_watts_strogatz_graph(n, k, p, seed=seed)
    e = np.asarray(gx.edges(), dtype=np.int64)
    return build_graph(n, e[:, 0], e[:, 1], _rand_weights(rng, len(e)))


def random_regular(n: int, d: int = 4, seed: int = 0) -> Graph:
    import networkx as nx

    rng = np.random.default_rng(seed)
    gx = nx.random_regular_graph(d, n, seed=seed)
    if not nx.is_connected(gx):
        # connect components with a path
        comps = [list(c) for c in nx.connected_components(gx)]
        for a, b in zip(comps, comps[1:]):
            gx.add_edge(a[0], b[0])
    e = np.asarray(gx.edges(), dtype=np.int64)
    return build_graph(n, e[:, 0], e[:, 1], _rand_weights(rng, len(e)))


def star_hub(n: int, extra: int = 0, seed: int = 0) -> Graph:
    """Star + random chords — the degenerate feGRASS input (one pass per edge)."""
    rng = np.random.default_rng(seed)
    src = np.zeros(n - 1, dtype=np.int64)
    dst = np.arange(1, n, dtype=np.int64)
    if extra:
        a = rng.integers(1, n, size=extra)
        b = rng.integers(1, n, size=extra)
        keep = a != b
        src = np.concatenate([src, a[keep]])
        dst = np.concatenate([dst, b[keep]])
    return build_graph(n, src, dst, _rand_weights(rng, len(src)))


def suite(scale: str = "small") -> dict:
    """The benchmark suite: one generator per structural family in Table II."""
    if scale == "tiny":
        return {
            "grid": grid2d(12, 12, seed=1),
            "mesh": mesh2d(12, 12, seed=2),
            "ba": barabasi_albert(150, 3, seed=3),
            "ws": watts_strogatz(150, 6, 0.1, seed=4),
            "star": star_hub(120, extra=80, seed=5),
        }
    if scale == "small":
        return {
            "grid": grid2d(60, 60, seed=1),
            "mesh": mesh2d(60, 60, seed=2),
            "ba": barabasi_albert(4000, 3, seed=3),
            "ws": watts_strogatz(4000, 6, 0.1, seed=4),
            "regular": random_regular(4000, 4, seed=6),
            "star": star_hub(3000, extra=2000, seed=5),
        }
    if scale == "medium":
        return {
            "grid": grid2d(300, 300, seed=1),
            "mesh": mesh2d(300, 300, seed=2),
            "ba": barabasi_albert(100_000, 3, seed=3),
            "ws": watts_strogatz(100_000, 6, 0.1, seed=4),
            "regular": random_regular(100_000, 4, seed=6),
            "star": star_hub(50_000, extra=40_000, seed=5),
        }
    raise ValueError(f"unknown scale {scale!r}")
