"""Device-resident companion to the host :class:`repro.core.graph.Graph`.

``Graph`` is numpy + CSR — the right substrate for host-side construction,
validation and reference algorithms.  :class:`DeviceGraph` is its jax pytree
twin: flat edge arrays plus the Laplacian diagonal, living on the device,
registered as a pytree so it flows through ``jit``/``vmap``/``shard_map``
untouched.  It is what the solver hot path consumes: ``laplacian_matvec``
is jit-safe scatter-add work, and ``to_ell`` emits the [n, L] ELL slabs the
Pallas SpMV kernel (``kernels/spmv_ell``) and the V-cycle levels eat —
no scipy, no host round-trip of edge data.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DeviceGraph:
    """Flat device edge arrays of an undirected weighted graph.

    Attributes:
      n:      vertex count (static pytree metadata).
      src/dst: ``[m]`` int32 endpoints, ``src < dst``.
      weight: ``[m]`` float32 positive edge weights.
      diag:   ``[n]`` float32 weighted degrees (the Laplacian diagonal).
    """

    n: int
    src: jnp.ndarray
    dst: jnp.ndarray
    weight: jnp.ndarray
    diag: jnp.ndarray

    @property
    def m(self) -> int:
        return int(self.src.shape[0])

    @classmethod
    def from_graph(cls, graph, edge_mask: Optional[np.ndarray] = None
                   ) -> "DeviceGraph":
        """Upload a host Graph (optionally restricted to ``edge_mask`` edges)."""
        if edge_mask is not None:
            keep = np.asarray(edge_mask, dtype=bool)
            src_h, dst_h, w_h = (graph.src[keep], graph.dst[keep],
                                 graph.weight[keep])
        else:
            src_h, dst_h, w_h = graph.src, graph.dst, graph.weight
        src = jnp.asarray(src_h, dtype=jnp.int32)
        dst = jnp.asarray(dst_h, dtype=jnp.int32)
        weight = jnp.asarray(w_h, dtype=jnp.float32)
        diag = (jnp.zeros((graph.n,), jnp.float32)
                .at[src].add(weight).at[dst].add(weight))
        return cls(n=graph.n, src=src, dst=dst, weight=weight, diag=diag)

    def laplacian_matvec(self, x: jnp.ndarray) -> jnp.ndarray:
        """``y = L x`` for ``x`` of shape [n] or [n, k] — jit-safe."""
        w, d = self.weight, self.diag
        if x.ndim == 2:
            w, d = w[:, None], d[:, None]
        y = d * x
        y = y.at[self.src].add(-w * x[self.dst])
        y = y.at[self.dst].add(-w * x[self.src])
        return y

    def to_ell(self, width: Optional[int] = None):
        """Laplacian in ELL [n, L] (column-index, value) slab layout.

        Row v holds its ``-w`` neighbor entries, then the diagonal, then
        padding slots that gather the row's own x with value 0 — the layout
        of ``kernels/spmv_ell``.  Built with device scatter ops; the only
        host sync is the slab width ``L`` (a shape, necessarily concrete).
        """
        n, m = self.n, self.m
        if m == 0:
            L = width or 1
            idx = jnp.broadcast_to(
                jnp.arange(n, dtype=jnp.int32)[:, None], (n, L))
            return idx, jnp.zeros((n, L), self.weight.dtype)
        heads = jnp.concatenate([self.src, self.dst])
        tails = jnp.concatenate([self.dst, self.src])
        ws = jnp.concatenate([self.weight, self.weight])
        deg = jnp.zeros((n,), jnp.int32).at[heads].add(1)
        L = int(deg.max()) + 1 if width is None else int(width)

        order = jnp.argsort(heads, stable=True)
        h, t, v = heads[order], tails[order], ws[order]
        start = jnp.cumsum(deg) - deg                 # first slot of each row
        slot = jnp.arange(2 * m, dtype=jnp.int32) - start[h]

        rows = jnp.arange(n, dtype=jnp.int32)
        idx = jnp.broadcast_to(rows[:, None], (n, L)).at[h, slot].set(t)
        val = jnp.zeros((n, L), self.weight.dtype).at[h, slot].set(-v)
        val = val.at[rows, deg].set(self.diag)
        return idx, val


jax.tree_util.register_dataclass(
    DeviceGraph,
    data_fields=["src", "dst", "weight", "diag"],
    meta_fields=["n"],
)
