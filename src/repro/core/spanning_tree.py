"""Step 1 of pdGRASS/feGRASS: effective-weight maximum spanning tree, in JAX.

TPU adaptation notes (see DESIGN.md):
  * BFS is expressed as iterative edge relaxation with scatter-min — one
    O(E) vectorized sweep per level instead of pointer-chasing frontiers.
  * The maximum spanning tree uses Boruvka (O(log V) fully-vectorizable
    rounds of segment-max + pointer jumping) instead of the sequential
    Kruskal/Prim used by the reference C++ implementation.  Boruvka with a
    strict (weight, -edge_id) total order provably produces the same MST and
    admits only 2-cycles in the hooking graph.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph_ops import pointer_jump, segment_argmax


def bfs_dist(n: int, usrc: jnp.ndarray, udst: jnp.ndarray, root) -> jnp.ndarray:
    """Unweighted BFS distances from ``root`` via edge relaxation.

    ``usrc``/``udst`` are the directed edge arrays (both orientations).
    Returns int32 distances; unreachable = n (graphs here are connected).
    """
    dist0 = jnp.full((n,), n, dtype=jnp.int32).at[root].set(0)

    def body(state):
        dist, _ = state
        cand = dist[usrc] + 1
        new = dist.at[udst].min(cand)
        return new, jnp.any(new != dist)

    def cond(state):
        return state[1]

    dist, _ = jax.lax.while_loop(cond, body, (dist0, jnp.bool_(True)))
    return dist


def effective_weights(n: int, src, dst, weight, deg, root_dist) -> jnp.ndarray:
    """Definition 1 (feGRASS): W_eff = w * log(max(deg)) / (d_u + d_v).

    ``root_dist`` are unweighted BFS distances from the max-degree root.
    deg >= 1 always; log(1) = 0 would zero out weights on degree-1 endpoints,
    so we floor the degree term at log(2) (documented deviation — only
    affects tie-breaking on leaf edges).
    """
    dmax = jnp.maximum(deg[src], deg[dst]).astype(jnp.float32)
    num = jnp.log(jnp.maximum(dmax, 2.0))
    den = (root_dist[src] + root_dist[dst]).astype(jnp.float32)
    den = jnp.maximum(den, 1.0)  # root's own edges have den >= 1 anyway
    return weight * num / den


class TreeResult(NamedTuple):
    in_tree: jnp.ndarray      # [m] bool — edge is in the spanning tree
    parent: jnp.ndarray       # [n] int32 — parent pointer (root -> itself)
    parent_w: jnp.ndarray     # [n] float32 — weight of edge to parent (root -> 0)
    depth: jnp.ndarray        # [n] int32 — hop depth from root
    root: jnp.ndarray         # int32


def boruvka_max_st(n: int, src, dst, eff_w) -> jnp.ndarray:
    """Maximum spanning tree over ``eff_w``; returns [m] bool mask.

    Deterministic via (weight, -edge index) total order.  O(log n) rounds,
    each a composition of the :mod:`repro.core.graph_ops` primitives: every
    component segment-argmaxes its best outgoing edge (proposal), hooks to
    the component across it (accept — 2-cycles broken to the smaller
    label), and the hooking forest collapses by pointer jumping.
    """
    m = src.shape[0]
    eidx = jnp.arange(m, dtype=jnp.int32)
    varange = jnp.arange(n, dtype=jnp.int32)
    eids2 = jnp.concatenate([eidx, eidx])

    def round_body(state):
        comp, in_tree, _ = state
        cu, cv = comp[src], comp[dst]
        valid = cu != cv
        key = jnp.where(valid, eff_w, -jnp.inf)
        # Best outgoing edge per component, proposed from either endpoint;
        # duplicated element ids make both directions resolve to one winner.
        pick, _ = segment_argmax(jnp.concatenate([key, key]),
                                 jnp.concatenate([cu, cv]), n,
                                 element_ids=eids2, sentinel=m)
        has = pick < m
        pe = jnp.where(has, pick, 0)
        # Hook each component to the component across its picked edge.
        ecu, ecv = comp[src[pe]], comp[dst[pe]]
        other = jnp.where(ecu == varange, ecv, ecu)
        parent = jnp.where(has, other, varange)
        # Break 2-cycles: keep the smaller label as the new root.
        p2 = parent[parent]
        parent = jnp.where((p2 == varange) & (varange < parent), varange, parent)
        parent = pointer_jump(parent)
        in_tree = in_tree.at[jnp.where(has, pick, m)].set(True, mode="drop")
        comp_new = parent[comp]
        return comp_new, in_tree, jnp.any(valid)

    def round_cond(state):
        return state[2]

    comp0 = varange
    in_tree0 = jnp.zeros((m,), dtype=bool)
    _, in_tree, _ = jax.lax.while_loop(
        round_cond, round_body, (comp0, in_tree0, jnp.bool_(True))
    )
    return in_tree


def root_tree(n: int, src, dst, weight, in_tree, root) -> TreeResult:
    """Orient the spanning tree away from ``root``: parent/depth/parent_w."""
    m = src.shape[0]
    big = jnp.where(in_tree, 0, n)  # drop non-tree edges by pushing dist to inf
    usrc = jnp.concatenate([src, dst])
    udst = jnp.concatenate([dst, src])
    mask2 = jnp.concatenate([in_tree, in_tree])
    dist0 = jnp.full((n,), n, dtype=jnp.int32).at[root].set(0)

    def body(state):
        dist, _ = state
        cand = jnp.where(mask2, dist[usrc] + 1, n)
        new = dist.at[udst].min(cand)
        return new, jnp.any(new != dist)

    depth, _ = jax.lax.while_loop(lambda s: s[1], body, (dist0, jnp.bool_(True)))

    # parent[child] = other endpoint for tree edges with depth diff +1.
    parent = jnp.arange(n, dtype=jnp.int32)
    parent_w = jnp.zeros((n,), dtype=weight.dtype)
    child_is_dst = in_tree & (depth[dst] == depth[src] + 1)
    child_is_src = in_tree & (depth[src] == depth[dst] + 1)
    parent = parent.at[jnp.where(child_is_dst, dst, n)].set(
        jnp.where(child_is_dst, src, 0), mode="drop")
    parent = parent.at[jnp.where(child_is_src, src, n)].set(
        jnp.where(child_is_src, dst, 0), mode="drop")
    parent_w = parent_w.at[jnp.where(child_is_dst, dst, n)].set(
        jnp.where(child_is_dst, weight, 0.0), mode="drop")
    parent_w = parent_w.at[jnp.where(child_is_src, src, n)].set(
        jnp.where(child_is_src, weight, 0.0), mode="drop")
    return TreeResult(in_tree=in_tree, parent=parent, parent_w=parent_w,
                      depth=depth, root=jnp.asarray(root, jnp.int32))


@functools.partial(jax.jit, static_argnums=0, static_argnames=("mode",))
def build_spanning_tree(n: int, src, dst, weight, *,
                        mode: str = "low_stretch") -> TreeResult:
    """Full step 1: degrees -> root -> BFS -> W_eff -> Boruvka -> rooting.

    ``mode`` selects the edge order Boruvka maximizes over (the pipeline's
    ``tree`` stage): ``"low_stretch"`` uses the feGRASS effective weights
    (Definition 1 — the low-stretch heuristic), ``"boruvka"`` uses the raw
    weights (a plain maximum spanning tree).
    """
    deg = (jnp.zeros((n,), jnp.int32).at[src].add(1).at[dst].add(1))
    root = jnp.argmax(deg).astype(jnp.int32)
    if mode == "low_stretch":
        usrc = jnp.concatenate([src, dst])
        udst = jnp.concatenate([dst, src])
        rd = bfs_dist(n, usrc, udst, root)
        eff = effective_weights(n, src, dst, weight, deg, rd)
    elif mode == "boruvka":
        eff = weight
    else:
        raise ValueError(f"unknown tree mode {mode!r}")
    in_tree = boruvka_max_st(n, src, dst, eff)
    return root_tree(n, src, dst, weight, in_tree, root)
