"""Training loop: pjit'd train_step + fault-tolerant resilient driver.

``make_train_step`` builds the jitted step with:
  * sharded-in params/opt-state (FSDP+TP specs from dist.sharding),
  * optional microbatch gradient accumulation (scan),
  * optional int8+error-feedback gradient compression (dist.compress),
  * donated buffers so params/opt update in place.

``ResilientTrainer`` is the large-scale control plane in miniature:
  * checkpoint every N steps (async, atomic) + restart-from-latest,
  * simulated failure injection (tests prove restart gives bit-identical
    training trajectories),
  * elastic re-mesh: restore the same checkpoint onto a smaller/bigger
    mesh (data-parallel world change) and keep going,
  * straggler mitigation: per-step wall-clock watchdog records slow steps
    and (at scale) would re-slice input shards away from slow hosts — the
    hook is here, the policy is pluggable.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import compress as comp_mod
from repro.dist import sharding as shard_mod
from repro.models import model as model_mod
from repro.models.config import ModelConfig
from repro.train import checkpoint as ckpt_mod
from repro.train.optimizer import AdamWConfig, OptState, adamw_update, init_opt_state


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    microbatches: int = 1          # gradient accumulation
    remat: bool = True
    compress_grads: bool = False   # int8 + error feedback
    aux_weight: float = 0.01


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh=None):
    """Returns train_step(params, opt_state, ef_state, batch) -> (...)."""

    def step_fn(params, opt_state, ef_state, batch):
        def lf(p, b):
            return model_mod.loss_fn(p, cfg, b, remat=tcfg.remat,
                                     aux_weight=tcfg.aux_weight)

        if tcfg.microbatches > 1:
            mb = tcfg.microbatches

            def split(x):
                return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

            mbatch = jax.tree.map(split, batch)

            def acc_fn(carry, b):
                g_acc, l_acc = carry
                (loss, _), g = jax.value_and_grad(lf, has_aux=True)(params, b)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(acc_fn, (g0, 0.0), mbatch)
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss = loss / mb
            metrics: Dict[str, Any] = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                lf, has_aux=True)(params, batch)

        if tcfg.compress_grads:
            grads, ef_state = comp_mod.compress_grads(grads, ef_state)

        params2, opt_state2, om = adamw_update(params, grads, opt_state,
                                               tcfg.opt)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = loss
        return params2, opt_state2, ef_state, metrics

    if mesh is None:
        return jax.jit(step_fn, donate_argnums=(0, 1, 2))
    return step_fn  # caller wraps with explicit shardings (launch.dryrun)


@dataclasses.dataclass
class ResilientTrainer:
    cfg: ModelConfig
    tcfg: TrainConfig
    ckpt_dir: str
    ckpt_every: int = 10
    straggler_factor: float = 3.0   # step slower than factor*median = straggler

    def __post_init__(self):
        self.step_times: list = []
        self.stragglers: list = []
        self._train_step = make_train_step(self.cfg, self.tcfg)

    def init_state(self, seed: int = 0):
        params = model_mod.init_params(self.cfg, jax.random.key(seed))
        opt = init_opt_state(params, self.tcfg.opt)
        ef = (comp_mod.init_error_feedback(params)
              if self.tcfg.compress_grads else
              jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params))
        return params, opt, ef

    def run(self, data_fn: Callable[[int], Iterator[Dict[str, np.ndarray]]],
            steps: int, fail_at: Optional[int] = None, resume: bool = True,
            seed: int = 0, log_every: int = 0):
        """Train; simulate a crash at ``fail_at`` (raises); resume from the
        latest checkpoint if one exists.  ``data_fn(start_step)`` builds the
        (deterministic) input iterator from a given step — on restart the
        pipeline rewinds to the checkpointed step, so the post-restart
        trajectory is bit-identical to an uninterrupted run."""
        params, opt, ef = self.init_state(seed)
        start = 0
        if resume:
            latest = ckpt_mod.latest_step(self.ckpt_dir)
            if latest is not None:
                params, opt, ef = ckpt_mod.restore(
                    self.ckpt_dir, latest, (params, opt, ef))
                start = latest
        data = data_fn(start)
        losses = []
        for step in range(start, steps):
            batch = next(data)
            if fail_at is not None and step == fail_at:
                raise RuntimeError(f"simulated node failure at step {step}")
            t0 = time.perf_counter()
            params, opt, ef, metrics = self._train_step(
                params, opt, ef, {k: jnp.asarray(v) for k, v in batch.items()})
            # designated sync point: the step must materialize here anyway —
            # step timing and straggler detection measure completed work
            host_metrics = jax.device_get(metrics)
            loss = float(host_metrics["loss"])
            dt = time.perf_counter() - t0
            self.step_times.append(dt)
            med = float(np.median(self.step_times[-20:]))
            if len(self.step_times) > 5 and dt > self.straggler_factor * med:
                self.stragglers.append((step, dt, med))
            losses.append(loss)
            if log_every and step % log_every == 0:
                print(f"step {step}: loss={loss:.4f} "
                      f"lr={float(host_metrics['lr']):.2e} {dt*1e3:.0f}ms")
            if (step + 1) % self.ckpt_every == 0:
                ckpt_mod.save(self.ckpt_dir, step + 1, (params, opt, ef))
                ckpt_mod.prune(self.ckpt_dir)
        return params, opt, losses
