"""Checkpointing: atomic, shard-friendly, reshardable on restore.

  * save: every leaf -> one .npy inside a step directory, written to a
    ``.tmp`` staging dir then atomically renamed (a crashed save can never
    corrupt the latest checkpoint) — the standard fault-tolerance contract.
  * async: saves can run on a background thread (overlaps the next step's
    compute, the usual trick to hide checkpoint latency at scale).
  * restore: loads the host arrays then ``device_put``s against *whatever
    mesh/shardings the caller passes* — this is what makes elastic
    restarts work: a checkpoint written on 2x16x16 restores onto 16x16 (or
    any mesh whose axes divide the shapes) without a conversion step.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str, step: int, tree: Any, *, blocking: bool = True):
    """Write checkpoint for ``step`` under ``path`` (atomic rename)."""
    leaves, treedef = _flatten(tree)
    host_leaves = [np.asarray(x) for x in leaves]
    dtypes = [str(a.dtype) for a in host_leaves]

    def _write():
        final = os.path.join(path, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        for i, arr in enumerate(host_leaves):
            if arr.dtype == "bfloat16":   # numpy can't serialize ml_dtypes
                arr = arr.view(np.uint16)
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "n_leaves": len(host_leaves),
                       "dtypes": dtypes, "treedef": str(treedef)}, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(path: str, step: int, like: Any, shardings: Any = None) -> Any:
    """Load ``step`` into the structure of ``like``; reshard if given."""
    d = os.path.join(path, f"step_{step:08d}")
    leaves, treedef = _flatten(like)
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    loaded = []
    for i, ref in enumerate(leaves):
        arr = np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
        if meta["dtypes"][i] == "bfloat16":
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"leaf {i}: shape {arr.shape} != {ref.shape}")
        loaded.append(arr.astype(ref.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, loaded)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree


def prune(path: str, keep: int = 3):
    if not os.path.isdir(path):
        return
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(path)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(path, f"step_{s:08d}"),
                      ignore_errors=True)
