"""AdamW + schedule, implemented raw on pytrees (no optax dependency).

Optimizer state shards exactly like the parameters (same PartitionSpecs),
which gives ZeRO-style fully-sharded optimizer memory for free under the
FSDP param specs.  Moments can be kept in bf16 (``state_dtype``) — the
update math always runs in f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    state_dtype: str = "float32"   # or "bfloat16" to halve optimizer memory


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jnp.ndarray


def init_opt_state(params, cfg: AdamWConfig) -> OptState:
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    z = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return OptState(m=jax.tree.map(z, params), v=jax.tree.map(z, params),
                    step=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step):
    """Linear warmup -> cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state: OptState, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return (newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    newp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    newm = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    newv = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return newp, OptState(newm, newv, step), {"lr": lr, "grad_norm": gnorm}
