"""Synthetic deterministic data pipeline.

Produces next-token-prediction batches from a seeded Markov-ish stream —
enough structure that the loss decreases, fully deterministic given
(seed, step), and shardable per host: each host materializes only its own
slice (``host_slice``), which is how a real multi-host input pipeline
feeds pjit'd arrays via ``jax.make_array_from_process_local_data``.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


def _tokens(rng: np.random.Generator, B: int, S: int, vocab: int):
    """Cheap structured stream: blockwise token-ramps + noise (learnable)."""
    base = rng.integers(0, vocab, (B, 1))
    step = rng.integers(1, 7, (B, 1))
    ramp = (base + step * np.arange(S + 1)[None, :]) % vocab
    noise = rng.integers(0, vocab, (B, S + 1))
    take = rng.random((B, S + 1)) < 0.1
    return np.where(take, noise, ramp).astype(np.int32)


def make_batch(cfg: ModelConfig, B: int, S: int, step: int, seed: int = 0,
               src_len: Optional[int] = None) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    toks = _tokens(rng, B, S, cfg.vocab)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.frontend and cfg.enc_layers == 0:
        batch["frontend"] = rng.standard_normal(
            (B, cfg.frontend_len, cfg.frontend_dim)).astype(np.float32)
    if cfg.enc_layers:
        batch["src"] = rng.standard_normal(
            (B, src_len or S, cfg.frontend_dim or cfg.d_model)
        ).astype(np.float32)
    return batch


def host_slice(batch: Dict[str, np.ndarray], host_id: int, n_hosts: int):
    """The per-host shard of a global batch (batch dim split)."""
    def sl(x):
        per = x.shape[0] // n_hosts
        return x[host_id * per:(host_id + 1) * per]

    return {k: sl(v) for k, v in batch.items()}


def batches(cfg: ModelConfig, B: int, S: int, seed: int = 0,
            start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield make_batch(cfg, B, S, step, seed)
        step += 1
