"""Roofline-term extraction from compiled dry-run artifacts.

    compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory term     = HLO_bytes_per_device / HBM_bandwidth
    collective term = collective_bytes_per_device / ICI_link_bandwidth

``cost_analysis()`` on a 512-way SPMD executable reports *per-device*
flops/bytes (verified against a hand-computed matmul).  Collective bytes
are NOT in cost_analysis: we parse the post-SPMD HLO text and sum the
result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction (per-device traffic).

Hardware constants (TPU v5e, per assignment):
  197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    """Sum per-device result bytes of every collective op in the HLO."""
    per_kind: Dict[str, int] = {}
    seen_done = set()
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        line = hlo_text[m.start():hlo_text.find("\n", m.start())]
        if "-done(" in line:
            continue  # started ops counted once at -start
        b = _shape_bytes(shape_str)
        per_kind[kind] = per_kind.get(kind, 0) + b
    return sum(per_kind.values()), per_kind


@dataclasses.dataclass
class Roofline:
    flops: float              # per device
    bytes_hbm: float          # per device
    bytes_coll: float         # per device
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float = 0.0  # global 6ND / 2ND
    useful_ratio: float = 0.0

    def as_dict(self):
        return dataclasses.asdict(self)


def analyze(compiled, n_devices: int, model_flops: float = 0.0) -> Roofline:
    """Roofline terms via the trip-count-aware HLO analyzer.

    ``cost_analysis()`` counts while bodies once (verified — a 10-iter scan
    reports 1x the per-iteration flops), so scanned models would be under-
    counted by ~n_layers x; launch.hlo_costs multiplies loop bodies by
    their static trip counts instead.
    """
    from repro.launch import hlo_costs

    hlo = compiled.as_text()
    costs = hlo_costs.analyze_hlo(hlo)
    flops = float(costs.flops)
    bytes_hbm = float(costs.bytes)
    per_kind = {k: float(v) for k, v in costs.coll.items()}
    bc = sum(per_kind.values())
    t_c = flops / PEAK_FLOPS
    t_m = bytes_hbm / HBM_BW
    t_l = bc / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_l}
    bottleneck = max(terms, key=terms.get)
    useful = (model_flops / (flops * n_devices)) if flops else 0.0
    r = Roofline(flops=flops, bytes_hbm=bytes_hbm, bytes_coll=float(bc),
                 t_compute=t_c, t_memory=t_m, t_collective=t_l,
                 bottleneck=bottleneck, model_flops=model_flops,
                 useful_ratio=useful)
    r.per_kind = per_kind  # type: ignore[attr-defined]
    r.dynamic_whiles = costs.dynamic_whiles  # type: ignore[attr-defined]
    return r


# ---------------------------------------------------------------------------
# Solver hot-loop byte/flop models (ELL spmv + multilevel V-cycle)
# ---------------------------------------------------------------------------

def ell_spmv_bytes(n: int, ell_width: int, k: int,
                   dtype_bytes: int = 4, idx_bytes: int = 4) -> int:
    """Minimum HBM traffic of one batched ELL spmv ``y[n,k] = A @ x[n,k]``.

    Streaming model: the idx/val slabs are read once, every nonzero gathers
    a k-wide row of x (gathers don't coalesce across rows, so x counts per
    reference, not per unique row), and y is written once.  This is the
    roofline floor — perfect caching of x would reduce the gather term to
    ``n*k``, so achieved/model ratios above 1 indicate cache reuse, not
    measurement error."""
    slab = n * ell_width * (idx_bytes + dtype_bytes)
    gather = n * ell_width * k * dtype_bytes
    out = n * k * dtype_bytes
    return slab + gather + out


def ell_spmv_flops(n: int, ell_width: int, k: int) -> int:
    """2 flops (mul+add) per stored entry per RHS column."""
    return 2 * n * ell_width * k


def vcycle_bytes(level_shapes, k: int, cheby_degree: int = 3,
                 dtype_bytes: int = 4) -> int:
    """HBM traffic of one V-cycle over ``level_shapes = [(n, ell_width)]``.

    Per fine level, down + up sweep each run one Chebyshev smoother
    (``cheby_degree`` spmvs) and the down sweep adds one residual spmv:
    ``2*degree + 1`` spmvs per level per cycle, plus the restriction /
    prolongation scatter-gathers (one k-wide read + write of the level).
    The coarsest dense triangular solve is excluded (it is
    compute-shaped, not stream-shaped, and tiny by construction)."""
    total = 0
    for n, width in level_shapes:
        total += (2 * cheby_degree + 1) * ell_spmv_bytes(
            n, width, k, dtype_bytes=dtype_bytes)
        total += 2 * 2 * n * k * dtype_bytes   # restrict + prolong r/w
    return total


def hierarchy_level_shapes(hierarchy) -> list:
    """[(n, ell_width)] of each fine level — feed to :func:`vcycle_bytes`."""
    return [(int(lev.n), int(lev.idx.shape[1]))
            for lev in hierarchy.levels]


def fused_smoother_bytes(n: int, ell_width: int, k: int,
                         cheby_degree: int = 3, with_guess: bool = False,
                         dtype_bytes: int = 4, idx_bytes: int = 4) -> int:
    """HBM traffic of ONE fused Chebyshev sweep
    (:func:`repro.kernels.vcycle_fused.make_fused_chebyshev`).

    The whole degree-``cheby_degree`` polynomial runs inside a single
    kernel with the slabs, diagonal and vectors VMEM resident: idx/val
    cross HBM once per sweep — the traffic is *degree independent*, which
    is exactly the fusion win over ``cheby_degree`` separate spmv streams.
    Reads: slab + diag + r (+ the initial iterate on post-smooth sweeps);
    writes: the smoothed z."""
    del cheby_degree  # documents the degree independence
    slab = n * ell_width * (idx_bytes + dtype_bytes)
    vecs = (2 + (1 if with_guess else 0)) * n * k * dtype_bytes  # r, z_out(, z_in)
    diag = n * dtype_bytes
    return slab + vecs + diag


def fused_restrict_residual_bytes(n: int, ell_width: int, k: int,
                                  n_coarse: int, dtype_bytes: int = 4,
                                  idx_bytes: int = 4) -> int:
    """HBM traffic of one fused restrict+residual pass
    (:func:`repro.kernels.vcycle_fused.make_fused_restrict_residual`):
    ``rc = segment_sum(r - L z, agg)`` in one kernel.  Reads slab + agg +
    r + z; writes only the ``[n_coarse, k]`` coarse residual — the fine
    residual never round-trips through HBM."""
    slab = n * ell_width * (idx_bytes + dtype_bytes)
    vecs = 2 * n * k * dtype_bytes              # r, z
    agg = n * idx_bytes
    out = n_coarse * k * dtype_bytes
    return slab + vecs + agg + out


def vcycle_bytes_fused(level_triples, k: int, cheby_degree: int = 3,
                       dtype_bytes: int = 4) -> int:
    """HBM traffic of one *fused* V-cycle over
    ``level_triples = [(n, ell_width, n_coarse)]``.

    Per fine level: one fused pre-smooth sweep, one fused
    restrict+residual pass, the prolongation gather-add (read coarse z +
    fine z, write fine z), and one fused post-smooth sweep (which also
    reads the prolonged iterate).  The slabs cross HBM three times per
    level per cycle instead of ``2*cheby_degree + 1`` — compare
    :func:`vcycle_bytes` with identical ``level_shapes``/``k`` for the
    modeled saving."""
    total = 0
    for n, width, nc in level_triples:
        total += fused_smoother_bytes(n, width, k, cheby_degree,
                                      with_guess=False,
                                      dtype_bytes=dtype_bytes)
        total += fused_restrict_residual_bytes(n, width, k, nc,
                                               dtype_bytes=dtype_bytes)
        total += (nc * k + 2 * n * k) * dtype_bytes    # prolong gather-add
        total += fused_smoother_bytes(n, width, k, cheby_degree,
                                      with_guess=True,
                                      dtype_bytes=dtype_bytes)
    return total


def hierarchy_level_triples(hierarchy) -> list:
    """[(n, ell_width, n_coarse)] of each fine level — feed to
    :func:`vcycle_bytes_fused`."""
    return [(int(lev.n), int(lev.idx.shape[1]), int(lev.n_coarse))
            for lev in hierarchy.levels]


def achieved_bandwidth(bytes_moved: float, seconds: float) -> dict:
    """Achieved bytes/s for a measured span + fraction of the HBM roof."""
    if seconds <= 0:
        return {"bytes_per_s": 0.0, "frac_of_hbm": 0.0}
    bps = bytes_moved / seconds
    return {"bytes_per_s": bps, "frac_of_hbm": bps / HBM_BW}


def model_flops_estimate(params_tree, cfg, shape) -> float:
    """6*N*D (train) / 2*N*D (inference); N = *active* params for MoE."""
    import jax
    import numpy as np

    total = 0
    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_tree)[0]:
        n = int(np.prod(leaf.shape))
        names = [getattr(k, "key", str(k)) for k in path]
        total += n
        if "moe" in names and names[-1] in ("w1", "w2", "w3"):
            expert += n
    n_active = total
    if cfg.n_experts:
        n_active = total - expert + expert * cfg.top_k // cfg.n_experts
    # embedding gather isn't matmul flops; subtract the embed table
    n_active -= cfg.d_model * (int(np.ceil(cfg.vocab / 512)) * 512)
    if not cfg.tie_embeddings:
        pass  # lm_head stays: the logits matmul is real compute
    tokens = shape.batch * (shape.seq if shape.kind in ("train", "prefill")
                            else 1)
    mult = 6 if shape.kind == "train" else 2
    return float(mult * n_active * tokens)
