"""Assigned input shapes x applicability + ShapeDtypeStruct builders.

LM transformer shapes are seq_len x global_batch.  ``decode_*``/``long_*``
lower ``serve_step`` (one new token against a seq_len-deep KV cache), NOT
``train_step``.  ``long_500k`` requires sub-quadratic attention and is
skipped (with a reason) for pure full-attention architectures —
DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as model_mod
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# decoder-side cross-attention source length used for enc-dec decode cells
ENCDEC_DECODE_SRC = 4096


def applicability(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """None if the (arch, shape) cell runs; else the skip reason."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return ("full quadratic attention (no SWA/SSM path) — 500k decode "
                "excluded per assignment; see DESIGN.md")
    return None


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.batch, shape.seq
    if shape.kind in ("train", "prefill"):
        S_txt = S - (cfg.frontend_len if cfg.frontend and not cfg.enc_layers
                     else 0)
        batch = {
            "tokens": sds((B, S_txt), jnp.int32),
            "labels": sds((B, S_txt), jnp.int32),
        }
        if cfg.frontend and cfg.enc_layers == 0:
            batch["frontend"] = sds((B, cfg.frontend_len, cfg.frontend_dim),
                                    jnp.float32)
        if cfg.enc_layers:
            batch["src"] = sds((B, S, cfg.frontend_dim or cfg.d_model),
                               jnp.float32)
        if shape.kind == "prefill":
            del batch["labels"]
        return batch
    # decode: one token + caches
    src_len = ENCDEC_DECODE_SRC if cfg.enc_layers else 0
    caches = jax.eval_shape(
        lambda: model_mod.init_cache(cfg, B, S, src_len=src_len))
    return {
        "token": sds((B, 1), jnp.int32),
        "pos": sds((), jnp.int32),
        "caches": caches,
    }


def make_step_fn(cfg: ModelConfig, shape: ShapeSpec, tcfg=None):
    """The function each cell lowers: train_step / prefill_step / serve_step."""
    if shape.kind == "train":
        from repro.train.trainer import TrainConfig, make_train_step

        tcfg = tcfg or TrainConfig()
        inner = make_train_step(cfg, tcfg, mesh="explicit")

        def train_step(params, opt_state, ef, batch):
            return inner(params, opt_state, ef, batch)

        return train_step
    if shape.kind == "prefill":
        def prefill_step(params, batch):
            hidden, _, _, caches = model_mod.forward_hidden(
                params, cfg, batch, remat=False, collect_kv=True)
            W = (params["embed"].T if cfg.tie_embeddings
                 else params["lm_head"]).astype(hidden.dtype)
            logits = (hidden[:, -1, :] @ W).astype(jnp.float32)
            return logits, caches

        return prefill_step

    def serve_step(params, caches, token, pos):
        return model_mod.decode_step(params, cfg, caches, token, pos)

    return serve_step
