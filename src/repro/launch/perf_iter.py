import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Must precede all other imports (jax locks device count at first init).

"""§Perf hillclimb driver: re-lower a cell with a config variant and print
baseline-vs-variant roofline terms side by side.

  PYTHONPATH=src python -m repro.launch.perf_iter \
      --arch arctic-480b --shape prefill_32k --set moe_impl=gather
"""
import argparse
import json

from repro.launch.dryrun import run_cell
from repro.launch.mesh import make_production_mesh


def parse_overrides(pairs):
    out = {}
    for kv in pairs or []:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("True", "true"):
            v = True
        if v in ("False", "false"):
            v = False
        out[k] = v
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", nargs="*", default=[])
    ap.add_argument("--skip-baseline", action="store_true")
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi)
    mesh_name = "2x16x16" if args.multi else "16x16"
    rows = []
    if not args.skip_baseline:
        rows.append(("baseline", run_cell(args.arch, args.shape, mesh,
                                          mesh_name)))
    ov = parse_overrides(args.set)
    rows.append((str(ov), run_cell(args.arch, args.shape, mesh, mesh_name,
                                   cfg_overrides=ov)))
    print(f"\n{'variant':40s} {'tc':>10s} {'tm':>10s} {'tl':>10s} "
          f"{'bottleneck':>11s} {'useful':>7s} {'mem GB':>7s}")
    for name, r in rows:
        print(f"{name:40s} {r['t_compute']:10.3e} {r['t_memory']:10.3e} "
              f"{r['t_collective']:10.3e} {r['bottleneck']:>11s} "
              f"{r['useful_ratio']:7.3f} {r['arg_gb']+r['temp_gb']:7.1f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump([dict(variant=n, **r) for n, r in rows], f, indent=1)


if __name__ == "__main__":
    main()
