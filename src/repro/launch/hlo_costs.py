"""Trip-count-aware cost accounting over post-SPMD HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE — for
scan-over-layers models that under-reports FLOPs/bytes by ~n_layers x
(verified experimentally; see EXPERIMENTS.md §Dry-run).  This module
re-derives per-device costs from the HLO text itself:

  * parses every computation (brace-matched), builds per-computation
    symbol tables (op name -> shape),
  * dot/convolution FLOPs from shapes + contracting dims,
  * elementwise/reduce FLOPs ~ output elements (coarse, documented),
  * bytes accessed = operands + results of top-level ops (fusion
    internals excluded — matches XLA's fusion-boundary accounting),
  * collective payload bytes by kind (max of operand/result),
  * ``while`` ops multiply their body+condition cost by the trip count
    recovered from the condition's ``compare(..., constant(N))``;
    dynamic whiles fall back to trip=1 and set ``dynamic_whiles``.

Everything is per-device: the input is the SPMD-partitioned module.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$")
_PARAM_RE = re.compile(r"%?([\w.\-]+):\s*((?:\([^)]*\))|[^,)]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "tanh", "exponential", "log", "rsqrt", "sqrt", "negate", "abs", "floor",
    "ceil", "sign", "logistic", "cosine", "sine", "select", "compare",
    "and", "or", "not", "xor", "clamp", "round-nearest-even", "atan2",
    "expm1", "log1p", "cbrt", "erf",
}


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    elems = 0
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    result_type: str
    args_str: str       # raw text after '(' (operands + attrs)
    operands: List[str]


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)
    dynamic_whiles: int = 0

    def __iadd__(self, o: "Costs"):
        self.flops += o.flops
        self.bytes += o.bytes
        for k, v in o.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v
        self.dynamic_whiles += o.dynamic_whiles
        return self

    def scaled(self, k: float) -> "Costs":
        return Costs(self.flops * k, self.bytes * k,
                     {a: b * k for a, b in self.coll.items()},
                     self.dynamic_whiles)


def _split_operands(args: str) -> Tuple[List[str], str]:
    """Split '(%a, %b), attr=1, ...' -> (['%a','%b'], 'attr=1, ...')."""
    depth = 0
    out, cur = [], []
    rest = ""
    for i, ch in enumerate(args):
        if ch in "([{":
            depth += 1
            cur.append(ch)
        elif ch in ")]}":
            if depth == 0:
                out.append("".join(cur).strip())
                rest = args[i + 1:]
                break
            depth -= 1
            cur.append(ch)
        elif ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    return [o for o in out if o], rest


def parse_module(text: str):
    """-> computations: name -> (ops, symbol_table name->type_str)."""
    comps: Dict[str, Tuple[List[Op], Dict[str, str]]] = {}
    cur_name = None
    ops: List[Op] = []
    syms: Dict[str, str] = {}
    for line in text.splitlines():
        if cur_name is None:
            m = _COMP_HDR.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur_name = m.group(2)
                ops, syms = [], {}
                for pname, ptype in _PARAM_RE.findall(m.group(3)):
                    syms[pname] = ptype
            continue
        if line.strip() == "}":
            comps[cur_name] = (ops, syms)
            cur_name = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rtype, kind, args = m.groups()
        operands, _ = _split_operands(args)
        # Newer XLA prints bare operand names (`dot(%a, %b)`); older XLA
        # prints the type inline (`dot(f32[128,256]{1,0} %a, ...)`).  Accept
        # both, and harvest inline types into the symbol table.
        opnames = []
        for o in operands:
            mo = re.match(
                r"^(?:((?:\w+\[[\d,]*\])(?:\{[\d,]*\})?)\s+)?%?([\w.\-]+)$", o)
            if mo:
                opnames.append(mo.group(2))
                if mo.group(1):
                    syms.setdefault(mo.group(2), mo.group(1))
        syms[name] = rtype
        ops.append(Op(name=name, kind=kind, result_type=rtype,
                      args_str=args, operands=opnames))
    return comps


def _trip_count(cond_comp: str, comps) -> Optional[int]:
    """Recover a static trip count from the loop condition computation."""
    if cond_comp not in comps:
        return None
    ops, _ = comps[cond_comp]
    const = None
    direction = None
    stack = [cond_comp]
    seen = set()
    while stack:
        c = stack.pop()
        if c in seen or c not in comps:
            continue
        seen.add(c)
        for op in comps[c][0]:
            if op.kind == "constant":
                m = re.search(r"constant\((\d+)\)", "constant(" + op.args_str)
                if m:
                    const = int(m.group(1))
            if op.kind == "compare":
                m = re.search(r"direction=(\w+)", op.args_str)
                if m:
                    direction = m.group(1)
            if op.kind == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", op.args_str)
                if m:
                    stack.append(m.group(1))
    if const is None or const <= 0:
        return None        # dynamic loop (e.g. `psum(open) > 0` conditions)
    if direction == "LE":
        return const + 1
    if direction == "LT":
        return const
    return None            # GT/GE/NE bounds are not scan trip counts


def _dot_flops(op: Op, syms) -> float:
    _, rbytes = _shape_elems_bytes(op.result_type)
    relems, _ = _shape_elems_bytes(op.result_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.args_str)
    if not m or not op.operands:
        return 2.0 * relems
    lhs_type = syms.get(op.operands[0], "")
    shapes = _SHAPE_RE.findall(lhs_type)
    if not shapes:
        return 2.0 * relems
    dims = [int(d) for d in shapes[0][1].split(",") if d]
    k = 1
    for ci in m.group(1).split(","):
        if ci and int(ci) < len(dims):
            k *= dims[int(ci)]
    return 2.0 * relems * k


def _op_cost(op: Op, syms, comps, memo) -> Costs:
    c = Costs()
    kind = op.kind
    relems, rbytes = _shape_elems_bytes(op.result_type)
    if kind in ("parameter", "constant", "get-tuple-element", "tuple",
                "bitcast", "after-all", "iota"):
        return c
    # bytes: operands + result (fusion boundary accounting).  Slicing ops
    # only touch the slice, not the whole operand; in-place updates touch
    # the update twice (read-modify-write), not the full buffer.
    if kind in ("dynamic-slice", "gather", "slice"):
        c.bytes = 2.0 * rbytes
        return c
    if kind in ("dynamic-update-slice", "scatter"):
        upd = 0
        for o in op.operands[1:]:
            t = syms.get(o)
            if t:
                upd = max(upd, _shape_elems_bytes(t)[1])
        c.bytes = 2.0 * upd + 8
        return c
    ob = 0
    for o in op.operands:
        t = syms.get(o)
        if t:
            ob += _shape_elems_bytes(t)[1]
    c.bytes = ob + rbytes

    if kind in COLLECTIVES or any(kind.startswith(k + "-") or kind == k
                                  for k in COLLECTIVES):
        base = next(k for k in COLLECTIVES if kind.startswith(k))
        if kind.endswith("-done"):
            c.bytes = 0
            return c
        payload = max(rbytes, ob)
        c.coll[base] = float(payload)
        return c

    if kind == "dot":
        c.flops = _dot_flops(op, syms)
    elif kind in ELEMENTWISE:
        c.flops = float(relems)
    elif kind in ("reduce", "reduce-window"):
        c.flops = float(ob // 4 if ob else relems)
    elif kind == "fusion":
        m = re.search(r"calls=%?([\w.\-]+)", op.args_str)
        if m:
            inner = _comp_cost(m.group(1), comps, memo)
            c.flops = inner.flops
            for k, v in inner.coll.items():
                c.coll[k] = c.coll.get(k, 0.0) + v
            c.dynamic_whiles += inner.dynamic_whiles
            # bytes stay at the fusion boundary
    elif kind == "while":
        mb = re.search(r"body=%?([\w.\-]+)", op.args_str)
        mc = re.search(r"condition=%?([\w.\-]+)", op.args_str)
        if mb:
            trip = _trip_count(mc.group(1), comps) if mc else None
            dyn = 0
            if trip is None:
                trip, dyn = 1, 1
            body = _comp_cost(mb.group(1), comps, memo).scaled(trip)
            cond = (_comp_cost(mc.group(1), comps, memo).scaled(trip)
                    if mc else Costs())
            body += cond
            body.dynamic_whiles += dyn
            body.bytes += 0  # loop-carried buffers counted inside body ops
            c.flops = body.flops
            c.bytes = body.bytes
            c.coll = body.coll
            c.dynamic_whiles += body.dynamic_whiles
    elif kind in ("call", "custom-call", "conditional", "async-start"):
        for m in re.finditer(r"(?:calls|to_apply|branch_computations)="
                             r"\{?%?([\w.\-,% ]+)\}?", op.args_str):
            for cname in re.split(r"[,\s]+", m.group(1)):
                cname = cname.lstrip("%")
                if cname in comps:
                    inner = _comp_cost(cname, comps, memo)
                    c.flops += inner.flops
                    for k, v in inner.coll.items():
                        c.coll[k] = c.coll.get(k, 0.0) + v
    return c


def _comp_cost(name: str, comps, memo) -> Costs:
    if name in memo:
        return memo[name]
    memo[name] = Costs()  # cycle guard
    if name not in comps:
        return memo[name]
    ops, syms = comps[name]
    total = Costs()
    for op in ops:
        total += _op_cost(op, syms, comps, memo)
    memo[name] = total
    return total


def analyze_hlo(text: str, entry: Optional[str] = None) -> Costs:
    comps = parse_module(text)
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
        entry = m.group(1) if m else next(iter(comps))
    return _comp_cost(entry, comps, {})
