import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the device
# count at first initialization, and the production meshes need 512 hosts.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (GSPMD partitions every op),
  * the per-device memory footprint (compiled.memory_analysis()),
  * the FLOP/byte/collective volumes feeding §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh both --out experiments/
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x22b \
      --shape decode_32k --mesh single
"""
import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.dist import sharding as shard_mod
from repro.launch import roofline as roof_mod
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import (SHAPES, ENCDEC_DECODE_SRC, applicability,
                                 input_specs, make_step_fn)
from repro.models import model as model_mod
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.trainer import TrainConfig


def _dp(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _dp_size(mesh):
    return int(np.prod([mesh.shape[a] for a in _dp(mesh)]))


def batch_specs(batch_sds, mesh):
    dp = _dp(mesh)
    size = _dp_size(mesh)
    dp_spec = dp if len(dp) > 1 else dp[0]

    def rule(x):
        if x.ndim and x.shape[0] % size == 0 and x.shape[0] >= size:
            return P(dp_spec)
        return P()

    return jax.tree.map(rule, batch_sds)


def cache_specs(caches_sds, mesh, model_axis_ok=True):
    """Sharding for decode caches: batch over DP when divisible, else the
    cache-length dim (sequence sharding for the 500k single-stream cell);
    KV heads / channels over 'model' when divisible."""
    dp = _dp(mesh)
    size = _dp_size(mesh)
    dp_spec = dp if len(dp) > 1 else dp[0]
    tp = mesh.shape.get("model", 1)

    def leaf_rule(path, x):
        name = [getattr(k, "key", str(k)) for k in path][-1]
        B = x.shape[0] if x.ndim else 1
        b_ok = B % size == 0 and B >= size
        if name in ("k", "v", "ek", "ev"):      # [B, C, KV, hd]
            kv_ok = x.shape[2] % tp == 0 and x.shape[2] >= tp
            if b_ok:
                return P(dp_spec, None, "model" if kv_ok else None, None)
            if x.shape[1] % size == 0:
                return P(None, dp_spec, "model" if kv_ok else None, None)
            return P()
        if name == "pos":                        # [B, C]
            if b_ok:
                return P(dp_spec, None)
            if x.shape[1] % size == 0:
                return P(None, dp_spec)
            return P()
        if name == "h":                          # [B, di, state]
            di_ok = x.shape[1] % tp == 0
            return P(dp_spec if b_ok else None,
                     "model" if di_ok else None, None)
        if name == "conv":                       # [B, k-1, di]
            di_ok = x.shape[2] % tp == 0
            return P(dp_spec if b_ok else None, None,
                     "model" if di_ok else None)
        return P()

    return jax.tree_util.tree_map_with_path(leaf_rule, caches_sds)


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             tcfg: Optional[TrainConfig] = None, verbose: bool = True,
             cfg_overrides: Optional[dict] = None):
    import dataclasses

    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    row = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "n_devices": int(np.prod(list(mesh.shape.values())))}
    reason = applicability(cfg, shape)
    if reason:
        row.update(status="skipped", reason=reason)
        return row
    tcfg = tcfg or TrainConfig()

    t0 = time.time()
    params_sds = jax.eval_shape(
        lambda: model_mod.init_params(cfg, jax.random.key(0)))
    p_specs = shard_mod.param_pspecs(params_sds, mesh,
                                     expert_shard=cfg.expert_shard)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                        is_leaf=lambda x: isinstance(x, P))
    step_fn = make_step_fn(cfg, shape, tcfg)
    specs = input_specs(cfg, shape)

    with jax.set_mesh(mesh):
        if shape.kind == "train":
            opt_sds = jax.eval_shape(
                lambda: init_opt_state(params_sds, tcfg.opt))
            ef_sds = jax.eval_shape(lambda: (
                jax.tree.map(lambda p: jax.ShapeDtypeStruct((), jnp.float32),
                             params_sds)
                if not tcfg.compress_grads else
                jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape,
                                                            jnp.bfloat16),
                             params_sds)))
            opt_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                (shard_mod.param_pspecs(params_sds, mesh,
                                        expert_shard=cfg.expert_shard),) * 2,
                is_leaf=lambda x: isinstance(x, P))
            opt_sharding = type(opt_sds)(
                m=opt_sh[0], v=opt_sh[1],
                step=NamedSharding(mesh, P()))
            ef_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                (shard_mod.param_pspecs(params_sds, mesh,
                                        expert_shard=cfg.expert_shard)
                 if tcfg.compress_grads else
                 jax.tree.map(lambda _: P(), params_sds)),
                is_leaf=lambda x: isinstance(x, P))
            b_specs = batch_specs(specs, mesh)
            b_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), b_specs,
                                is_leaf=lambda x: isinstance(x, P))
            jitted = jax.jit(step_fn,
                             in_shardings=(p_sh, opt_sharding, ef_sh, b_sh))
            lowered = jitted.lower(params_sds, opt_sds, ef_sds, specs)
        elif shape.kind == "prefill":
            b_specs = batch_specs(specs, mesh)
            b_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), b_specs,
                                is_leaf=lambda x: isinstance(x, P))
            jitted = jax.jit(step_fn, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(params_sds, specs)
        else:  # decode
            c_specs = cache_specs(specs["caches"], mesh)
            c_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs,
                                is_leaf=lambda x: isinstance(x, P))
            t_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                batch_specs({"token": specs["token"]}, mesh),
                is_leaf=lambda x: isinstance(x, P))["token"]
            jitted = jax.jit(step_fn,
                             in_shardings=(p_sh, c_sh, t_sh,
                                           NamedSharding(mesh, P())))
            lowered = jitted.lower(params_sds, specs["caches"],
                                   specs["token"], specs["pos"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    n_dev = row["n_devices"]
    mf = roof_mod.model_flops_estimate(params_sds, cfg, shape)
    roof = roof_mod.analyze(compiled, n_dev, model_flops=mf)
    row.update(
        status="ok",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        arg_gb=round(mem.argument_size_in_bytes / 2**30, 3),
        temp_gb=round(mem.temp_size_in_bytes / 2**30, 3),
        out_gb=round(mem.output_size_in_bytes / 2**30, 3),
        flops_per_dev=roof.flops,
        hbm_bytes_per_dev=roof.bytes_hbm,
        coll_bytes_per_dev=roof.bytes_coll,
        coll_by_kind=getattr(roof, "per_kind", {}),
        t_compute=roof.t_compute,
        t_memory=roof.t_memory,
        t_collective=roof.t_collective,
        bottleneck=roof.bottleneck,
        model_flops=mf,
        useful_ratio=round(roof.useful_ratio, 4),
    )
    if verbose:
        print(f"[{mesh_name}] {arch} x {shape_name}: OK "
              f"compile={t_compile:.1f}s args={row['arg_gb']}GB "
              f"temp={row['temp_gb']}GB bottleneck={roof.bottleneck} "
              f"tc={roof.t_compute:.3e}s tm={roof.t_memory:.3e}s "
              f"tl={roof.t_collective:.3e}s useful={row['useful_ratio']}",
              flush=True)
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments")
    ap.add_argument("--compress", action="store_true",
                    help="int8+EF gradient compression in train cells")
    args = ap.parse_args()

    archs = ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    tcfg = TrainConfig(compress_grads=args.compress)
    all_rows = []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mesh_name = "2x16x16" if multi else "16x16"
        for arch in archs:
            for shape in shapes:
                try:
                    row = run_cell(arch, shape, mesh, mesh_name, tcfg)
                except Exception as e:  # a failing cell is a bug — record it
                    row = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "FAILED", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                    print(f"[{mesh_name}] {arch} x {shape}: FAILED {e}",
                          flush=True)
                all_rows.append(row)
                tag = f"{args.arch}_{args.shape}_{args.mesh}".replace("/", "_")
                with open(os.path.join(args.out, f"dryrun_{tag}.json"),
                          "w") as f:
                    json.dump(all_rows, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in all_rows)
    n_skip = sum(r["status"] == "skipped" for r in all_rows)
    n_fail = sum(r["status"] == "FAILED" for r in all_rows)
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
