"""Production meshes.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before the
first jax initialization.

Topology (TPU v5e): 16x16 = 256 chips per pod; multi-pod adds a leading
'pod' axis over the DCN (2 pods = 512 chips).
  * 'model' — tensor/expert parallel (intra-pod ICI ring).
  * 'data'  — data parallel + FSDP (intra-pod).
  * 'pod'   — data parallel + FSDP across pods (DCN; gradient compression
              applies here — see dist.compress).
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions: the ``axis_types`` kwarg (and
    ``jax.sharding.AxisType``) only exist on newer jax; older versions
    default every axis to auto sharding, which is what we want anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_mesh_for(n_devices: int, model_par: int = None):
    """Elastic helper: best (data, model) mesh for whatever devices exist."""
    if model_par is None:
        model_par = min(16, n_devices)
    while n_devices % model_par:
        model_par //= 2
    return compat_make_mesh((n_devices // model_par, model_par),
                            ("data", "model"))
