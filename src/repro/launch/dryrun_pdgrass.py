import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Must precede all other imports.

"""Dry-run for the paper's own workload: the distributed strict-similarity
recovery step sharded across the full production mesh.

The off-tree edge array (ancestor signatures + beta + subtask ids) is
sharded over ALL mesh axes flattened; each round does one all_gather of
candidate rows + a psum for termination (see core.distributed).

  PYTHONPATH=src python -m repro.launch.dryrun_pdgrass --mesh both
"""
import argparse
import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.pdgrass_graph import CONFIG
from repro.core.distributed import _inner_round_engine
from repro.launch import roofline as roof_mod
from repro.launch.mesh import make_production_mesh


def run(multi_pod: bool, cfg=CONFIG):
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = tuple(mesh.shape.keys())
    n_dev = int(np.prod(list(mesh.shape.values())))
    mesh_name = "x".join(str(mesh.shape[a]) for a in axes)

    m = cfg.m_offtree
    c1 = cfg.c + 1
    sds = jax.ShapeDtypeStruct
    args = dict(
        sig_u=sds((m, c1), jnp.int32),
        sig_v=sds((m, c1), jnp.int32),
        beta=sds((m,), jnp.int32),
        seg=sds((m,), jnp.int32),
    )
    shardings = {
        "sig_u": NamedSharding(mesh, P(axes, None)),
        "sig_v": NamedSharding(mesh, P(axes, None)),
        "beta": NamedSharding(mesh, P(axes)),
        "seg": NamedSharding(mesh, P(axes)),
    }

    fn = jax.shard_map(
        functools.partial(_inner_round_engine, axis=axes,
                          block_size=cfg.block_size, chunk=cfg.chunk),
        mesh=mesh,
        in_specs=(P(axes, None), P(axes, None), P(axes), P(axes)),
        out_specs=(P(axes), P()),
    )
    t0 = time.time()
    with jax.set_mesh(mesh):
        lowered = jax.jit(fn, in_shardings=(
            shardings["sig_u"], shardings["sig_v"], shardings["beta"],
            shardings["seg"],
        )).lower(args["sig_u"], args["sig_v"], args["beta"], args["seg"])
        compiled = lowered.compile()
    dt = time.time() - t0
    mem = compiled.memory_analysis()
    roof = roof_mod.analyze(compiled, n_dev)
    row = dict(
        arch="pdgrass-graph", shape=f"recover_m{m}", mesh=mesh_name,
        status="ok", compile_s=round(dt, 2),
        arg_gb=round(mem.argument_size_in_bytes / 2**30, 3),
        temp_gb=round(mem.temp_size_in_bytes / 2**30, 3),
        flops_per_dev=roof.flops, hbm_bytes_per_dev=roof.bytes_hbm,
        coll_bytes_per_dev=roof.bytes_coll,
        coll_by_kind=getattr(roof, "per_kind", {}),
        t_compute=roof.t_compute, t_memory=roof.t_memory,
        t_collective=roof.t_collective, bottleneck=roof.bottleneck,
        dynamic_whiles=getattr(roof, "dynamic_whiles", 0),
    )
    print(f"[{mesh_name}] pdgrass recover_step: OK compile={dt:.1f}s "
          f"args={row['arg_gb']}GB temp={row['temp_gb']}GB "
          f"tc={roof.t_compute:.3e} tm={roof.t_memory:.3e} "
          f"tl={roof.t_collective:.3e} (per round; loop trip dynamic)",
          flush=True)
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments")
    args = ap.parse_args()
    rows = []
    for multi in {"single": [False], "multi": [True],
                  "both": [False, True]}[args.mesh]:
        rows.append(run(multi))
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, "dryrun_pdgrass.json"), "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
