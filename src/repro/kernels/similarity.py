"""Pallas TPU kernel: strict-similarity marking pass (pdGRASS step 4 hot spot).

The quadratic term in the paper's work bound is the pairwise similarity
check inside each subtask.  With the ancestor-signature reduction (see
``repro.core.lifting``), checking whether recovered edge k marks edge j is

    sim(k, j) = (u_j in S_{u_k, beta_k}  and  v_j in S_{v_k, beta_k})
             or (u_j in S_{v_k, beta_k}  and  v_j in S_{u_k, beta_k})

where membership is ``exists a+b <= beta_k: sig_x[k, a] == sig_y[j, b]`` —
a fixed (c+1)^2 grid of int32 equality tests.  No gathers, no BFS: the
whole pass is data-independent dense VPU work, which is exactly what the
MXU-adjacent vector units want.

Tiling: the K candidate rows (K <= 128, with their 9-entry signatures)
stay resident in VMEM across the whole grid; edges stream through in
``tile_m``-row slabs.  The (a, b) loop is unrolled at trace time and pairs
with a+b > c are statically skipped (45 of 81 survive for c = 8).

Block layout per grid step (c1 = 9, int32):
    candidates:  4 x [K, c1]   ~ 18 KB   (replicated across grid)
    edge slab:   2 x [tile_m, c1] + [tile_m]   ~ 9.4 KB per 128 rows
    accumulators: 4 x [K, tile_m] bool
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sim_kernel(csu_ref, csv_ref, cbeta_ref, cseg_ref,
                esu_ref, esv_ref, eseg_ref, out_ref, *, c1: int):
    csu = csu_ref[...]          # [K, c1]
    csv = csv_ref[...]
    cbeta = cbeta_ref[...]      # [K]
    cseg = cseg_ref[...]        # [K]
    esu = esu_ref[...]          # [Tm, c1]
    esv = esv_ref[...]
    eseg = eseg_ref[...]        # [Tm]

    K = csu.shape[0]
    Tm = esu.shape[0]
    cmax = c1 - 1

    acc_uu = jnp.zeros((K, Tm), dtype=jnp.bool_)
    acc_vv = jnp.zeros((K, Tm), dtype=jnp.bool_)
    acc_uv = jnp.zeros((K, Tm), dtype=jnp.bool_)
    acc_vu = jnp.zeros((K, Tm), dtype=jnp.bool_)
    for a in range(c1):
        for b in range(c1):
            if a + b > cmax:
                continue  # static skip: beta <= c always
            ok = ((a + b) <= cbeta)[:, None]          # [K, 1]
            cu_a = csu[:, a][:, None]                 # [K, 1]
            cv_a = csv[:, a][:, None]
            eu_b = esu[:, b][None, :]                 # [1, Tm]
            ev_b = esv[:, b][None, :]
            acc_uu |= ok & (cu_a == eu_b)
            acc_vv |= ok & (cv_a == ev_b)
            acc_uv |= ok & (cu_a == ev_b)
            acc_vu |= ok & (cv_a == eu_b)
    sim = (acc_uu & acc_vv) | (acc_uv & acc_vu)
    sim &= cseg[:, None] == eseg[None, :]
    out_ref[...] = jnp.any(sim, axis=0)


@functools.partial(jax.jit,
                   static_argnames=("tile_m", "interpret"))
def similarity_mark(csu, csv, cbeta, cseg, esu, esv, eseg,
                    *, tile_m: int = 512, interpret: bool = True):
    """kill[j] = any recovered candidate k (same subtask) marks edge j.

    Args:
      csu/csv:   [K, c1] int32 candidate signatures (beta < 0 disables row).
      cbeta:     [K] int32.
      cseg:      [K] int32 subtask ids (use -2 for invalid rows).
      esu/esv:   [m, c1] int32 edge slab signatures; m % tile_m == 0.
      eseg:      [m] int32 (-1 for padding rows).
    Returns: [m] bool.
    """
    m, c1 = esu.shape
    assert m % tile_m == 0, (m, tile_m)
    grid = (m // tile_m,)
    kern = functools.partial(_sim_kernel, c1=c1)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(csu.shape, lambda i: (0, 0)),   # candidates resident
            pl.BlockSpec(csv.shape, lambda i: (0, 0)),
            pl.BlockSpec(cbeta.shape, lambda i: (0,)),
            pl.BlockSpec(cseg.shape, lambda i: (0,)),
            pl.BlockSpec((tile_m, c1), lambda i: (i, 0)),  # edge slabs stream
            pl.BlockSpec((tile_m, c1), lambda i: (i, 0)),
            pl.BlockSpec((tile_m,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile_m,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.bool_),
        interpret=interpret,
    )(csu, csv, cbeta, cseg, esu, esv, eseg)
