"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only; on a
real TPU deployment set ``REPRO_KERNEL_INTERPRET=0`` (or pass
``interpret=False``) and the same pallas_call lowers through Mosaic.
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.similarity import similarity_mark as _similarity_mark
from repro.kernels.spmv_ell import spmv_ell as _spmv_ell, to_ell  # noqa: F401

_INTERPRET = os.environ.get("REPRO_KERNEL_INTERPRET", "1") != "0"


def similarity_mark(csu, csv, cbeta, cseg, esu, esv, eseg,
                    tile_m: int = 512, interpret: bool | None = None):
    if interpret is None:
        interpret = _INTERPRET
    m = esu.shape[0]
    if m % tile_m != 0:  # pad to tile multiple with inert rows
        pad = tile_m - m % tile_m
        esu = jnp.pad(esu, ((0, pad), (0, 0)), constant_values=-1)
        esv = jnp.pad(esv, ((0, pad), (0, 0)), constant_values=-1)
        eseg = jnp.pad(eseg, (0, pad), constant_values=-1)
    out = _similarity_mark(csu, csv, cbeta, cseg, esu, esv, eseg,
                           tile_m=tile_m, interpret=interpret)
    return out[:m]


def spmv(idx, val, x, tile_n: int = 256, interpret: bool | None = None):
    if interpret is None:
        interpret = _INTERPRET
    n = idx.shape[0]
    if n % tile_n != 0:
        pad = tile_n - n % tile_n
        idx = jnp.pad(idx, ((0, pad), (0, 0)))
        val = jnp.pad(val, ((0, pad), (0, 0)))
        out = _spmv_ell(idx, val, x, tile_n=tile_n, interpret=interpret)
        return out[:n]
    return _spmv_ell(idx, val, x, tile_n=tile_n, interpret=interpret)


similarity_mark_ref = _ref.similarity_mark_ref
spmv_ref = _ref.spmv_ell_ref
