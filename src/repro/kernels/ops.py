"""jit'd public wrappers around the Pallas kernels.

``interpret=None`` (the default everywhere) resolves automatically via
:func:`resolve_interpret`: an explicit bool wins, else the
``REPRO_KERNEL_INTERPRET`` environment variable (``"0"`` = compiled), else
the kernels compile through Mosaic only when ``jax.default_backend()`` is
TPU and interpret everywhere else (CPU containers, CI).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.similarity import similarity_mark as _similarity_mark
from repro.kernels.spmv_ell import spmv_ell as _spmv_ell, to_ell  # noqa: F401
from repro.kernels.vcycle_fused import (  # noqa: F401
    make_fused_chebyshev, make_fused_restrict_residual, resolve_interpret,
    spmv_ell_batched as _spmv_ell_batched)


def similarity_mark(csu, csv, cbeta, cseg, esu, esv, eseg,
                    tile_m: int = 512, interpret: bool | None = None):
    interpret = resolve_interpret(interpret)
    m = esu.shape[0]
    if m % tile_m != 0:  # pad to tile multiple with inert rows
        pad = tile_m - m % tile_m
        esu = jnp.pad(esu, ((0, pad), (0, 0)), constant_values=-1)
        esv = jnp.pad(esv, ((0, pad), (0, 0)), constant_values=-1)
        eseg = jnp.pad(eseg, (0, pad), constant_values=-1)
    out = _similarity_mark(csu, csv, cbeta, cseg, esu, esv, eseg,
                           tile_m=tile_m, interpret=interpret)
    return out[:m]


def spmv(idx, val, x, tile_n: int = 256, interpret: bool | None = None):
    """Single-column ELL spmv; non-tile-multiple row counts pad inside
    the kernel wrapper."""
    return _spmv_ell(idx, val, x, tile_n=tile_n,
                     interpret=resolve_interpret(interpret))


def spmv_batched(idx, val, x, tile_n: int = 256,
                 interpret: bool | None = None):
    """Batched-RHS ELL spmv: the whole ``[n, k]`` block in one kernel."""
    return _spmv_ell_batched(idx, val, x, tile_n=tile_n,
                             interpret=resolve_interpret(interpret))


similarity_mark_ref = _ref.similarity_mark_ref
spmv_ref = _ref.spmv_ell_ref
