"""Pallas TPU kernels for the compute hot-spots (validated in interpret
mode against the pure-jnp oracles in ref.py; see tests/test_kernels.py):

  similarity.py — strict-similarity marking pass (pdGRASS step 4's
                  quadratic term; candidate signatures VMEM-resident,
                  edge slabs streamed).
  ssm_scan.py   — fused Mamba1 selective scan (the falcon-mamba/hymba
                  memory-roofline fix; §Perf I3).
  spmv_ell.py   — ELLPACK Laplacian SpMV (PCG inner loop).
"""
from repro.kernels import ops  # noqa: F401
