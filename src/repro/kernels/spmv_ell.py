"""Pallas TPU kernel: ELLPACK SpMV for Laplacian matvecs (PCG inner loop).

The PCG application that consumes the sparsifier spends its time in
``y = L x``.  Ultra-sparse graph Laplacians (tree + alpha*|V| off-tree
edges) have bounded row degree after ELL padding, so we store the matrix
as dense [n, L] (column-index, value) slabs — the TPU-native layout:
contiguous, MXU/VPU-aligned, no CSR pointer chasing.

Tiling: rows stream through in ``tile_n`` slabs; the x vector stays fully
VMEM-resident (f32[n]; up to ~2M rows fits comfortably in 16 MB VMEM
alongside the slabs).  The per-slab gather ``x[idx]`` is a VMEM dynamic
gather, supported by Mosaic; the multiply-accumulate over the L (padded
degree) dimension is unrolled.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_kernel(idx_ref, val_ref, x_ref, out_ref):
    idx = idx_ref[...]          # [Tn, L] int32
    val = val_ref[...]          # [Tn, L] f32
    x = x_ref[...]              # [n] f32 (resident)
    acc = jnp.zeros((idx.shape[0],), dtype=val.dtype)
    for l in range(idx.shape[1]):
        acc = acc + val[:, l] * x[idx[:, l]]
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def spmv_ell(idx, val, x, *, tile_n: int = 256, interpret: bool = True):
    """y[i] = sum_l val[i, l] * x[idx[i, l]].  Rows padded with val = 0.

    Row counts that are not a multiple of ``tile_n`` are padded up to the
    tile boundary with zero-valued ELL entries (which gather ``x[0]`` and
    contribute nothing) and sliced back — arbitrary graph sizes never
    crash the kernel."""
    n, L = idx.shape
    pad = (-n) % tile_n
    if pad:
        idx = jnp.pad(idx, ((0, pad), (0, 0)))
        val = jnp.pad(val, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _spmv_kernel,
        grid=((n + pad) // tile_n,),
        in_specs=[
            pl.BlockSpec((tile_n, L), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, L), lambda i: (i, 0)),
            pl.BlockSpec(x.shape, lambda i: (0,)),   # x resident in VMEM
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + pad,), val.dtype),
        interpret=interpret,
    )(idx, val, x)
    return out[:n] if pad else out


def to_ell(graph, dtype=jnp.float32):
    """Host-side: Laplacian of a Graph/edge mask in ELL [n, L] layout.

    Vectorized scatter (no per-vertex python loop) — this runs once per
    hierarchy level at solver-setup time, so it must scale to 1e5+ rows.
    Layout per row v: the -w neighbor entries, then the diagonal (weighted
    degree), then padding slots that gather the row's own x with val = 0.
    """
    import numpy as np

    n = graph.n
    deg = np.diff(graph.indptr).astype(np.int64)
    L = int(deg.max()) + 1 if n else 1  # +1 for the diagonal
    rows = np.repeat(np.arange(n), deg)
    slot = np.arange(deg.sum()) - np.repeat(graph.indptr[:-1], deg)
    idx = np.broadcast_to(np.arange(n, dtype=np.int32)[:, None], (n, L)).copy()
    val = np.zeros((n, L), dtype=np.float64)
    idx[rows, slot] = graph.adj
    val[rows, slot] = -graph.adj_w.astype(np.float64)
    wdeg = np.zeros(n, dtype=np.float64)
    np.add.at(wdeg, rows, graph.adj_w.astype(np.float64))
    val[np.arange(n), deg] = wdeg
    return jnp.asarray(idx), jnp.asarray(val.astype(np.float32))
