"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each kernel's test sweeps shapes/dtypes and asserts allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def similarity_mark_ref(csu, csv, cbeta, cseg, esu, esv, eseg):
    """Reference for kernels.similarity.similarity_mark."""
    c1 = csu.shape[1]
    a = jnp.arange(c1)
    apb = a[:, None] + a[None, :]

    def match(sa, sb):  # [K, c1] x [m, c1] -> [K, m]
        eq = sa[:, None, :, None] == sb[None, :, None, :]
        ok = eq & (apb[None, None] <= cbeta[:, None, None, None])
        return jnp.any(ok, axis=(-1, -2))

    sim = (match(csu, esu) & match(csv, esv)) | (match(csu, esv) & match(csv, esu))
    sim &= cseg[:, None] == eseg[None, :]
    return jnp.any(sim, axis=0)


def spmv_ell_ref(idx, val, x):
    """Reference for kernels.spmv_ell.spmv_ell."""
    return jnp.sum(val * x[idx], axis=1)
