"""Pallas fused V-cycle kernel suite: batched ELL spmv, fused Chebyshev
smoother, fused restrict+residual.

The solve plane is memory-bound: a V-cycle application is a chain of ELL
matvecs, diagonal scalings and axpy combines, and the unfused composition
re-reads the ``[n, L]`` idx/val slabs from HBM for *every* matvec — the
degree-``d`` Chebyshev smoother alone streams them ``d`` times per sweep.
These kernels collapse the chain so each slab crosses HBM once per
logical pass:

  ``spmv_ell_batched``
      ``y[n, k] = A @ x[n, k]`` with the whole ``[n, k]`` RHS block VMEM
      resident — one kernel for a multi-column solve instead of ``k``
      single-column dispatches.
  ``make_fused_chebyshev``
      the entire degree-2/3 Chebyshev polynomial in ``D^-1 L`` (two/three
      matvecs + diagonal scaling + recurrence combines) as ONE
      ``pallas_call``: idx/val/diag/r (and the optional initial iterate)
      are DMA'd HBM->VMEM once, every matvec inside is a VMEM gather.
  ``make_fused_restrict_residual``
      ``rc = restrict(r - L z)`` — the residual matvec and the
      aggregation-tree segment-sum restriction in a single pass over the
      slabs, writing the ``[n_coarse, k]`` coarse residual directly.

Layout contract: the fused smoother / restrict kernels hold the full
level slabs and vectors VMEM-resident (no row tiling) — the recurrence
steps are globally data-dependent, so row tiles cannot stream without
cross-tile synchronization.  A level with ``n * L * 8 + ~3 n k * 4``
bytes over the ~16 MB VMEM budget should use the unfused path; every
hierarchy level this repo builds (ultra-sparse sparsifiers, bounded ELL
width) fits with room to spare.  ``spmv_ell_batched`` row-tiles like the
single-column kernel, with only ``x`` resident.

Numerics contract: kernel bodies are written op-for-op identical to the
unfused jnp composition (the same ``einsum`` contraction, the same
:func:`cheby_recurrence`, the same ``segment_sum``), so under
``interpret=True`` the fused V-cycle is *bit-identical* to the unfused
one and PCG iteration counts match exactly (asserted in
``tests/test_fused_vcycle.py``).

``interpret=None`` everywhere means "resolve automatically" — see
:func:`resolve_interpret`.
"""
from __future__ import annotations

import functools
import os
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Resolve the Pallas ``interpret`` knob.

    Priority: an explicit ``True``/``False`` wins; else the
    ``REPRO_KERNEL_INTERPRET`` environment variable (``"0"`` = compiled,
    anything else = interpret); else auto-select from
    ``jax.default_backend()`` — compiled on TPU (the kernels lower
    through Mosaic), interpret everywhere else (CPU containers, CI).
    """
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get("REPRO_KERNEL_INTERPRET")
    if env is not None:
        return env != "0"
    return jax.default_backend() != "tpu"


def cheby_coeffs(rho: float):
    """Chebyshev smoother coefficients for eigenvalues of ``D^-1 L`` in
    ``[lmax/4, lmax]`` with ``lmax = 1.1 * rho`` (overestimating the
    spectral radius is benign; underestimating can amplify the top mode).
    Returns ``(theta, delta, sigma)`` — the interval midpoint, half-width,
    and their ratio."""
    lmax = 1.1 * rho
    lmin = lmax / 4.0
    theta = 0.5 * (lmax + lmin)
    delta = 0.5 * (lmax - lmin)
    return theta, delta, theta / delta


def cheby_recurrence(matvec: Callable, inv_d, r, z, *, degree: int,
                     theta: float, delta: float, sigma: float):
    """The degree-``degree`` Chebyshev recurrence for ``L z ~= r`` with
    Jacobi scaling — the ONE definition of the polynomial, shared by the
    unfused smoother closure (``device_pcg.make_chebyshev_smoother``) and
    the fused Pallas kernel body, so the two paths are identical by
    construction.  ``z=None`` starts from the zero iterate."""
    res = r if z is None else r - matvec(z)
    p = inv_d * res / theta
    z = p if z is None else z + p
    rho_prev = 1.0 / sigma
    for _ in range(degree - 1):
        res = r - matvec(z)
        rho_k = 1.0 / (2.0 * sigma - rho_prev)
        p = (rho_k * rho_prev) * p + (2.0 * rho_k / delta) * (inv_d * res)
        z = z + p
        rho_prev = rho_k
    return z


def _ell_matvec(idx, val):
    """In-kernel ELL contraction ``x [nx, k] -> [n, k]`` over VMEM-resident
    slabs — the same einsum expression as the jnp reference path."""
    def mv(x):
        return jnp.einsum("nl,nlk->nk", val, x[idx])

    return mv


# ---------------------------------------------------------------------------
# Batched-RHS ELL spmv
# ---------------------------------------------------------------------------

def _spmv_batched_kernel(idx_ref, val_ref, x_ref, out_ref):
    out_ref[...] = _ell_matvec(idx_ref[...], val_ref[...])(x_ref[...])


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def spmv_ell_batched(idx, val, x, *, tile_n: int = 256,
                     interpret: Optional[bool] = None):
    """``y[i, j] = sum_l val[i, l] * x[idx[i, l], j]`` for a ``[nx, k]``
    RHS block in one kernel.

    Rows stream through in ``tile_n`` slabs; the whole ``x`` block stays
    VMEM resident.  ``x`` may have more rows than ``idx`` (the sharded
    plane gathers from ``[n_loc + halo]`` extended vectors).  Rows are
    padded up to the tile multiple with zero-valued ELL entries, so any
    ``n`` is accepted."""
    interpret = resolve_interpret(interpret)
    n, L = idx.shape
    pad = (-n) % tile_n
    if pad:
        # padding rows gather x[0] with val 0 — inert, sliced away below
        idx = jnp.pad(idx, ((0, pad), (0, 0)))
        val = jnp.pad(val, ((0, pad), (0, 0)))
    nx, k = x.shape
    out = pl.pallas_call(
        _spmv_batched_kernel,
        grid=((n + pad) // tile_n,),
        in_specs=[
            pl.BlockSpec((tile_n, L), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, L), lambda i: (i, 0)),
            pl.BlockSpec((nx, k), lambda i: (0, 0)),   # x resident in VMEM
        ],
        out_specs=pl.BlockSpec((tile_n, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pad, k), val.dtype),
        interpret=interpret,
    )(idx, val, x)
    return out[:n] if pad else out


# ---------------------------------------------------------------------------
# Fused Chebyshev smoother
# ---------------------------------------------------------------------------

def make_fused_chebyshev(idx, val, diag, rho: float, *, degree: int = 3,
                         interpret: Optional[bool] = None) -> Callable:
    """Build ``smooth(r, z=None)`` whose whole degree-``degree`` polynomial
    is one ``pallas_call``: the idx/val slabs and the diagonal are read
    from HBM once per sweep instead of once per matvec.  Coefficients are
    baked in at build time from the (host-estimated) spectral radius
    ``rho``, exactly as the unfused closure does."""
    theta, delta, sigma = cheby_coeffs(rho)
    interpret = resolve_interpret(interpret)

    def _kernel(idx_ref, val_ref, diag_ref, r_ref, *rest):
        z_ref = rest[0] if len(rest) == 2 else None
        out_ref = rest[-1]
        mv = _ell_matvec(idx_ref[...], val_ref[...])
        inv_d = (1.0 / diag_ref[...])[:, None]
        z = None if z_ref is None else z_ref[...]
        out_ref[...] = cheby_recurrence(mv, inv_d, r_ref[...], z,
                                        degree=degree, theta=theta,
                                        delta=delta, sigma=sigma)

    def smooth(r, z=None):
        args = (idx, val, diag, r) + (() if z is None else (z,))
        return pl.pallas_call(
            _kernel,
            out_shape=jax.ShapeDtypeStruct(r.shape, r.dtype),
            interpret=interpret,
        )(*args)

    return smooth


# ---------------------------------------------------------------------------
# Fused restrict + residual
# ---------------------------------------------------------------------------

def make_fused_restrict_residual(idx, val, agg, n_coarse: int, *,
                                 interpret: Optional[bool] = None
                                 ) -> Callable:
    """Build ``restrict(r, z) -> rc [n_coarse, k]`` computing
    ``segment_sum(r - L z, agg)`` in a single pass over the slabs: the
    residual matvec's output never round-trips through HBM before the
    aggregation-tree scatter consumes it."""
    interpret = resolve_interpret(interpret)

    def _kernel(idx_ref, val_ref, agg_ref, r_ref, z_ref, out_ref):
        mv = _ell_matvec(idx_ref[...], val_ref[...])
        resid = r_ref[...] - mv(z_ref[...])
        out_ref[...] = jax.ops.segment_sum(resid, agg_ref[...],
                                           num_segments=n_coarse)

    def restrict(r, z):
        return pl.pallas_call(
            _kernel,
            out_shape=jax.ShapeDtypeStruct((n_coarse, r.shape[1]), r.dtype),
            interpret=interpret,
        )(idx, val, agg, r, z)

    return restrict
