"""Pallas TPU kernel: fused Mamba1 selective scan.

The unfused XLA lowering of the selective scan writes the per-step
[d_inner, state] decay/state intermediates to HBM — measured at ~2.6 MB
per token per layer on the falcon-mamba train cell, i.e. a 697 s/step
memory-roofline term (EXPERIMENTS.md §Perf).  Fusing the scan keeps h in
VMEM and reduces HBM traffic to the block inputs/outputs:

    reads  : x1, dt  [S, blk]      Bm, Cm  [S, state]     A [blk, state]
    writes : y [S, blk], h_out [blk, state]

Grid: (batch, d_inner / blk) — each program scans the full sequence for
one channel block of one batch element; channel blocks are independent
(the recurrence couples only time), which also matches how the channels
are sharded over the 'model' axis in the distributed setting.

VMEM at blk=512, S=4096, state=16: x1/dt/y 3x8 MB + small = ~26 MB with
f32; use S-chunked grids (the ``seq_chunk`` arg) for longer sequences.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, h0_ref,
                 y_ref, hT_ref):
    # blocks: x/dt [1, S, blk]; b/c [1, S, state]; a [blk, state];
    #         h0 [1, blk, state]; y [1, S, blk]; hT [1, blk, state]
    S = x_ref.shape[1]
    A = a_ref[...]                       # [blk, state]
    h0 = h0_ref[0]                       # [blk, state]

    def step(t, h):
        x_t = x_ref[0, t, :]             # [blk]
        dt_t = dt_ref[0, t, :]           # [blk]
        B_t = b_ref[0, t, :]             # [state]
        C_t = c_ref[0, t, :]             # [state]
        da = jnp.exp(dt_t[:, None] * A)                     # [blk, state]
        dbx = (dt_t * x_t)[:, None] * B_t[None, :]
        h = da * h + dbx
        y_ref[0, t, :] = jnp.sum(h * C_t[None, :], axis=-1)
        return h

    h = jax.lax.fori_loop(0, S, step, h0)
    hT_ref[0] = h


@functools.partial(jax.jit, static_argnames=("blk", "interpret"))
def ssm_scan(x1, dt, Bm, Cm, A, h0, *, blk: int = 512,
             interpret: bool = True):
    """Fused selective scan.  Shapes:
    x1/dt [B,S,di] f32; Bm/Cm [B,S,state] f32; A [di,state]; h0 [B,di,state].
    Returns y [B,S,di] (pre-D skip), hT [B,di,state].
    """
    B, S, di = x1.shape
    state = A.shape[1]
    blk = min(blk, di)
    assert di % blk == 0
    grid = (B, di // blk)
    y, hT = pl.pallas_call(
        _scan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, S, blk), lambda b, c: (b, 0, c)),
            pl.BlockSpec((1, S, blk), lambda b, c: (b, 0, c)),
            pl.BlockSpec((1, S, state), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((1, S, state), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((blk, state), lambda b, c: (c, 0)),
            pl.BlockSpec((1, blk, state), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, blk), lambda b, c: (b, 0, c)),
            pl.BlockSpec((1, blk, state), lambda b, c: (b, c, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, di), jnp.float32),
            jax.ShapeDtypeStruct((B, di, state), jnp.float32),
        ],
        interpret=interpret,
    )(x1.astype(jnp.float32), dt.astype(jnp.float32),
      Bm.astype(jnp.float32), Cm.astype(jnp.float32),
      A.astype(jnp.float32), h0.astype(jnp.float32))
    return y, hT


def ssm_scan_ref(x1, dt, Bm, Cm, A, h0):
    """Pure-jnp oracle (same recurrence as models.layers._ssm_step)."""
    def step(h, t):
        x_t, dt_t, B_t, C_t = t
        da = jnp.exp(dt_t.astype(jnp.float32)[..., None] * A)
        dbx = (dt_t * x_t).astype(jnp.float32)[..., None] * B_t[:, None, :]
        h = da * h + dbx
        y = jnp.sum(h * C_t.astype(jnp.float32)[:, None, :], axis=-1)
        return h, y

    xs = (x1.transpose(1, 0, 2), dt.transpose(1, 0, 2),
          Bm.transpose(1, 0, 2).astype(jnp.float32),
          Cm.transpose(1, 0, 2).astype(jnp.float32))
    h, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2), h
