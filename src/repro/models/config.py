"""Unified model configuration covering all assigned architecture families.

One frozen dataclass drives every architecture: dense / MoE / SSM (mamba1)
/ hybrid (parallel attn+ssm) / VLM (stub frontend) / audio enc-dec.
Per-architecture instances live in ``repro.configs.<arch>``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | encdec
    n_layers: int
    d_model: int
    vocab: int
    # --- attention ---
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0              # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    qk_norm: bool = False          # qwen3-style per-head RMSNorm on q/k
    attn_softcap: Optional[float] = None    # gemma2 attention logit softcap
    logit_softcap: Optional[float] = None   # gemma2 final logit softcap
    window: Optional[int] = None   # sliding-window size for local layers
    layer_pattern: str = "global"  # global | local_global | swa | hymba
    sandwich_norm: bool = False    # gemma2 pre+post norms
    # --- mlp ---
    d_ff: int = 0
    mlp_type: str = "swiglu"       # swiglu | gelu
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0              # 0 -> d_ff
    dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    moe_group: int = 2048          # GShard dispatch group size (tokens)
    expert_shard: str = "ep"       # ep: experts over 'model'; tp: ff over 'model'
    moe_impl: str = "onehot"       # onehot: GShard einsum dispatch (baseline)
    #                                gather: index-based dispatch (see §Perf —
    #                                kills the T*E*k*cf*d dispatch flops)
    # --- SSM (mamba1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0           # 0 -> d_model // 16
    ssm_chunk: int = 256           # remat chunk for the selective scan
    # --- encoder-decoder ---
    enc_layers: int = 0            # >0 -> encoder-decoder
    # --- modality frontend stub ---
    frontend: Optional[str] = None  # vision | audio
    frontend_dim: int = 0          # precomputed embedding dim (e.g. CLIP 1024)
    frontend_len: int = 0          # patches/frames prefixed to the sequence
    # --- misc ---
    tie_embeddings: bool = False
    embed_scale: bool = False      # gemma-style sqrt(d_model) embed scaling
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"        # compute dtype
    param_dtype: str = "float32"

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or max(self.d_model // 16, 1)

    @property
    def eff_moe_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch run the 500k-context decode shape?"""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.layer_pattern == "swa" and self.window is not None

    def layer_kinds(self) -> Tuple[int, ...]:
        """Per-layer attention kind: 0 = global, 1 = local/window."""
        if self.layer_pattern == "global":
            return tuple(0 for _ in range(self.n_layers))
        if self.layer_pattern == "swa":
            return tuple(1 for _ in range(self.n_layers))
        if self.layer_pattern == "local_global":   # gemma2: alternate L,G
            return tuple(i % 2 for i in range(self.n_layers))
        if self.layer_pattern == "hymba":
            # 3 global layers (first / middle / last), SWA elsewhere
            g = {0, self.n_layers // 2, self.n_layers - 1}
            return tuple(0 if i in g else 1 for i in range(self.n_layers))
        raise ValueError(self.layer_pattern)

    def validate(self) -> "ModelConfig":
        if self.family in ("dense", "moe", "vlm", "encdec", "hybrid"):
            assert self.n_heads > 0 and self.n_kv_heads > 0
            assert self.n_heads % self.n_kv_heads == 0
        if self.family in ("ssm", "hybrid"):
            assert self.ssm_state > 0
        if self.family == "moe":
            assert self.n_experts > 0 and self.top_k > 0
        if self.family == "encdec":
            assert self.enc_layers > 0
        if self.frontend:
            assert self.frontend_dim > 0
            if self.enc_layers == 0:   # decoder-prefix frontends (VLM)
                assert self.frontend_len > 0
        return self
