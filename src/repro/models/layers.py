"""Composable JAX layers shared by all assigned architectures.

Everything is a pure function over explicit param pytrees (no flax):
  * rmsnorm / rope
  * blockwise attention — online-softmax over KV blocks so no [S, S]
    score tensor is ever materialized (required for the 32k prefill and
    4k train shapes at production batch sizes); GQA, sliding windows,
    gemma-style softcap and qwen-style qk-norm are all folded in.
  * decode attention against a (rolling or full) KV cache.
  * MLP: swiglu / gelu.
  * MoE with GShard-style grouped capacity dispatch (einsum one-hots) —
    compiles to dense MXU work + EP/TP collectives, no ragged ops.
  * Mamba1 selective scan, chunked + rematerialized, with exact
    single-step recurrence for decode.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

# ---------------------------------------------------------------------------
# norms / rope / embeddings
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps=1e-6, plus_one=False):
    dt = x.dtype
    x = x.astype(jnp.float32)
    y = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    scale = (1.0 + w.astype(jnp.float32)) if plus_one else w.astype(jnp.float32)
    return (y * scale).astype(dt)


def rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: [..., S] int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]   # [..., S, 1, half]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _qkv(x, p, cfg: ModelConfig):
    """x [B,S,d] -> q [B,S,H,hd], k/v [B,S,KV,hd] (pre-rope)."""
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def constrain(x, *names):
    """Best-effort sharding constraint against the ambient abstract mesh.

    ``names`` per dim: 'batch' -> the data-parallel axes present in the
    mesh, 'model' -> the tensor-parallel axis, None -> unconstrained.
    A dim is only constrained when its size divides the axis size.  Without
    an ambient mesh (unit tests, single device) this is a no-op.

    Why it exists: GSPMD occasionally drops the batch sharding when
    propagating into while-loop bodies (observed on the blockwise-attention
    q-block loop: the body ran with the full batch replicated per device,
    16x attention flops).  Pinning q/k/v and the output is cheap insurance.
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return x
    if mesh is None or not mesh.shape:
        return x
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    import numpy as _np

    def axis_for(name, dim):
        if name == "batch" and dp:
            size = int(_np.prod([mesh.shape[a] for a in dp]))
            if dim % size == 0 and dim >= size:
                return dp if len(dp) > 1 else dp[0]
            # try single axes
            for a in dp:
                if dim % mesh.shape[a] == 0 and dim >= mesh.shape[a]:
                    return a
        if name == "model" and "model" in mesh.shape:
            if dim % mesh.shape["model"] == 0 and dim >= mesh.shape["model"]:
                return "model"
        return None

    spec = jax.sharding.PartitionSpec(
        *[axis_for(n, d) if n else None for n, d in zip(names, x.shape)])
    return jax.lax.with_sharding_constraint(x, spec)


def repeat_kv(k, rep: int):
    """[B,S,KV,hd] -> [B,S,KV*rep,hd].  Keeps a FLAT head dim so GSPMD can
    shard attention over 'model' whenever H divides the axis — reshaping
    into (KV, rep) factors instead makes the dim unshardable and silently
    replicates all attention compute across the model axis (16x waste)."""
    if rep == 1:
        return k
    B, S, KV, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, KV, rep, hd)
                            ).reshape(B, S, KV * rep, hd)


def blockwise_attention(q, k, v, q_pos, k_pos, cfg: ModelConfig, kind,
                        q_block: int = 512, kv_block: int = 1024):
    """Online-softmax attention; never materializes [Sq, Sk] globally.

    q [B,Sq,H,hd]; k/v [B,Sk,KV,hd]; kind: 0 global-causal, 1 windowed.
    Each kv block step is rematerialized (flash-style backward): only the
    (m, l, acc) carries are saved, the [qb, cb] score block is recomputed.
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    k = repeat_kv(k, H // KV)
    v = repeat_kv(v, H // KV)
    scale = 1.0 / np.sqrt(hd)
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Sk)
    assert Sq % q_block == 0 and Sk % kv_block == 0
    nq, nk = Sq // q_block, Sk // kv_block
    qr = constrain(q.reshape(B, nq, q_block, H, hd),
                   "batch", None, None, "model", None)
    kr = constrain(k.reshape(B, nk, kv_block, H, hd),
                   "batch", None, None, "model", None)
    vr = constrain(v.reshape(B, nk, kv_block, H, hd),
                   "batch", None, None, "model", None)
    qp = q_pos.reshape(nq, q_block)
    kp = k_pos.reshape(nk, kv_block)
    win = cfg.window or (1 << 30)

    def q_step(qi):
        qb = constrain(qr[:, qi], "batch", None, "model", None)
        qpb = qp[qi]

        def kv_step(carry, ki):
            m, l, acc = carry
            kb = constrain(kr[:, ki], "batch", None, "model", None)
            vb = constrain(vr[:, ki], "batch", None, "model", None)
            s = jnp.einsum("bqnh,bcnh->bnqc", qb, kb).astype(jnp.float32)
            s = constrain(s, "batch", "model", None, None)
            s = softcap(s * scale, cfg.attn_softcap)
            causal = kp[ki][None, :] <= qpb[:, None]          # [qb, cb]
            inwin = (qpb[:, None] - kp[ki][None, :]) < win
            mask = causal & jnp.where(kind == 1, inwin, True)
            mask = mask | (kind == 2)   # kind 2: bidirectional (encoder)
            s = jnp.where(mask[None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p_ = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p_.sum(-1)
            pv = jnp.einsum("bnqc,bcnh->bnqh", p_.astype(vb.dtype), vb)
            acc = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l, acc), None

        m0 = jnp.full((B, H, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, q_block), jnp.float32)
        a0 = jnp.zeros((B, H, q_block, hd), q.dtype)
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(kv_step), (m0, l0, a0),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        out = out.transpose(0, 2, 1, 3).reshape(B, q_block, H * hd)
        return constrain(out, "batch", None, "model")

    out = jax.lax.map(q_step, jnp.arange(nq))         # [nq,B,qb,H*hd]
    out = constrain(out, None, "batch", None, "model")
    return out.transpose(1, 0, 2, 3).reshape(B, Sq, H * hd)


def attention_train(x, p, cfg: ModelConfig, kind, positions=None,
                    return_kv: bool = False):
    """Full-sequence attention for train/prefill.  x [B,S,d] -> [B,S,d]."""
    B, S, _ = x.shape
    pos = positions if positions is not None else jnp.arange(S)
    q, k, v = _qkv(x, p, cfg)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    o = blockwise_attention(q, k, v, pos, pos, cfg, kind)
    out = jnp.einsum("bsx,xd->bsd", o, p["wo"].astype(x.dtype))
    if return_kv:
        return out, (k, v)
    return out


def attention_decode(x, p, cfg: ModelConfig, kind, cache_k, cache_v,
                     cache_pos, pos):
    """Single-token decode.  x [B,1,d]; caches [B,C,KV,hd]; pos scalar.

    Rolling-buffer semantics: the new K/V lands at slot pos % C; masking is
    by absolute positions stored in ``cache_pos`` [B, C] (-1 = empty).
    Works for full caches (C = max_len) and windowed caches (C = window).
    """
    B, C = cache_k.shape[0], cache_k.shape[1]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    rep = H // KV
    q, k, v = _qkv(x, p, cfg)
    posv = jnp.full((B, 1), pos, jnp.int32)
    q = rope(q, posv, cfg.rope_theta)
    k = rope(k, posv, cfg.rope_theta)
    slot = pos % C
    cache_k = jax.lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0))
    cache_pos = jax.lax.dynamic_update_slice(
        cache_pos, jnp.full((B, 1), pos, jnp.int32), (0, slot))

    qh = q.reshape(B, KV, rep, hd)
    s = jnp.einsum("bkrh,bckh->bkrc", qh, cache_k).astype(jnp.float32)
    s = softcap(s / np.sqrt(hd), cfg.attn_softcap)
    win = cfg.window or (1 << 30)
    valid = (cache_pos >= 0) & (cache_pos <= pos)
    valid &= jnp.where(kind == 1, (pos - cache_pos) < win, True)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkrc,bckh->bkrh", w.astype(cache_v.dtype), cache_v)
    o = o.reshape(B, 1, H * hd)
    out = jnp.einsum("bsx,xd->bsd", o, p["wo"].astype(x.dtype))
    return out, cache_k, cache_v, cache_pos


def cross_attention(x, p, cfg: ModelConfig, enc_k, enc_v):
    """Decoder->encoder attention (blockwise, unmasked); enc_k/enc_v
    [B,Ss,KV,hd] precomputed once per generation."""
    B, S, _ = x.shape
    Ss = enc_k.shape[1]
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(x.dtype))
    o = blockwise_attention(q, enc_k, enc_v, jnp.arange(S), jnp.arange(Ss),
                            cfg, jnp.int32(2))
    return jnp.einsum("bsx,xd->bsd", o, p["wo"].astype(x.dtype))


def encoder_attention(x, p, cfg: ModelConfig):
    """Bidirectional self-attention (encoder), blockwise (kind=2)."""
    B, S, _ = x.shape
    pos = jnp.arange(S)
    q, k, v = _qkv(x, p, cfg)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    o = blockwise_attention(q, k, v, pos, pos, cfg, jnp.int32(2))
    return jnp.einsum("bsx,xd->bsd", o, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp(x, p, cfg: ModelConfig):
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ p["w1"].astype(x.dtype)) * (x @ p["w3"].astype(x.dtype))
    elif cfg.mlp_type == "geglu":   # gemma2
        h = jax.nn.gelu(x @ p["w1"].astype(x.dtype)) * (x @ p["w3"].astype(x.dtype))
    elif cfg.mlp_type == "gelu":
        h = jax.nn.gelu(x @ p["w1"].astype(x.dtype))
    else:
        raise ValueError(cfg.mlp_type)
    return h @ p["w2"].astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE (GShard grouped capacity dispatch)
# ---------------------------------------------------------------------------

class MoEOut(NamedTuple):
    y: jnp.ndarray
    aux_loss: jnp.ndarray


def _expert_compute(xe, p, cfg: ModelConfig):
    """xe [g,E,C,d] -> ye [g,E,C,d] through each expert's FFN."""
    w1 = p["w1"].astype(xe.dtype)                           # [E,d,f]
    w2 = p["w2"].astype(xe.dtype)                           # [E,f,d]
    if cfg.mlp_type in ("swiglu", "geglu"):
        w3 = p["w3"].astype(xe.dtype)
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("gecd,edf->gecf", xe, w1))
        h = h * jnp.einsum("gecd,edf->gecf", xe, w3)
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", xe, w1))
    return jnp.einsum("gecf,efd->gecd", h, w2)


def moe_ffn(x, p, cfg: ModelConfig) -> MoEOut:
    """x [B,S,d] -> [B,S,d].  Router top-k + capacity-limited dispatch.

    Two dispatch implementations:
      * ``onehot`` (baseline, GShard-faithful): einsum against one-hot
        dispatch/combine tensors — pure MXU work, but costs
        T*E*k*cf*d flops per dispatch (dominates expert compute itself
        at E=128; see EXPERIMENTS.md §Perf).
      * ``gather``: scatter slot->token indices, gather token rows into
        [E, C, d] and gather-combine back — O(slots*d) bytes, ~0 flops.
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    G = min(cfg.moe_group, T)
    assert T % G == 0, (T, G)
    ng = T // G
    C = max(int(np.ceil(G * k * cfg.capacity_factor / E)), 1)
    xt = x.reshape(ng, G, d)

    logits = jnp.einsum("gtd,de->gte", xt, p["router"].astype(x.dtype))
    logits = logits.astype(jnp.float32)
    gate_w, gate_i = jax.lax.top_k(logits, k)           # [ng,G,k]
    gate_w = jax.nn.softmax(gate_w, axis=-1)

    # aux load-balance loss (Switch): E * mean_e(frac_tokens * mean_prob)
    probs = jax.nn.softmax(logits, axis=-1)
    frac_tok = jnp.mean(
        jax.nn.one_hot(gate_i[..., 0], E, dtype=jnp.float32), axis=1)
    frac_prob = jnp.mean(probs, axis=1)
    aux = E * jnp.mean(jnp.sum(frac_tok * frac_prob, -1))

    # capacity positions over flattened (token, slot) pairs, token-major
    assign = jax.nn.one_hot(gate_i, E, dtype=jnp.int32)     # [ng,G,k,E]
    af = assign.reshape(ng, G * k, E)
    pos = jnp.cumsum(af, axis=1) - af                       # [ng,G*k,E]
    keep = (pos < C) & (af > 0)

    if cfg.moe_impl == "gather":
        # slot tables: slot_token[g,e,c] = token index feeding that slot
        tok_of_slot = jnp.arange(G * k, dtype=jnp.int32) // k   # [G*k]
        pos_tk = jnp.take_along_axis(
            pos, gate_i.reshape(ng, G * k)[..., None], axis=-1)[..., 0]
        keep_tk = jnp.take_along_axis(
            keep, gate_i.reshape(ng, G * k)[..., None], axis=-1)[..., 0]
        e_tk = gate_i.reshape(ng, G * k)
        g_idx = jnp.broadcast_to(jnp.arange(ng)[:, None], (ng, G * k))
        slot_token = jnp.zeros((ng, E, C), jnp.int32).at[
            g_idx, jnp.where(keep_tk, e_tk, 0),
            jnp.where(keep_tk, pos_tk, C)
        ].set(jnp.broadcast_to(tok_of_slot, (ng, G * k)), mode="drop")
        xe = jnp.take_along_axis(
            xt, slot_token.reshape(ng, E * C)[..., None], axis=1
        ).reshape(ng, E, C, d)
        xe = constrain(xe, "batch", "model" if cfg.expert_shard == "ep"
                       else None, None, None)
        ye = _expert_compute(xe, p, cfg)
        ye = constrain(ye, "batch", "model" if cfg.expert_shard == "ep"
                       else None, None, None)
        # combine: for each (token, slot k) gather its expert output row
        flat = ye.reshape(ng, E * C, d)
        idx = jnp.where(keep_tk, e_tk * C + jnp.minimum(pos_tk, C - 1), 0)
        rows = jnp.take_along_axis(flat, idx[..., None], axis=1)  # [ng,G*k,d]
        rows = rows * (keep_tk[..., None].astype(rows.dtype))
        wf = gate_w.reshape(ng, G * k)[..., None].astype(rows.dtype)
        y = (rows * wf).reshape(ng, G, k, d).sum(2)
        return MoEOut(y.reshape(B, S, d), aux)

    pos_oh = jax.nn.one_hot(pos, C, dtype=x.dtype) * keep[..., None].astype(x.dtype)
    disp = pos_oh.reshape(ng, G, k, E, C)                   # one-hot [.. E,C]
    wf = gate_w.astype(x.dtype)[..., None, None]            # [ng,G,k,1,1]
    combine = (disp * wf).sum(2)                            # [ng,G,E,C]
    disp_t = disp.sum(2)                                    # [ng,G,E,C]

    xe = jnp.einsum("gtec,gtd->gecd", disp_t, xt)           # dispatch
    ye = _expert_compute(xe, p, cfg)
    # NOTE (§Perf, refuted hypothesis): constraining ye to reduce-scatter
    # over d made the mixtral train cell WORSE (tl 64.7 -> 76.4 s) — the
    # d-sharded combine output then fights the sequence-parallel residual
    # sharding and GSPMD inserts an extra per-layer reshard.  Left as-is.
    y = jnp.einsum("gecd,gtec->gtd", ye, combine)           # combine
    return MoEOut(y.reshape(B, S, d), aux)


# ---------------------------------------------------------------------------
# Mamba1 (selective SSM)
# ---------------------------------------------------------------------------

def _causal_conv(x, w, b, ssm_conv: int):
    """Depthwise causal conv over S.  x [B,S,di]; w [di,k]; b [di]."""
    k = ssm_conv
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(pad[:, j:j + x.shape[1]] * w[:, j].astype(x.dtype)
            for j in range(k))
    return y + b.astype(x.dtype)


def _ssm_inputs(x1, p, cfg: ModelConfig):
    """x1 [B,S,di] -> dt [B,S,di], Bm/Cm [B,S,state], A [di,state], D [di]."""
    xdbc = x1 @ p["x_proj"].astype(x1.dtype)   # [B,S,dt_rank+2*state]
    r, st = cfg.dt_rank, cfg.ssm_state
    dt_in, Bm, Cm = xdbc[..., :r], xdbc[..., r:r + st], xdbc[..., r + st:]
    dt = jax.nn.softplus(
        dt_in @ p["dt_proj"].astype(x1.dtype)
        + p["dt_bias"].astype(x1.dtype))       # [B,S,di]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # [di,state]
    return dt, Bm, Cm, A, p["D"].astype(jnp.float32)


def _ssm_step(h, x_t, dt_t, B_t, C_t, A):
    """One recurrence step.  h [B,di,state]."""
    da = jnp.exp(dt_t.astype(jnp.float32)[..., None] * A)           # [B,di,st]
    dbx = (dt_t[..., None] * B_t[:, None, :]).astype(jnp.float32) \
        * x_t.astype(jnp.float32)[..., None]
    h = da * h + dbx
    y = jnp.sum(h * C_t.astype(jnp.float32)[:, None, :], axis=-1)   # [B,di]
    return h, y


def mamba_scan(x1, dt, Bm, Cm, A, D, h0, chunk: int):
    """Chunked + rematerialized selective scan.  x1 [B,S,di] -> y, h."""
    B, S, di = x1.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    def chunk_fn(h, inp):
        xc, dtc, bc, cc = inp  # [chunk,B,...]

        def step(h, t):
            x_t, dt_t, B_t, C_t = t
            h, y = _ssm_step(h, x_t, dt_t, B_t, C_t, A)
            return h, y

        h, ys = jax.lax.scan(step, h, (xc, dtc, bc, cc))
        return h, ys

    # time-major chunks: [nc, chunk, B, ...]
    def tm(a):
        return a.transpose(1, 0, 2).reshape(nc, chunk, B, a.shape[-1])

    h, ys = jax.lax.scan(jax.checkpoint(chunk_fn), h0,
                         (tm(x1), tm(dt), tm(Bm), tm(Cm)))
    y = ys.reshape(S, B, di).transpose(1, 0, 2)
    y = y + D[None, None, :] * x1.astype(jnp.float32)
    return y, h


def mamba_block(x, p, cfg: ModelConfig, h0=None, conv_buf=None,
                decode: bool = False):
    """Mamba1 block.  Train: x [B,S,d].  Decode: x [B,1,d] + carried state.

    Returns (y, h, conv_buf) — h/conv_buf are None in train mode unless
    initial state is provided.
    """
    B = x.shape[0]
    di, st = cfg.d_inner, cfg.ssm_state
    xz = x @ p["in_proj"].astype(x.dtype)      # [B,S,2*di]
    x1, z = xz[..., :di], xz[..., di:]

    if not decode:
        x1 = jax.nn.silu(_causal_conv(x1, p["conv_w"], p["conv_b"],
                                      cfg.ssm_conv))
        dt, Bm, Cm, A, D = _ssm_inputs(x1, p, cfg)
        h0 = (jnp.zeros((B, di, st), jnp.float32) if h0 is None else h0)
        y, h = mamba_scan(x1, dt, Bm, Cm, A, D, h0, cfg.ssm_chunk)
        y = y.astype(x.dtype) * jax.nn.silu(z)
        out = y @ p["out_proj"].astype(x.dtype)
        return out, h, None

    # decode: conv_buf [B, k-1, di] carries the last k-1 pre-conv inputs
    k = cfg.ssm_conv
    window = jnp.concatenate([conv_buf, x1], axis=1)       # [B,k,di]
    xc = sum(window[:, j] * p["conv_w"][:, j].astype(x.dtype)
             for j in range(k)) + p["conv_b"].astype(x.dtype)
    xc = jax.nn.silu(xc)[:, None, :]                       # [B,1,di]
    dt, Bm, Cm, A, D = _ssm_inputs(xc, p, cfg)
    h, y = _ssm_step(h0, xc[:, 0], dt[:, 0], Bm[:, 0], Cm[:, 0], A)
    y = y + D[None, :] * xc[:, 0].astype(jnp.float32)
    y = y[:, None, :].astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, h, window[:, 1:]
