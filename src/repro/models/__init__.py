from repro.models.config import ModelConfig
from repro.models.model import (init_params, loss_fn, forward_hidden,
                                decode_step, init_cache, prefill,
                                param_count, vocab_padded)

__all__ = ["ModelConfig", "init_params", "loss_fn", "forward_hidden",
           "decode_step", "init_cache", "prefill", "param_count",
           "vocab_padded"]
