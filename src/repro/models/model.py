"""Model assembly: param init, train forward, chunked loss, decode.

Conventions:
  * params are a nested dict pytree; per-layer leaves are stacked [L, ...]
    and consumed by ``lax.scan`` (keeps HLO size O(1) in depth — critical
    for 512-device SPMD compiles).
  * train/prefill use full-sequence layers; decode uses a Python-unrolled
    layer loop with per-layer caches (cache shapes differ per layer kind —
    windowed vs global vs SSM — so stacking would waste memory).
  * the LM head loss is computed in token chunks (never materializes the
    [B, S, V] logits tensor).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.config import ModelConfig


def vocab_padded(cfg: ModelConfig) -> int:
    return int(np.ceil(cfg.vocab / 512)) * 512


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _norm_init(key, shape, scale=0.02, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _attn_params(cfg: ModelConfig, key, n_layers, out_scale):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 6)
    p = {
        "wq": _norm_init(ks[0], (n_layers, d, H, hd)),
        "wk": _norm_init(ks[1], (n_layers, d, KV, hd)),
        "wv": _norm_init(ks[2], (n_layers, d, KV, hd)),
        "wo": _norm_init(ks[3], (n_layers, H * hd, d), scale=out_scale),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((n_layers, hd), jnp.float32)
        p["k_norm"] = jnp.ones((n_layers, hd), jnp.float32)
    return p


def _mlp_params(cfg: ModelConfig, key, n_layers, out_scale, d_ff=None):
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w1": _norm_init(ks[0], (n_layers, d, ff)),
        "w2": _norm_init(ks[1], (n_layers, ff, d), scale=out_scale),
    }
    if cfg.mlp_type in ("swiglu", "geglu"):
        p["w3"] = _norm_init(ks[2], (n_layers, d, ff))
    return p


def _moe_params(cfg: ModelConfig, key, n_layers, out_scale):
    d, E, f = cfg.d_model, cfg.n_experts, cfg.eff_moe_d_ff
    ks = jax.random.split(key, 4)
    p = {
        "router": _norm_init(ks[0], (n_layers, d, E)),
        "w1": _norm_init(ks[1], (n_layers, E, d, f)),
        "w2": _norm_init(ks[2], (n_layers, E, f, d), scale=out_scale),
    }
    if cfg.mlp_type in ("swiglu", "geglu"):
        p["w3"] = _norm_init(ks[3], (n_layers, E, d, f))
    return p


def _mamba_params(cfg: ModelConfig, key, n_layers, out_scale):
    d, di, st, r, k = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                       cfg.dt_rank, cfg.ssm_conv)
    ks = jax.random.split(key, 5)
    A = jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": _norm_init(ks[0], (n_layers, d, 2 * di)),
        "conv_w": _norm_init(ks[1], (n_layers, di, k), scale=0.1),
        "conv_b": jnp.zeros((n_layers, di), jnp.float32),
        "x_proj": _norm_init(ks[2], (n_layers, di, r + 2 * st)),
        "dt_proj": _norm_init(ks[3], (n_layers, r, di)),
        "dt_bias": jnp.full((n_layers, di), -4.6, jnp.float32),  # softplus^-1(0.01)
        "A_log": jnp.tile(jnp.log(A)[None], (n_layers, 1, 1)),
        "D": jnp.ones((n_layers, di), jnp.float32),
        "out_proj": _norm_init(ks[4], (n_layers, di, d), scale=out_scale),
    }


def _layer_params(cfg: ModelConfig, key, n_layers, decoder=False):
    out_scale = 0.02 / np.sqrt(2 * cfg.n_layers)
    ks = jax.random.split(key, 5)
    p: Dict[str, Any] = {"ln1": jnp.ones((n_layers, cfg.d_model), jnp.float32),
                         "ln2": jnp.ones((n_layers, cfg.d_model), jnp.float32)}
    if cfg.sandwich_norm:
        p["ln1_post"] = jnp.ones((n_layers, cfg.d_model), jnp.float32)
        p["ln2_post"] = jnp.ones((n_layers, cfg.d_model), jnp.float32)
    fam = cfg.family
    if fam == "ssm":
        p["ssm"] = _mamba_params(cfg, ks[0], n_layers, out_scale)
        del p["ln2"]  # single-branch block
        return p
    p["attn"] = _attn_params(cfg, ks[0], n_layers, out_scale)
    if fam == "hybrid":
        p["ssm"] = _mamba_params(cfg, ks[1], n_layers, out_scale)
    if fam == "moe":
        p["moe"] = _moe_params(cfg, ks[2], n_layers, out_scale)
        if cfg.dense_residual:
            p["mlp"] = _mlp_params(cfg, ks[3], n_layers, out_scale)
    else:
        p["mlp"] = _mlp_params(cfg, ks[3], n_layers, out_scale)
    if decoder:
        p["xattn"] = _attn_params(cfg, ks[4], n_layers, out_scale)
        p["ln_x"] = jnp.ones((n_layers, cfg.d_model), jnp.float32)
    return p


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    Vp = vocab_padded(cfg)
    ks = jax.random.split(key, 6)
    params: Dict[str, Any] = {
        "embed": _norm_init(ks[0], (Vp, cfg.d_model)),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "layers": _layer_params(cfg, ks[1], cfg.n_layers,
                                decoder=cfg.enc_layers > 0),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _norm_init(ks[2], (cfg.d_model, Vp))
    if cfg.enc_layers:
        enc_cfg = cfg  # same dims for encoder stack
        params["encoder"] = _layer_params(enc_cfg, ks[3], cfg.enc_layers)
        params["enc_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
    if cfg.frontend:
        params["frontend_proj"] = _norm_init(
            ks[4], (cfg.frontend_dim, cfg.d_model))
    return params


def param_count(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# layer bodies (scanned over stacked params)
# ---------------------------------------------------------------------------

def _decoder_layer(x, p, cfg: ModelConfig, kind, enc_out=None,
                   collect_kv: bool = False):
    """One transformer block (any family except pure ssm encoder).

    Returns (x, aux, ys) — ys is the per-layer serving cache content
    (K/V and/or SSM state) when ``collect_kv``, else None.
    """
    aux = jnp.zeros((), jnp.float32)
    ys = {}
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps, plus_one=cfg.sandwich_norm)
    if cfg.family == "hybrid":
        a, kv = L.attention_train(h, p["attn"], cfg, kind, return_kv=True)
        s, hs, _ = L.mamba_block(h, p["ssm"], cfg)
        o = 0.5 * (a + s)
        if collect_kv:
            ys = {"k": kv[0], "v": kv[1], "h": hs}
    else:
        o, kv = L.attention_train(h, p["attn"], cfg, kind, return_kv=True)
        if collect_kv:
            ys = {"k": kv[0], "v": kv[1]}
    if cfg.sandwich_norm:
        o = L.rmsnorm(o, p["ln1_post"], cfg.norm_eps, plus_one=True)
    x = x + o
    if enc_out is not None:
        hx = L.rmsnorm(x, p["ln_x"], cfg.norm_eps)
        ek = jnp.einsum("bsd,dnh->bsnh", enc_out,
                        p["xattn"]["wk"].astype(enc_out.dtype))
        ev = jnp.einsum("bsd,dnh->bsnh", enc_out,
                        p["xattn"]["wv"].astype(enc_out.dtype))
        x = x + L.cross_attention(hx, p["xattn"], cfg, ek, ev)
    h = L.rmsnorm(x, p["ln2"], cfg.norm_eps, plus_one=cfg.sandwich_norm)
    if cfg.family == "moe":
        mo = L.moe_ffn(h, p["moe"], cfg)
        o, aux = mo.y, mo.aux_loss
        if cfg.dense_residual:
            o = o + L.mlp(h, p["mlp"], cfg)
    else:
        o = L.mlp(h, p["mlp"], cfg)
    if cfg.sandwich_norm:
        o = L.rmsnorm(o, p["ln2_post"], cfg.norm_eps, plus_one=True)
    return x + o, aux, (ys if collect_kv else None)


def _ssm_layer(x, p, cfg: ModelConfig, collect_kv: bool = False):
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    o, hs, _ = L.mamba_block(h, p["ssm"], cfg)
    ys = {"h": hs} if collect_kv else None
    return x + o, jnp.zeros((), jnp.float32), ys


def _encoder_layer(x, p, cfg: ModelConfig):
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    x = x + L.encoder_attention(h, p["attn"], cfg)
    h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    return x + L.mlp(h, p["mlp"], cfg), jnp.zeros((), jnp.float32), None


_PARAM_DIM_TAGS = {
    # per-layer (unstacked) param dims -> ('batch' = FSDP axes, 'model' = TP)
    "wq": ("batch", "model", None), "wk": ("batch", "model", None),
    "wv": ("batch", "model", None), "wo": ("model", "batch"),
    "w1": ("batch", "model"), "w3": ("batch", "model"),
    "w2": ("model", "batch"),
    "router": ("batch", None),
    "in_proj": ("batch", "model"), "conv_w": ("model", None),
    "conv_b": ("model",), "x_proj": ("model", None),
    "dt_proj": (None, "model"), "dt_bias": ("model",),
    "A_log": ("model", None), "D": ("model",),
    "out_proj": ("model", "batch"),
}


def _constrain_layer_slice(p, cfg: ModelConfig):
    """Pin shardings of one layer's param slice (and, because
    with_sharding_constraint transposes to itself, of its GRADIENT).

    Without this the backward scan accumulates per-layer grads into fully
    replicated [L, ...] buffers (GSPMD drops the sharding through the
    in-loop dynamic-update-slice) — 16x grad memory + traffic.
    """

    def rule(path, leaf):
        names = [getattr(k, "key", str(k)) for k in path]
        name = names[-1]
        tags = _PARAM_DIM_TAGS.get(name)
        if name in ("w1", "w2", "w3") and leaf.ndim == 3:   # MoE [E, d, f]
            if cfg.expert_shard == "ep":
                tags = ("model", "batch", None)
            else:
                tags = ((None, "batch", "model") if name != "w2"
                        else (None, "model", "batch"))
        if tags is None or len(tags) != leaf.ndim:
            return leaf
        return L.constrain(leaf, *tags)

    return jax.tree_util.tree_map_with_path(rule, p)


def _stack(x, stacked_params, cfg: ModelConfig, body, remat: bool):
    """scan the layer body over stacked params (+ per-layer kind).

    ``body(x, p, kind) -> (x', aux, ys)``; ys (or None) is collected
    across layers as stacked [L, ...] arrays (serving caches).
    """
    kinds = jnp.asarray(cfg.layer_kinds(), jnp.int32)
    n = kinds.shape[0]

    def step(carry, xs):
        p, kind = xs
        p = _constrain_layer_slice(p, cfg)
        x, aux = carry
        # Megatron-style sequence parallelism: the residual stream between
        # blocks is sharded over the 'model' axis on the sequence dim, so
        # the per-layer remat residual is 1/TP the size (GSPMD inserts the
        # all-gather at qkv/mlp entry and the reduce-scatter at exit).
        x = L.constrain(x, "batch", "model", None)
        fn = jax.checkpoint(body) if remat else body
        y, a, ys = fn(x, p, kind)
        y = L.constrain(y, "batch", "model", None)
        return (y, aux + a), ys

    (x, aux), ys = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)),
                                (stacked_params, kinds), length=n)
    return x, aux, ys


# ---------------------------------------------------------------------------
# train forward + loss
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, tokens):
    x = params["embed"].astype(_cdtype(cfg))[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return x


def _cdtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def forward_hidden(params, cfg: ModelConfig, batch, remat: bool = True,
                   collect_kv: bool = False):
    """Run the backbone.

    Returns (hidden [B,S,d], aux_loss, loss_mask, caches) — ``caches`` is
    the stacked per-layer K/V (+SSM state) when ``collect_kv`` (used by the
    serving prefill path), else None.
    """
    tokens = batch["tokens"]
    x = embed_tokens(params, cfg, tokens)
    loss_mask = jnp.ones(tokens.shape, bool)
    if cfg.frontend and cfg.enc_layers == 0:  # VLM: patch prefix on decoder
        front = batch["frontend"].astype(x.dtype)  # [B,P,frontend_dim]
        fx = front @ params["frontend_proj"].astype(x.dtype)
        x = jnp.concatenate([fx, x], axis=1)
        loss_mask = jnp.concatenate(
            [jnp.zeros(fx.shape[:2], bool), loss_mask], axis=1)

    enc_out = None
    if cfg.enc_layers:
        src = batch["src"].astype(x.dtype)          # [B,Ss,frontend_dim]|emb
        if "frontend_proj" in params:
            src = src @ params["frontend_proj"].astype(x.dtype)
        e, _, _ = _stack(src, params["encoder"], cfg,
                         lambda h, p, k: _encoder_layer(h, p, cfg), remat)
        enc_out = L.rmsnorm(e, params["enc_norm"], cfg.norm_eps)

    if cfg.family == "ssm":
        body = lambda h, p, k: _ssm_layer(h, p, cfg, collect_kv)  # noqa: E731
    else:
        body = lambda h, p, k: _decoder_layer(  # noqa: E731
            h, p, cfg, k, enc_out, collect_kv)
    # Cast the stacked params to compute dtype *outside* the scan: casting
    # inside the body makes the backward scan accumulate per-layer grads
    # into full UNSHARDED f32 buffers (GSPMD loses the param sharding
    # through the in-loop convert) — observed as ~3.6 GB/device/buffer on
    # the 16x16 mesh.  bf16 grads re-shard correctly and AdamW upcasts.
    cd = _cdtype(cfg)
    stacked = jax.tree.map(lambda w: w.astype(cd), params["layers"])
    x, aux, caches = _stack(x, stacked, cfg, body, remat)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, aux, loss_mask, caches


def chunked_ce_loss(params, cfg: ModelConfig, hidden, labels, loss_mask,
                    chunk: int = 4096):
    """Cross-entropy without materializing [B,S,V] logits.

    ``labels`` aligns with the *text* positions (the tail of the sequence
    when a modality prefix is present).
    """
    Vp = vocab_padded(cfg)
    W = (params["embed"].T if cfg.tie_embeddings
         else params["lm_head"]).astype(_cdtype(cfg))
    B, S_all, d = hidden.shape
    S_txt = labels.shape[1]
    h = hidden[:, S_all - S_txt:, :]
    mask = loss_mask[:, S_all - S_txt:]
    T = B * S_txt
    hf = h.reshape(T, d)
    lf = labels.reshape(T)
    mf = mask.reshape(T)
    chunk = min(chunk, T)
    assert T % chunk == 0
    ncol = jnp.arange(Vp) >= cfg.vocab  # mask padded vocab columns

    def step(carry, xs):
        hs, ls, ms = xs
        # keep the token dim sharded over DP inside the loop (GSPMD loses
        # it through the reshape otherwise -> 16x logits traffic)
        hs = L.constrain(hs, "batch", None)
        logits = (hs @ W).astype(jnp.float32)
        logits = L.constrain(logits, "batch", "model")
        logits = L.softcap(logits, cfg.logit_softcap)
        logits = jnp.where(ncol[None, :], -1e30, logits)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[:, None], axis=-1)[:, 0]
        nll = (lse - gold) * ms
        loss, cnt = carry
        return (loss + nll.sum(), cnt + ms.sum()), None

    xs = (hf.reshape(-1, chunk, d), lf.reshape(-1, chunk),
          mf.reshape(-1, chunk).astype(jnp.float32))
    (loss, cnt), _ = jax.lax.scan(
        jax.checkpoint(step), (jnp.zeros((), jnp.float32),
                               jnp.zeros((), jnp.float32)), xs)
    return loss / jnp.maximum(cnt, 1.0)


def loss_fn(params, cfg: ModelConfig, batch, remat: bool = True,
            aux_weight: float = 0.01):
    hidden, aux, loss_mask, _ = forward_hidden(params, cfg, batch, remat)
    ce = chunked_ce_loss(params, cfg, hidden, batch["labels"], loss_mask)
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------

def _take_layer(tree, i):
    return jax.tree.map(lambda x: x[i], tree)


def init_cache(cfg: ModelConfig, B: int, cache_len: int,
               src_len: int = 0) -> list:
    """Per-layer cache list; shapes depend on the layer kind."""
    KV, hd = cfg.n_kv_heads, cfg.hd
    kinds = cfg.layer_kinds() if cfg.family != "ssm" else [0] * cfg.n_layers
    dt = _cdtype(cfg)
    caches = []
    for i in range(cfg.n_layers):
        c: Dict[str, Any] = {}
        if cfg.family in ("ssm", "hybrid"):
            c["h"] = jnp.zeros((B, cfg.d_inner, cfg.ssm_state), jnp.float32)
            c["conv"] = jnp.zeros((B, cfg.ssm_conv - 1, cfg.d_inner), dt)
        if cfg.family != "ssm":
            C = cache_len
            if kinds[i] == 1 and cfg.window:
                C = min(cache_len, cfg.window)
            c["k"] = jnp.zeros((B, C, KV, hd), dt)
            c["v"] = jnp.zeros((B, C, KV, hd), dt)
            c["pos"] = jnp.full((B, C), -1, jnp.int32)
        if cfg.enc_layers:
            c["ek"] = jnp.zeros((B, src_len, KV, hd), dt)
            c["ev"] = jnp.zeros((B, src_len, KV, hd), dt)
        caches.append(c)
    return caches


def decode_step(params, cfg: ModelConfig, caches, token, pos):
    """One-token decode.  token [B,1] int32; pos scalar int32.

    Returns (logits [B, vocab_padded], new_caches).
    """
    x = embed_tokens(params, cfg, token)
    kinds = cfg.layer_kinds() if cfg.family != "ssm" else [0] * cfg.n_layers
    new_caches = []
    for i in range(cfg.n_layers):
        p = _take_layer(params["layers"], i)
        c = dict(caches[i])
        h = L.rmsnorm(x, p["ln1"], cfg.norm_eps, plus_one=cfg.sandwich_norm)
        if cfg.family == "ssm":
            o, c["h"], c["conv"] = L.mamba_block(
                h, p["ssm"], cfg, h0=c["h"], conv_buf=c["conv"], decode=True)
            x = x + o
            new_caches.append(c)
            continue
        if cfg.family == "hybrid":
            a, c["k"], c["v"], c["pos"] = L.attention_decode(
                h, p["attn"], cfg, kinds[i], c["k"], c["v"], c["pos"], pos)
            s, c["h"], c["conv"] = L.mamba_block(
                h, p["ssm"], cfg, h0=c["h"], conv_buf=c["conv"], decode=True)
            o = 0.5 * (a + s)
        else:
            o, c["k"], c["v"], c["pos"] = L.attention_decode(
                h, p["attn"], cfg, kinds[i], c["k"], c["v"], c["pos"], pos)
        if cfg.sandwich_norm:
            o = L.rmsnorm(o, p["ln1_post"], cfg.norm_eps, plus_one=True)
        x = x + o
        if cfg.enc_layers:
            hx = L.rmsnorm(x, p["ln_x"], cfg.norm_eps)
            x = x + L.cross_attention(hx, p["xattn"], cfg, c["ek"], c["ev"])
        h = L.rmsnorm(x, p["ln2"], cfg.norm_eps, plus_one=cfg.sandwich_norm)
        if cfg.family == "moe":
            mo = L.moe_ffn(h, p["moe"], cfg)
            o = mo.y
            if cfg.dense_residual:
                o = o + L.mlp(h, p["mlp"], cfg)
        else:
            o = L.mlp(h, p["mlp"], cfg)
        if cfg.sandwich_norm:
            o = L.rmsnorm(o, p["ln2_post"], cfg.norm_eps, plus_one=True)
        x = x + o
        new_caches.append(c)

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    W = (params["embed"].T if cfg.tie_embeddings
         else params["lm_head"]).astype(x.dtype)
    logits = (x[:, 0, :] @ W).astype(jnp.float32)
    logits = L.softcap(logits, cfg.logit_softcap)
    return logits, new_caches


def prefill(params, cfg: ModelConfig, tokens, cache_len: int,
            frontend=None, src=None):
    """Fill caches by running the train-style forward and extracting K/V.

    Simple reference implementation used by the serving example: runs
    attention layers one by one (unrolled) so each layer's K/V can be
    written into its cache.
    """
    B, S = tokens.shape
    caches = init_cache(cfg, B, cache_len,
                        src_len=src.shape[1] if src is not None else 0)
    x = embed_tokens(params, cfg, tokens)
    if cfg.frontend and frontend is not None:
        fx = frontend.astype(x.dtype) @ params["frontend_proj"].astype(x.dtype)
        x = jnp.concatenate([fx, x], axis=1)
    enc_out = None
    if cfg.enc_layers and src is not None:
        src_x = src.astype(x.dtype)
        if "frontend_proj" in params:
            src_x = src_x @ params["frontend_proj"].astype(x.dtype)
        e, _, _ = _stack(src_x, params["encoder"], cfg,
                         lambda h, p, k: _encoder_layer(h, p, cfg), False)
        enc_out = L.rmsnorm(e, params["enc_norm"], cfg.norm_eps)
    S_all = x.shape[1]
    pos = jnp.arange(S_all)
    kinds = cfg.layer_kinds() if cfg.family != "ssm" else [0] * cfg.n_layers
    for i in range(cfg.n_layers):
        p = _take_layer(params["layers"], i)
        c = caches[i]
        h = L.rmsnorm(x, p["ln1"], cfg.norm_eps, plus_one=cfg.sandwich_norm)
        if cfg.family in ("ssm", "hybrid"):
            hs = h
            xz = hs @ p["ssm"]["in_proj"].astype(x.dtype)
            x1 = xz[..., :cfg.d_inner]
            conv_in = jax.nn.silu(L._causal_conv(
                x1, p["ssm"]["conv_w"], p["ssm"]["conv_b"], cfg.ssm_conv))
            dt, Bm, Cm, A, D = L._ssm_inputs(conv_in, p["ssm"], cfg)
            y, hfin = L.mamba_scan(conv_in, dt, Bm, Cm, A, D,
                                   jnp.zeros((B, cfg.d_inner, cfg.ssm_state),
                                             jnp.float32), cfg.ssm_chunk)
            y = y.astype(x.dtype) * jax.nn.silu(xz[..., cfg.d_inner:])
            s_out = y @ p["ssm"]["out_proj"].astype(x.dtype)
            c["h"] = hfin
            c["conv"] = x1[:, S_all - (cfg.ssm_conv - 1):, :]
        if cfg.family == "ssm":
            x = x + s_out
            continue
        # attention with cache write
        q, kk, vv = L._qkv(h, p["attn"], cfg)
        q = L.rope(q, pos, cfg.rope_theta)
        kk = L.rope(kk, pos, cfg.rope_theta)
        o = L.blockwise_attention(q, kk, vv, pos, pos, cfg, kinds[i])
        o = jnp.einsum("bsx,xd->bsd", o, p["attn"]["wo"].astype(x.dtype))
        Ci = c["k"].shape[1]
        take = min(Ci, S_all)
        c["k"] = c["k"].at[:, :take].set(kk[:, S_all - take:])
        c["v"] = c["v"].at[:, :take].set(vv[:, S_all - take:])
        c["pos"] = c["pos"].at[:, :take].set(pos[None, S_all - take:])
        if cfg.family == "hybrid":
            o = 0.5 * (o + s_out)
        if cfg.sandwich_norm:
            o = L.rmsnorm(o, p["ln1_post"], cfg.norm_eps, plus_one=True)
        x = x + o
        if cfg.enc_layers and enc_out is not None:
            ek = jnp.einsum("bsd,dnh->bsnh", enc_out,
                            p["xattn"]["wk"].astype(x.dtype))
            ev = jnp.einsum("bsd,dnh->bsnh", enc_out,
                            p["xattn"]["wv"].astype(x.dtype))
            c["ek"], c["ev"] = ek, ev
            hx = L.rmsnorm(x, p["ln_x"], cfg.norm_eps)
            x = x + L.cross_attention(hx, p["xattn"], cfg, ek, ev)
        h = L.rmsnorm(x, p["ln2"], cfg.norm_eps, plus_one=cfg.sandwich_norm)
        if cfg.family == "moe":
            mo = L.moe_ffn(h, p["moe"], cfg)
            o = mo.y + (L.mlp(h, p["mlp"], cfg) if cfg.dense_residual else 0)
        else:
            o = L.mlp(h, p["mlp"], cfg)
        if cfg.sandwich_norm:
            o = L.rmsnorm(o, p["ln2_post"], cfg.norm_eps, plus_one=True)
        x = x + o

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    W = (params["embed"].T if cfg.tie_embeddings
         else params["lm_head"]).astype(x.dtype)
    logits = (x[:, -1, :] @ W).astype(jnp.float32)
    return L.softcap(logits, cfg.logit_softcap), caches
