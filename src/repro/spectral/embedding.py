"""Fiedler vectors and k-dimensional spectral embeddings on the solver.

SF-GRASS (arXiv 2008.07633) motivates spectral embeddings as the
quality-defining application of a sparsifier: the smallest nontrivial
Laplacian eigenpairs drive partitioning, clustering, and drawing.  This
module computes them as a *thin consumer* of the solver service —
block inverse power iteration where every "apply ``L^+``" is one batched
service solve against the cached V-cycle-preconditioned PCG, so the
existing hierarchy is the only preconditioner involved.

The iteration (host-orchestrated, f64):

  1. start from a seeded random block, deflated against the all-ones
     nullspace vector and orthonormalized;
  2. solve ``L Y = X`` through the service (one ``[n, k]`` request — one
     flush group), re-deflate, re-orthonormalize;
  3. Rayleigh-Ritz: diagonalize the small projected operator
     ``Q^T L Q`` and rotate the block onto the Ritz vectors (this is the
     LOBPCG-style acceleration — clustered eigenvalues converge as a
     subspace, not one by one);
  4. stop when every column's residual ``||L v - θ v||`` (``v`` unit) is
     under ``tol``.

Deflation against ones is exact by construction: the service centers
every solution into ``range(L)``, and the host loop re-centers after each
orthonormalization, so the trivial eigenvector can never re-enter the
block through round-off.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import numpy as np

from repro.core.graph import Graph
from repro.obs import get_tracer
from repro.solver.requests import GraphHandle, SolveRequest
from repro.spectral.resistance import _service_of


@dataclasses.dataclass(frozen=True)
class EmbeddingResult:
    """``k`` smallest nontrivial Laplacian eigenpairs (approximate).

    Attributes:
      vectors:     ``[n, k]`` orthonormal, mean-zero Ritz vectors
                   (ascending eigenvalue order; column 0 is the Fiedler
                   vector).
      values:      ``[k]`` Ritz values ``θ_j ≈ λ_{j+1}(L)``.
      residuals:   ``[k]`` final ``||L v_j - θ_j v_j||_2`` (unit ``v_j``).
      iterations:  outer inverse-iteration steps taken.
      solve_iters: total PCG iterations across all service solves.
      converged:   every residual ≤ the requested tolerance.
    """

    vectors: np.ndarray
    values: np.ndarray
    residuals: np.ndarray
    iterations: int
    solve_iters: int
    converged: bool


def spectral_embedding(svc, graph: Union[Graph, GraphHandle], k: int = 2, *,
                       tol: float = 1e-4, max_iterations: int = 100,
                       solve_tol: float = 1e-8, seed: int = 0,
                       oversample: int = 2, pipeline=None,
                       result_timeout: Optional[float] = None,
                       **submit_kw) -> EmbeddingResult:
    """The ``k``-dimensional spectral embedding of ``graph`` via the
    service's V-cycle-preconditioned solver.

    ``oversample`` extra block columns are iterated and discarded — the
    standard guard for clustered trailing eigenvalues (the block converges
    at the gap *past* the oversampled columns).  ``svc`` may be a
    :class:`~repro.solver.service.SolverService` or a
    :class:`~repro.serve.solver_daemon.SolverDaemon` (``submit_kw``
    forwards e.g. ``tenant=``); ``pipeline`` picks the sparsifier config
    backing the preconditioner per request.
    """
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    service, submit = _service_of(svc)
    handle = service.register(graph)
    g = handle.graph
    n = g.n
    kb = min(k + max(int(oversample), 0), n - 1)
    if k > n - 1:
        raise ValueError(
            f"k={k} nontrivial eigenpairs do not exist on {n} vertices")
    metrics = service.metrics
    tracer = get_tracer()

    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, kb))
    X -= X.mean(axis=0)
    X, _ = np.linalg.qr(X)
    theta = np.zeros(kb)
    resid = np.full(kb, np.inf)
    solve_iters = 0
    it = 0

    with tracer.span("spectral.embedding", n=n, k=k, block=kb) as sp:
        for it in range(1, max_iterations + 1):
            ticket = submit(SolveRequest(graph=handle,
                                         b=X.astype(np.float32),
                                         tol=solve_tol, pipeline=pipeline),
                            **submit_kw)
            res = ticket.result(result_timeout) if result_timeout \
                is not None else ticket.result()
            solve_iters += int(np.sum(res.iters))
            Y = np.asarray(res.x, dtype=np.float64)
            Y -= Y.mean(axis=0)
            Q, _ = np.linalg.qr(Y)
            Q -= Q.mean(axis=0)
            LQ = g.laplacian_matvec(Q)
            A = Q.T @ LQ
            theta, S = np.linalg.eigh(0.5 * (A + A.T))
            X = Q @ S
            R = LQ @ S - X * theta[None, :]
            resid = np.linalg.norm(R, axis=0) / np.maximum(
                np.linalg.norm(X, axis=0), np.finfo(np.float64).tiny)
            if np.all(resid[:k] <= tol):
                break
        converged = bool(np.all(resid[:k] <= tol))
        sp.set(iterations=it, converged=converged,
               max_residual=float(resid[:k].max()))
    metrics.inc("spectral.embedding.runs")
    metrics.observe("spectral.embedding.iterations", it)
    metrics.observe("spectral.embedding.solve_iters", solve_iters)
    if not converged:
        metrics.inc("spectral.embedding.unconverged")
    return EmbeddingResult(
        vectors=X[:, :k], values=theta[:k].copy(),
        residuals=resid[:k].copy(), iterations=it,
        solve_iters=solve_iters, converged=converged)


def fiedler_vector(svc, graph: Union[Graph, GraphHandle], *,
                   tol: float = 1e-4, max_iterations: int = 100,
                   solve_tol: float = 1e-8, seed: int = 0, pipeline=None,
                   **kw) -> Tuple[float, np.ndarray]:
    """``(λ₂, v₂)`` — the algebraic connectivity and Fiedler vector.

    A ``k=1`` :func:`spectral_embedding` (with the default oversampling,
    so near-degenerate λ₂ ≈ λ₃ spectra still converge as a subspace).
    The vector is unit-norm and mean-zero; its sign is arbitrary.
    """
    out = spectral_embedding(svc, graph, k=1, tol=tol,
                             max_iterations=max_iterations,
                             solve_tol=solve_tol, seed=seed,
                             pipeline=pipeline, **kw)
    return float(out.values[0]), out.vectors[:, 0]
