"""Batched effective-resistance queries as a service endpoint.

Effective resistance ``R_eff(u, v) = (e_u - e_v)^T L^+ (e_u - e_v)`` is the
core primitive of spectral perturbation analysis — GRASS (arXiv 1911.04382)
ranks edges by it, and Spielman-Srivastava sampling needs it per edge.  The
identity ``R_eff(u, v) = x_u - x_v`` where ``L x = e_u - e_v`` turns every
query into one Laplacian solve, which is exactly what the solver service
batches: ``q`` queries stack into a ``[n, q]`` RHS block solved by a single
jit'd PCG against the cached hierarchy.

Three layers, thinnest on top:

  * :func:`effective_resistance` — the endpoint.  Accepts a
    :class:`~repro.solver.service.SolverService` *or* a
    :class:`~repro.serve.solver_daemon.SolverDaemon`, dedupes queries
    against a content-keyed :class:`ResistanceCache`, chunks large query
    sets (``chunk`` columns per request), and submits every chunk before
    resolving the first — all chunks of one call share a single flush
    group per ``(graph, config)``.
  * :func:`resistances_via_solver` — the same batched ±e_uv solves against
    a bare ``make_solver`` closure (no service, no cache); the building
    block for pipeline-internal consumers.
  * :func:`exact_offtree_resistances` / :func:`tree_preconditioned_solver`
    — real (not tree-approximated) resistances for the ``er_exact`` score
    stage: the full Laplacian solved with a V-cycle built over the
    *spanning tree* subgraph, so scoring never recurses into the pipeline
    it is configuring.
"""
from __future__ import annotations

import collections
import threading
from typing import Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph
from repro.obs import get_tracer
from repro.solver.requests import GraphHandle, SolveRequest


def _canonical_pairs(pairs) -> np.ndarray:
    """``[q, 2]`` int64 with ``u != v`` kept as given but order-normalized
    (``min, max``) — R_eff is symmetric, so (u, v) and (v, u) must share a
    cache entry and a solve column."""
    p = np.asarray(pairs, dtype=np.int64)
    if p.ndim == 1:
        p = p.reshape(1, 2)
    if p.ndim != 2 or p.shape[1] != 2:
        raise ValueError(f"pairs must be [q, 2] vertex pairs, got shape "
                         f"{p.shape}")
    return np.stack([np.minimum(p[:, 0], p[:, 1]),
                     np.maximum(p[:, 0], p[:, 1])], axis=1)


def pair_rhs(n: int, u: np.ndarray, v: np.ndarray) -> np.ndarray:
    """``[n, q]`` float32 block of ±e_uv columns (+1 at ``u``, −1 at ``v``).

    Each column sums to zero, so it lies in ``range(L)`` exactly — no mass
    is lost to the centering the solver applies anyway.
    """
    q = len(u)
    B = np.zeros((n, q), dtype=np.float32)
    B[np.asarray(u), np.arange(q)] = 1.0
    B[np.asarray(v), np.arange(q)] -= 1.0
    return B


class ResistanceCache:
    """Content-keyed result cache for effective-resistance queries.

    Keys are ``(graph fingerprint, config digest, tol, u, v)`` — a value is
    reusable only under the same graph *content* and the same solve
    contract, which is the same invariant the artifact cache enforces one
    layer down.  Bounded LRU (``max_pairs`` entries, each one float);
    thread-safe so daemon-routed queries may share it.
    """

    def __init__(self, max_pairs: int = 1_000_000):
        self.max_pairs = int(max_pairs)
        self._data: "collections.OrderedDict[tuple, float]" = \
            collections.OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._data)

    def lookup(self, keys) -> list:
        """Per-key ``float`` or ``None``; hits are LRU-refreshed."""
        out = []
        with self._lock:
            for k in keys:
                val = self._data.get(k)
                if val is None:
                    self.misses += 1
                else:
                    self._data.move_to_end(k)
                    self.hits += 1
                out.append(val)
        return out

    def insert(self, keys, values) -> None:
        with self._lock:
            for k, val in zip(keys, values):
                self._data[k] = float(val)
                self._data.move_to_end(k)
            while len(self._data) > self.max_pairs:
                self._data.popitem(last=False)
                self.evictions += 1

    @property
    def stats(self) -> dict:
        return {"pairs": len(self._data), "max_pairs": self.max_pairs,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions}


# Shared default cache: repeated queries for the same (graph, config, tol)
# across call sites hit it without any caller-side plumbing.  Pass an
# explicit ``cache=ResistanceCache(...)`` for isolation (benchmarks do).
_DEFAULT_CACHE = ResistanceCache()


def default_cache() -> ResistanceCache:
    return _DEFAULT_CACHE


def _service_of(svc):
    """The underlying :class:`SolverService` of a service-or-daemon, plus
    the submit callable routing through whichever plane was handed in."""
    inner = getattr(svc, "service", None)
    if inner is not None and hasattr(svc, "max_batch_delay_ms"):
        return inner, svc.submit        # SolverDaemon: async submit plane
    return svc, svc.submit              # SolverService: sync submit plane


def effective_resistance(svc, graph: Union[Graph, GraphHandle], pairs, *,
                         tol: float = 1e-7, maxiter: int = 2000,
                         chunk: int = 256,
                         pipeline=None,
                         cache: Optional[ResistanceCache] = None,
                         result_timeout: Optional[float] = None,
                         **submit_kw) -> np.ndarray:
    """Batched ``R_eff(u, v)`` queries against a solver service or daemon.

    ``pairs`` is ``[q, 2]`` (or a single ``(u, v)``); the return is ``[q]``
    float64 resistances in input order.  Self-pairs are 0 by definition and
    never solved.  Uncached queries are deduped, stacked into ±e_uv RHS
    blocks of ``chunk`` columns, and submitted *before* the first result is
    resolved — on a sync service the first ``result()`` flushes every chunk
    in one flush, and all chunks of one ``(graph, config)`` land in a
    single scheduler group either way.

    ``svc`` may be a :class:`SolverService` (lazy-flush path) or a
    :class:`SolverDaemon` (``submit_kw`` forwards e.g. ``tenant=...``;
    ``result_timeout`` bounds each blocking wait).  ``pipeline`` overrides
    the service-wide config per request, exactly as on ``SolveRequest``.
    """
    service, submit = _service_of(svc)
    handle = service.register(graph)
    p = _canonical_pairs(pairs)
    q = p.shape[0]
    if q and (p.min() < 0 or p.max() >= handle.n):
        raise ValueError(
            f"pair endpoints must be vertex ids in [0, {handle.n}), got "
            f"range [{p.min()}, {p.max()}]")
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    cache = cache if cache is not None else _DEFAULT_CACHE
    config = pipeline if pipeline is not None else service.pipeline
    base = (handle.fingerprint, config.digest(), float(tol))
    metrics = service.metrics
    tracer = get_tracer()

    out = np.zeros(q, dtype=np.float64)
    keys = [base + (int(u), int(v)) for u, v in p]
    cached = cache.lookup(keys)
    todo: "collections.OrderedDict[tuple, list]" = collections.OrderedDict()
    for i, ((u, v), val) in enumerate(zip(p, cached)):
        if u == v:
            out[i] = 0.0
        elif val is not None:
            out[i] = val
            metrics.inc("spectral.resistance.cache_hits")
        else:
            todo.setdefault((int(u), int(v)), []).append(i)
    metrics.inc("spectral.resistance.queries", q)

    with tracer.span("spectral.resistance", pairs=q, misses=len(todo),
                     chunk=chunk) as sp:
        if todo:
            uniq = np.asarray(list(todo), dtype=np.int64)   # [t, 2] deduped
            tickets = []
            for lo in range(0, uniq.shape[0], chunk):
                part = uniq[lo:lo + chunk]
                B = pair_rhs(handle.n, part[:, 0], part[:, 1])
                tickets.append((part, submit(SolveRequest(
                    graph=handle, b=B, tol=tol, maxiter=maxiter,
                    pipeline=pipeline), **submit_kw)))
            metrics.inc("spectral.resistance.requests", len(tickets))
            metrics.inc("spectral.resistance.solved_columns", uniq.shape[0])
            for part, ticket in tickets:
                res = ticket.result(result_timeout) if result_timeout \
                    is not None else ticket.result()
                x = np.asarray(res.x, dtype=np.float64)
                x = x[:, None] if x.ndim == 1 else x
                cols = np.arange(part.shape[0])
                r_vals = x[part[:, 0], cols] - x[part[:, 1], cols]
                cache.insert([base + (int(u), int(v)) for u, v in part],
                             r_vals)
                for (u, v), r in zip(part, r_vals):
                    for i in todo[(int(u), int(v))]:
                        out[i] = r
        sp.set(requests=0 if not todo else
               int(np.ceil(len(todo) / chunk)))
    return out


def resistances_via_solver(solve, n: int, u, v, *, tol: float = 1e-6,
                           maxiter: int = 2000,
                           chunk: int = 512) -> np.ndarray:
    """``R_eff`` for vertex pairs against a bare jit'd solve closure
    (:func:`repro.solver.device_pcg.make_solver` signature) — the
    service-free path used inside the pipeline, chunked so arbitrarily
    many queries never materialize one giant RHS block."""
    u = np.asarray(u, dtype=np.int64)
    v = np.asarray(v, dtype=np.int64)
    q = u.shape[0]
    out = np.zeros(q, dtype=np.float64)
    for lo in range(0, q, chunk):
        uu, vv = u[lo:lo + chunk], v[lo:lo + chunk]
        k = uu.shape[0]
        res = solve(jnp.asarray(pair_rhs(n, uu, vv)),
                    tol=jnp.full((k,), tol, jnp.float32),
                    maxiter=jnp.full((k,), maxiter, jnp.int32))
        x = np.asarray(res.x, dtype=np.float64)
        cols = np.arange(k)
        out[lo:lo + chunk] = x[uu, cols] - x[vv, cols]
    return out


def tree_preconditioned_solver(graph: Graph, in_tree: np.ndarray, *,
                               coarse_n: int = 64):
    """A jit'd solve closure for ``L_G x = b`` preconditioned by a V-cycle
    built over the *spanning tree* subgraph.

    The tree is already in hand when scores are computed (pipeline step 1),
    its hierarchy is cheap (a tree stays ultra-sparse under contraction),
    and — critically — building it runs the pipeline on a graph with zero
    off-tree edges, so the score stage is never re-entered: ``er_exact``
    can use this solver without recursing into itself.
    """
    from repro.solver.device_pcg import ell_laplacian, make_solver
    from repro.solver.hierarchy import build_hierarchy, subgraph

    tree_g = subgraph(graph, np.asarray(in_tree, dtype=bool))
    idx, val = ell_laplacian(graph)      # matvec over the FULL Laplacian
    hier = build_hierarchy(tree_g, coarse_n=coarse_n)
    return make_solver(idx, val, hierarchy=hier)


def exact_offtree_resistances(graph: Graph, in_tree: np.ndarray, u, v, *,
                              tol: float = 1e-6, maxiter: int = 2000,
                              chunk: int = 512) -> np.ndarray:
    """Real ``R_G(u, v)`` for the off-tree edges, via batched solves on the
    spanning-tree-preconditioned solver — the ``er_exact`` score stage's
    engine.  Unlike the tree resistance ``R_T`` (an upper bound that can
    badly over-rank edges shortcut by other off-tree edges), these are the
    true leverage-score resistances of the full graph."""
    with get_tracer().span("spectral.er_exact", m_off=int(len(u))):
        solve = tree_preconditioned_solver(graph, in_tree)
        return resistances_via_solver(solve, graph.n, u, v, tol=tol,
                                      maxiter=maxiter, chunk=chunk)
