"""Spectral graph services — batched workloads on top of the solver plane.

The sparsifier's job is the downstream tasks it accelerates.  This package
hosts those tasks as thin, batched consumers of
:class:`~repro.solver.service.SolverService` /
:class:`~repro.serve.solver_daemon.SolverDaemon`:

  * :mod:`repro.spectral.resistance` — batched effective-resistance
    queries with a content-keyed cache, plus the exact off-tree
    resistances behind the ``score: er_exact`` pipeline stage.
  * :mod:`repro.spectral.embedding`  — Fiedler vectors and k-dimensional
    spectral embeddings by V-cycle-preconditioned block inverse iteration.
  * :mod:`repro.spectral.harmonic`   — harmonic interpolation / label
    propagation via a jit-safe interior/boundary split of ``DeviceGraph``.

Every endpoint emits ``spectral.*`` spans and metrics into the shared
telemetry plane, so service ``stats()`` and exported traces cover the new
workloads with zero extra wiring.
"""
from repro.spectral.embedding import (EmbeddingResult,  # noqa: F401
                                      fiedler_vector, spectral_embedding)
from repro.spectral.harmonic import (HarmonicResult,  # noqa: F401
                                     harmonic_interpolate,
                                     label_propagation,
                                     make_harmonic_solver)
from repro.spectral.resistance import (ResistanceCache,  # noqa: F401
                                       default_cache, effective_resistance,
                                       exact_offtree_resistances, pair_rhs,
                                       resistances_via_solver,
                                       tree_preconditioned_solver)

__all__ = [
    "EmbeddingResult", "fiedler_vector", "spectral_embedding",
    "HarmonicResult", "harmonic_interpolate", "label_propagation",
    "make_harmonic_solver",
    "ResistanceCache", "default_cache", "effective_resistance",
    "exact_offtree_resistances", "pair_rhs", "resistances_via_solver",
    "tree_preconditioned_solver",
]
