"""Harmonic interpolation / label propagation on ``DeviceGraph``.

Given boundary vertices ``B`` with fixed values ``x_B``, the harmonic
extension solves the Dirichlet problem ``L_II x_I = -L_IB x_B`` — the
interior values are weighted averages of their neighbors, the discrete
analogue of a harmonic function.  This is the classic semi-supervised
label-propagation primitive (Zhu-Ghahramani-Lafferty), and it exercises
the sparsifier stack on a task where quality is a *prediction error*, not
an iteration count.

Rather than materializing the interior submatrix (which would need a
data-dependent gather/reindex — hostile to jit), the split is expressed as
a masking projection over the *full* vertex set.  With ``m`` the 0/1
interior indicator and ``x0`` the boundary extension (``x_B`` on ``B``,
zero inside), write ``x = x0 + c`` where ``c`` is interior-supported.  The
correction solves

    A c = b,   A(y) = m · L(m · y) + (1-m) · y,   b = -m · L(x0).

``A`` agrees with ``L_II`` on interior-supported vectors and is the
identity on boundary-supported ones, so it is SPD whenever every connected
component touches the boundary — plain PCG applies, no nullspace centering
(the shared :func:`~repro.solver.device_pcg._pcg_loop` runs with an
identity ``center``).  All shapes are static in ``n``; the boundary set is
a traced ``[n]`` mask, so one compiled closure serves every split of a
graph.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device_graph import DeviceGraph
from repro.core.graph import Graph
from repro.obs import get_metrics, get_tracer
from repro.solver.device_pcg import _pcg_loop


@dataclasses.dataclass(frozen=True)
class HarmonicResult:
    """Solution of one Dirichlet problem.

    Attributes:
      x:         ``[n, k]`` harmonic extension — equals the boundary values
                 on ``B`` exactly (enforced by construction, not by solve
                 accuracy), harmonic inside.
      iters:     ``[k]`` PCG iterations per column.
      relres:    ``[k]`` true relative residuals of the correction system.
      converged: ``[k]`` bool, per-column tolerance met.
    """

    x: np.ndarray
    iters: np.ndarray
    relres: np.ndarray
    converged: np.ndarray


def make_dirichlet_core(dg: DeviceGraph) -> Callable:
    """A jit'd closure ``(interior [n], b [n, k], tol, maxiter)`` running
    PCG on the projected operator ``A`` for an arbitrary interior-supported
    RHS — the refinement-friendly primitive under
    :func:`make_harmonic_solver`."""

    @partial(jax.jit, static_argnames=())
    def solve_correction(interior, b, tol, maxiter):
        m = interior[:, None]
        # Jacobi on the projected operator: true diagonal inside, 1 on the
        # identity-padded boundary rows (guarded — isolated boundary-only
        # rows of a disconnected component would otherwise divide by 0).
        dmod = jnp.maximum(m[:, 0] * dg.diag + (1.0 - m[:, 0]), 1e-30)[:, None]

        def matvec(y):
            return m * dg.laplacian_matvec(m * y) + (1.0 - m) * y

        res = _pcg_loop(matvec, m * b, lambda r: r / dmod, tol, maxiter,
                        colsum=lambda v: jnp.sum(v, axis=0),
                        center=lambda v: v)
        return res._replace(x=m * res.x)

    return solve_correction


def make_harmonic_solver(dg: DeviceGraph) -> Callable:
    """A jit'd closure ``(interior [n], xb [n, k], tol, maxiter)`` solving
    the Dirichlet problem on ``dg`` for any boundary split.

    ``interior`` is a float 0/1 mask (1 = free vertex), ``xb`` carries the
    boundary values on masked-out rows (interior rows of ``xb`` are
    ignored).  Returns the raw device pytree; :func:`harmonic_interpolate`
    is the host-facing wrapper (and adds f64 refinement on top).
    """
    core = make_dirichlet_core(dg)

    @partial(jax.jit, static_argnames=())
    def solve(interior, xb, tol, maxiter):
        m = interior[:, None]
        x0 = (1.0 - m) * xb
        b = -m * dg.laplacian_matvec(x0)
        res = core(interior, b, tol, maxiter)
        return res._replace(x=x0 + res.x)

    return solve


def _host_operator(dg: DeviceGraph, bmask: np.ndarray):
    """f64 numpy ``A`` (and raw ``L``) matvecs of the projected operator —
    the residual oracle for host-side iterative refinement."""
    src = np.asarray(dg.src)
    dst = np.asarray(dg.dst)
    w = np.asarray(dg.weight, dtype=np.float64)[:, None]
    # Recompute the weighted degrees in f64 — ``dg.diag`` is an f32
    # scatter-add whose ~1e-6 rounding would become the accuracy floor of
    # the refined solution (the f32 device solve is only a preconditioner
    # here; the residual oracle defines what "exact" means).
    d = np.zeros((dg.n, 1))
    np.add.at(d, src, w)
    np.add.at(d, dst, w)
    m = (~bmask).astype(np.float64)[:, None]

    def L64(x):
        y = d * x
        np.add.at(y, src, -w * x[dst])
        np.add.at(y, dst, -w * x[src])
        return y

    def A64(y):
        return m * L64(m * y) + (1.0 - m) * y

    return L64, A64, m


def _as_device(graph: Union[Graph, DeviceGraph]) -> DeviceGraph:
    return graph if isinstance(graph, DeviceGraph) \
        else DeviceGraph.from_graph(graph)


def harmonic_interpolate(graph: Union[Graph, DeviceGraph], boundary,
                         values, *, tol: float = 1e-8,
                         maxiter: int = 2000,
                         max_refine: int = 2) -> HarmonicResult:
    """Harmonic extension of ``values`` on ``boundary`` to the whole graph.

    ``boundary`` is a vertex-id array (or ``[n]`` bool mask); ``values`` is
    ``[|B|]`` / ``[|B|, k]`` aligned with it (or ``[n]`` / ``[n, k]`` when
    a mask is given).  Every connected component must contain at least one
    boundary vertex — otherwise the Dirichlet system is singular there.

    The device PCG runs in f32; tolerances below its ~1e-7 floor are
    reached by up to ``max_refine`` rounds of f64 iterative refinement
    (solve, recompute the true residual on the host, re-solve the
    correction) — the same contract the solver service offers.
    """
    dg = _as_device(graph)
    n = dg.n
    boundary = np.asarray(boundary)
    bids = None
    if boundary.dtype == bool:
        if boundary.shape != (n,):
            raise ValueError(f"boundary mask must be [{n}], got "
                             f"{boundary.shape}")
        bmask = boundary
    else:
        bids = boundary.astype(np.int64)
        bmask = np.zeros(n, dtype=bool)
        bmask[bids] = True
    nb = int(bmask.sum())
    if nb == 0:
        raise ValueError("boundary must be nonempty")

    vals = np.asarray(values, dtype=np.float32)
    squeeze = vals.ndim == 1
    if squeeze:
        vals = vals[:, None]
    xb = np.zeros((n, vals.shape[1]), dtype=np.float32)
    if vals.shape[0] == n:
        xb[bmask] = vals[bmask]
    elif bids is not None and vals.shape[0] == bids.shape[0]:
        xb[bids] = vals          # rows align with the ids AS GIVEN
    elif vals.shape[0] == nb:
        xb[bmask] = vals
    else:
        raise ValueError(f"values rows ({vals.shape[0]}) match neither the "
                         f"boundary size ({nb}) nor n ({n})")

    metrics = get_metrics()
    k = xb.shape[1]
    with get_tracer().span("spectral.harmonic", n=n, boundary=nb,
                           k=k) as sp:
        core = make_dirichlet_core(dg)
        L64, A64, m64 = _host_operator(dg, bmask)
        interior = jnp.asarray(~bmask, jnp.float32)
        x0 = (1.0 - m64) * xb.astype(np.float64)
        b64 = -(m64 * L64(x0))
        bn = np.maximum(np.linalg.norm(b64, axis=0),
                        np.finfo(np.float64).tiny)

        c = np.zeros((n, k), dtype=np.float64)
        iters = np.zeros(k, dtype=np.int64)
        relres = np.ones(k)
        passes = 0
        for passes in range(1, max_refine + 2):
            r = b64 - A64(c)
            relres = np.linalg.norm(r, axis=0) / bn
            if np.all(relres <= tol):
                break
            # Per-pass target: the reduction factor still missing, clamped
            # to what one f32 PCG sweep can deliver.
            inner = float(np.clip((tol / max(relres.max(), tol)), 1e-7, 0.5))
            res = core(interior, jnp.asarray(r, jnp.float32),
                       jnp.float32(inner), jnp.int32(maxiter))
            c += np.asarray(res.x, dtype=np.float64)
            iters += np.asarray(res.iters, dtype=np.int64)
        relres = np.linalg.norm(b64 - A64(c), axis=0) / bn
        sp.set(iters=int(iters.max(initial=0)), passes=passes,
               max_relres=float(relres.max(initial=0.0)))
    metrics.inc("spectral.harmonic.solves")
    metrics.inc("spectral.harmonic.columns", k)
    metrics.observe_many("spectral.harmonic.iters", iters.tolist())

    x = x0 + m64 * c
    return HarmonicResult(
        x=x[:, 0] if squeeze else x,
        iters=iters, relres=relres, converged=relres <= tol)


def label_propagation(graph: Union[Graph, DeviceGraph], labeled, labels, *,
                      num_classes: int = None, tol: float = 1e-6,
                      maxiter: int = 2000):
    """Semi-supervised node classification by harmonic extension.

    ``labeled`` are the seed vertex ids, ``labels`` their integer classes.
    Each class becomes a one-hot boundary column; the harmonic extension
    gives every vertex a score per class and the argmax is its prediction.
    Returns ``(pred [n] int64, scores [n, C] float64)``.
    """
    labeled = np.asarray(labeled, dtype=np.int64)
    labels = np.asarray(labels, dtype=np.int64)
    if labeled.shape != labels.shape:
        raise ValueError("labeled ids and labels must align")
    C = int(num_classes) if num_classes is not None else int(labels.max()) + 1
    onehot = np.zeros((labeled.shape[0], C), dtype=np.float32)
    onehot[np.arange(labeled.shape[0]), labels] = 1.0
    res = harmonic_interpolate(graph, labeled, onehot, tol=tol,
                               maxiter=maxiter)
    return np.argmax(res.x, axis=1), res.x
