"""Device-timeline annotations: semantic labels for jit'd solver internals.

Host-side spans (:mod:`repro.obs.trace`) time dispatch, not device
execution — under jit the V-cycle is one opaque XLA computation.  Two
mechanisms put solver semantics back onto device timelines:

  * :func:`named_scope` — ``jax.named_scope`` labels attach to the jaxpr /
    HLO **at trace time** (zero runtime cost, safe inside jit and
    ``shard_map``), so XLA profiles and HLO dumps show ``vcycle.L0.down``
    instead of anonymous fusions.  Always on.
  * :func:`trace_annotation` — ``jax.profiler.TraceAnnotation`` marks the
    host thread's dispatch window in the XLA profiler timeline; gated on
    the repro tracer being enabled so the disabled hot path stays free.

Both degrade to ``contextlib.nullcontext`` when jax lacks the API (or is
absent entirely — this keeps :mod:`repro.obs` importable everywhere).
"""
from __future__ import annotations

import contextlib

from repro.obs.trace import get_tracer


def named_scope(name: str):
    """Trace-time name scope for ops created under it (no runtime cost)."""
    try:
        import jax
        return jax.named_scope(name)
    except Exception:
        return contextlib.nullcontext()


def trace_annotation(name: str):
    """XLA-profiler host annotation around a dispatch; no-op unless the
    repro tracer is enabled."""
    if not get_tracer().enabled:
        return contextlib.nullcontext()
    try:
        import jax
        ta = getattr(jax.profiler, "TraceAnnotation", None)
        return ta(name) if ta is not None else contextlib.nullcontext()
    except Exception:
        return contextlib.nullcontext()


class annotated_span:
    """A tracer span and an XLA TraceAnnotation entered/exited together —
    the host span times the dispatch, the annotation labels the same window
    in the device profiler."""

    def __init__(self, name: str, **attrs):
        self._span = get_tracer().span(name, **attrs)
        self._anno = trace_annotation(name)

    def __enter__(self):
        self._anno.__enter__()
        return self._span.__enter__()

    def __exit__(self, *exc):
        self._span.__exit__(*exc)
        return self._anno.__exit__(*exc)
