"""Unified telemetry plane: span tracing, metrics, device annotations.

Three dependency-light modules, one import surface:

  * :mod:`repro.obs.trace`   — thread-safe span tracer; Chrome trace-event
    (Perfetto) + JSONL export; near-zero-cost no-op when disabled.
  * :mod:`repro.obs.metrics` — namespaced counters / gauges / bounded
    latency histograms with percentile snapshots.
  * :mod:`repro.obs.device`  — ``jax.named_scope`` / ``TraceAnnotation``
    wrappers that put solver semantics on device timelines.

Quick start (see README "Observability"):

    from repro.obs import enable_tracing, get_tracer, get_metrics

    enable_tracing()
    svc.solve(g, b)
    get_tracer().export_chrome("trace.json")   # open in ui.perfetto.dev
    print(svc.stats()["convergence"])          # per-config PCG percentiles
"""
from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               Metrics, get_metrics)
from repro.obs.trace import (NOOP_SPAN, Tracer, disable_tracing,  # noqa: F401
                             enable_tracing, get_tracer, span)

__all__ = [
    "Counter", "Gauge", "Histogram", "Metrics", "get_metrics",
    "NOOP_SPAN", "Tracer", "get_tracer", "span",
    "enable_tracing", "disable_tracing",
]
