"""Metrics registry: counters, gauges, bounded-bucket latency histograms.

One namespaced surface for every number the serving stack used to scatter
across ad-hoc dicts (``SolverService._timing``), per-object counters
(``LRUCache.hits``), and module globals (``cache.HASH_EVENTS``):

    from repro.obs import get_metrics

    m = get_metrics()
    m.counter("cache.mem_hits").inc()
    m.histogram("solver.latency.solve_ms").observe(12.7)
    m.snapshot()   # {"cache.mem_hits": 1,
                   #  "solver.latency.solve_ms": {"count": 1, ..., "p99": 12.7}}

Instruments are created on first touch and keyed by dotted names
(``plane.thing.detail``); re-requesting a name returns the same instrument,
and requesting it as a different type raises (a counter silently read as a
gauge is a bug, not a feature).

Histograms are **bounded**: a fixed geometric bucket grid (default ~19
decades at ~1.26x resolution, covering everything from 1e-12 relative
residuals to 1e7 ms latencies) plus count/sum/min/max — O(1) memory per
histogram regardless of observation count, percentile queries by cumulative
bucket counts with linear interpolation inside the winning bucket.  The
relative error of a percentile is therefore at most one bucket ratio
(~26%), which is the right trade for latency telemetry (the oracle test
asserts this against numpy).

Everything here is stdlib-only and thread-safe (one lock per registry, one
per histogram; counters/gauges take the registry's lock only at creation
and rely on a dedicated lock for mutation).
"""
from __future__ import annotations

import bisect
import math
import threading
from typing import Dict, List, Optional, Sequence, Union

Number = Union[int, float]


class Counter:
    """Monotonically increasing value (float-capable, for ms accumulators)."""

    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: Number = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> Number:
        return self._value


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0
        self._lock = threading.Lock()

    def set(self, v: Number) -> None:
        with self._lock:
            self._value = v

    @property
    def value(self) -> Number:
        return self._value


def default_edges() -> List[float]:
    """Geometric bucket edges 1e-12 .. 1e7, 10 per decade (~1.26x steps)."""
    return [10.0 ** (k / 10.0) for k in range(-120, 71)]


class Histogram:
    """Bounded-bucket histogram with percentile snapshots.

    ``edges`` are the bucket upper bounds (ascending); values above the last
    edge land in an overflow bucket whose "upper bound" is the observed max.
    Negative/zero values clamp into the first bucket (latencies and
    iteration counts are nonnegative by construction).
    """

    __slots__ = ("edges", "_counts", "_count", "_sum", "_min", "_max",
                 "_lock")

    def __init__(self, edges: Optional[Sequence[float]] = None):
        self.edges = list(edges) if edges is not None else default_edges()
        if sorted(self.edges) != self.edges:
            raise ValueError("histogram edges must be ascending")
        self._counts = [0] * (len(self.edges) + 1)
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._lock = threading.Lock()

    def observe(self, v: Number) -> None:
        v = float(v)
        i = bisect.bisect_left(self.edges, v)
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    def observe_many(self, values) -> None:
        for v in values:
            self.observe(v)

    @property
    def count(self) -> int:
        return self._count

    def percentile(self, p: float) -> float:
        """Value at percentile ``p`` (0..100), interpolated within the
        winning bucket; exact at the recorded min/max endpoints."""
        with self._lock:
            return self._percentile_locked(p)

    def _percentile_locked(self, p: float) -> float:
        if self._count == 0:
            return 0.0
        target = (p / 100.0) * self._count
        seen = 0.0
        for i, c in enumerate(self._counts):
            if c == 0:
                continue
            lo = self.edges[i - 1] if i > 0 else min(self._min, self.edges[0])
            hi = self.edges[i] if i < len(self.edges) else self._max
            lo = max(lo, self._min)
            hi = min(hi, self._max)
            if seen + c >= target:
                frac = (target - seen) / c
                return lo + (hi - lo) * max(0.0, min(1.0, frac))
            seen += c
        return self._max

    def snapshot(self) -> dict:
        with self._lock:
            if self._count == 0:
                return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                        "p50": 0.0, "p90": 0.0, "p99": 0.0}
            return {
                "count": self._count,
                "sum": self._sum,
                "min": self._min,
                "max": self._max,
                "p50": self._percentile_locked(50),
                "p90": self._percentile_locked(90),
                "p99": self._percentile_locked(99),
            }


class Metrics:
    """A namespaced instrument registry.

    Use the process-wide default (:func:`get_metrics`) for cross-cutting
    plumbing (pipeline stages, hierarchy builds, content hashes), or a
    private instance (``SolverService`` owns one per service) where
    isolation matters — e.g. two services must not share latency histograms.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: Dict[str, object] = {}

    def _get(self, name: str, cls, *args):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(*args)
                self._instruments[name] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} is a {type(inst).__name__}, "
                    f"requested as {cls.__name__}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  edges: Optional[Sequence[float]] = None) -> Histogram:
        if edges is not None:
            return self._get(name, Histogram, edges)
        return self._get(name, Histogram)

    # convenience one-liners for call sites that don't hold the instrument
    def inc(self, name: str, n: Number = 1) -> None:
        self.counter(name).inc(n)

    def observe(self, name: str, v: Number) -> None:
        self.histogram(name).observe(v)

    def observe_many(self, name: str, values) -> None:
        self.histogram(name).observe_many(values)

    def set_gauge(self, name: str, v: Number) -> None:
        self.gauge(name).set(v)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._instruments)

    def snapshot(self) -> dict:
        """Flat ``{name: value-or-histogram-dict}`` copy of every
        instrument.  Every container in the result is freshly built —
        callers can mutate it freely without corrupting live state."""
        with self._lock:
            items = list(self._instruments.items())
        out = {}
        for name, inst in items:
            if isinstance(inst, Histogram):
                out[name] = inst.snapshot()
            else:
                out[name] = inst.value
        return out

    def reset(self) -> None:
        with self._lock:
            self._instruments.clear()


_GLOBAL = Metrics()


def get_metrics() -> Metrics:
    """The process-wide registry for instrumentation that has no service to
    hang off (pipeline stages, hierarchy builds, distributed recovery,
    content-hash events)."""
    return _GLOBAL
