"""Thread-safe span tracer: nested spans, monotonic clocks, Perfetto export.

The serving stack's timing story used to be ad-hoc ``perf_counter`` deltas
scattered through ``service.py``; this module replaces them with one
structured tracer:

    from repro.obs import get_tracer

    tr = get_tracer()
    tr.enable()
    with tr.span("solver.flush", groups=2):
        with tr.span("solver.solve", k=8):
            ...
    tr.export_chrome("trace.json")     # open in ui.perfetto.dev

Design constraints (all load-bearing for the serving hot path):

  * **Near-zero cost when disabled.**  ``span()`` on a disabled tracer is
    one attribute read returning a shared singleton no-op context manager —
    no allocation, no lock, no clock read.  The solver's warm-solve path is
    instrumented unconditionally, so this is what keeps the <2% overhead
    contract (asserted in ``tests/test_obs.py`` via an allocation spy).
  * **Thread-safe.**  Spans may open/close concurrently from any thread
    (the request plane is headed for a background flusher); the finished-
    event buffer is lock-guarded and per-thread nesting depth lives in
    ``threading.local`` storage.
  * **Monotonic clocks.**  ``time.perf_counter_ns`` throughout — wall-clock
    adjustments can never produce negative durations.
  * **Bounded.**  At most ``max_events`` finished spans are retained;
    overflow increments ``dropped`` instead of growing without limit.

Exports:

  * **Chrome trace-event format** (``to_chrome()`` / ``export_chrome()``) —
    complete ("X") events with microsecond timestamps, viewable in Perfetto
    or ``chrome://tracing``.  Nesting is implicit: events on the same thread
    whose time ranges contain each other render as a flame stack.
  * **JSONL** (``export_jsonl()``) — one event object per line for ad-hoc
    ``jq``/pandas analysis.

This module is dependency-free (stdlib only) by design: the tracer must be
importable from every layer — kernels, pipeline, solver, benches — without
dragging jax or numpy into modules that do not already need them.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional


class _NoopSpan:
    """Shared do-nothing context manager returned by disabled tracers.

    A single module-level instance serves every disabled ``span()`` call, so
    the disabled hot path allocates nothing (``tracer.span(a) is
    tracer.span(b)``).
    """

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NoopSpan":
        return self


NOOP_SPAN = _NoopSpan()


class _DroppedSpan:
    """Context manager for an *unsampled* trace on an enabled tracer.

    Sampling decisions are made at the root span only; everything nested
    under a dropped root must also be dropped, and the no-op singleton
    cannot express that (it does not track enter/exit).  This object
    maintains a per-thread "drop depth" so nested ``span()`` calls know
    they are inside a dropped trace.  It is only ever constructed when
    ``sample_rate < 1.0`` — the always-on and disabled paths never pay
    the allocation.
    """

    __slots__ = ("_tracer",)

    def __init__(self, tracer: "Tracer"):
        self._tracer = tracer

    def __enter__(self) -> "_DroppedSpan":
        tls = self._tracer._tls
        tls.drop_depth = getattr(tls, "drop_depth", 0) + 1
        return self

    def __exit__(self, *exc) -> bool:
        self._tracer._tls.drop_depth -= 1
        return False

    def set(self, **attrs) -> "_DroppedSpan":
        return self


class _Span:
    """A live (entered, not yet exited) span.  Only ever constructed by an
    *enabled* tracer — the allocation spy in the tests counts instances of
    this class to prove the disabled path allocates nothing."""

    __slots__ = ("_tracer", "name", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str,
                 args: Optional[Dict[str, Any]]):
        self._tracer = tracer
        self.name = name
        self.args = args

    def set(self, **attrs) -> "_Span":
        """Attach/override attributes after entry (e.g. a result computed
        inside the span)."""
        if self.args is None:
            self.args = {}
        self.args.update(attrs)
        return self

    def __enter__(self) -> "_Span":
        tls = self._tracer._tls
        tls.depth = getattr(tls, "depth", 0) + 1
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter_ns()
        tls = self._tracer._tls
        depth = getattr(tls, "depth", 1) - 1
        tls.depth = depth
        self._tracer._record(self.name, self._t0, t1 - self._t0, depth,
                             self.args)
        return False


class Tracer:
    """Span recorder with Chrome-trace / JSONL export.

    ``enabled`` gates everything: a disabled tracer's ``span()`` returns the
    shared :data:`NOOP_SPAN` and records nothing.
    """

    def __init__(self, enabled: bool = False, max_events: int = 200_000,
                 sample_rate: float = 1.0):
        """``sample_rate`` keeps 1-in-round(1/rate) *root* spans (depth 0 on
        their thread) and everything nested under them; the other traces are
        dropped wholesale.  The decision is a deterministic counter, not a
        RNG — rate 0.25 records roots 0, 4, 8, ... — so production sampling
        (e.g. 1-in-N daemon flush cycles) is reproducible.  ``1.0`` (the
        default) records everything and skips the sampling machinery
        entirely; the disabled path is unaffected either way."""
        self.enabled = bool(enabled)
        self.max_events = int(max_events)
        sample_rate = float(sample_rate)
        if not 0.0 < sample_rate <= 1.0:
            raise ValueError(
                f"sample_rate wants a fraction in (0, 1], got {sample_rate}")
        self.sample_rate = sample_rate
        self._sample_period = max(1, round(1.0 / sample_rate))
        self._sample_seq = 0
        self.sampled_out = 0   # root spans dropped by the sampler
        self.dropped = 0
        self._pid = os.getpid()
        self._lock = threading.Lock()
        self._events: List[dict] = []
        self._tls = threading.local()

    # -- control -------------------------------------------------------------

    def enable(self) -> "Tracer":
        self.enabled = True
        return self

    def disable(self) -> "Tracer":
        self.enabled = False
        return self

    def set_sample_rate(self, sample_rate: float) -> "Tracer":
        """Reconfigure sampling on a live tracer (see ``__init__``); the
        root-span counter restarts so the next root is always recorded."""
        sample_rate = float(sample_rate)
        if not 0.0 < sample_rate <= 1.0:
            raise ValueError(
                f"sample_rate wants a fraction in (0, 1], got {sample_rate}")
        with self._lock:
            self.sample_rate = sample_rate
            self._sample_period = max(1, round(1.0 / sample_rate))
            self._sample_seq = 0
        return self

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0

    # -- recording -----------------------------------------------------------

    def span(self, name: str, **attrs):
        """Context manager timing a named span; ``**attrs`` become the
        event's ``args``.  The no-op singleton when disabled."""
        if not self.enabled:
            return NOOP_SPAN
        if self._sample_period > 1:
            tls = self._tls
            if getattr(tls, "drop_depth", 0) > 0:
                return _DroppedSpan(self)     # inside a dropped trace
            if getattr(tls, "depth", 0) == 0:
                with self._lock:
                    seq = self._sample_seq
                    self._sample_seq += 1
                if seq % self._sample_period != 0:
                    self.sampled_out += 1
                    return _DroppedSpan(self)
        return _Span(self, name, attrs or None)

    def instant(self, name: str, **attrs) -> None:
        """A zero-duration marker event (Chrome "i" phase).  Instants inside
        a sampled-out trace are dropped with it."""
        if not self.enabled:
            return
        tls = self._tls
        if getattr(tls, "drop_depth", 0) > 0:
            return
        self._record(name, time.perf_counter_ns(), None,
                     getattr(tls, "depth", 0), attrs or None)

    def _record(self, name: str, t0_ns: int, dur_ns: Optional[int],
                depth: int, args: Optional[Dict[str, Any]]) -> None:
        ev = {"name": name, "ts_ns": t0_ns, "tid": threading.get_ident(),
              "depth": depth}
        if dur_ns is not None:
            ev["dur_ns"] = dur_ns
        if args:
            ev["args"] = args
        with self._lock:
            if len(self._events) >= self.max_events:
                self.dropped += 1
                return
            self._events.append(ev)

    # -- introspection / export ----------------------------------------------

    def events(self) -> List[dict]:
        """Snapshot copy of the finished-span buffer (oldest first)."""
        with self._lock:
            return [dict(ev) for ev in self._events]

    def span_names(self) -> List[str]:
        with self._lock:
            return [ev["name"] for ev in self._events]

    def durations_ms(self, name: str) -> List[float]:
        """All recorded durations (ms) of spans named ``name``."""
        with self._lock:
            return [ev["dur_ns"] / 1e6 for ev in self._events
                    if ev["name"] == name and "dur_ns" in ev]

    def to_chrome(self) -> dict:
        """Chrome trace-event JSON object (Perfetto-loadable).

        Complete ("X") events carry microsecond ``ts``/``dur``; instants map
        to thread-scoped "i" events.  All events share this process's pid.
        """
        trace_events = []
        for ev in self.events():
            out = {
                "name": ev["name"],
                "ph": "X" if "dur_ns" in ev else "i",
                "ts": ev["ts_ns"] / 1e3,
                "pid": self._pid,
                "tid": ev["tid"],
            }
            if "dur_ns" in ev:
                out["dur"] = ev["dur_ns"] / 1e3
            else:
                out["s"] = "t"
            if "args" in ev:
                out["args"] = {k: _jsonable(v) for k, v in ev["args"].items()}
            trace_events.append(out)
        return {"traceEvents": trace_events, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def export_chrome(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path

    def export_jsonl(self, path: str) -> str:
        with open(path, "w") as f:
            for ev in self.events():
                if "args" in ev:
                    ev = dict(ev, args={k: _jsonable(v)
                                        for k, v in ev["args"].items()})
                f.write(json.dumps(ev) + "\n")
        return path


def _jsonable(v):
    """Coerce span attributes to JSON-safe scalars (numpy ints/floats and
    arbitrary objects degrade to ``str``)."""
    if isinstance(v, (str, bool)) or v is None:
        return v
    if isinstance(v, (int, float)):
        return v
    try:
        import numbers
        if isinstance(v, numbers.Integral):
            return int(v)
        if isinstance(v, numbers.Real):
            return float(v)
    except Exception:
        pass
    return str(v)


# -- process-wide default tracer ---------------------------------------------

_GLOBAL = Tracer(
    enabled=os.environ.get("REPRO_TRACE", "0") not in ("", "0", "false"))


def get_tracer() -> Tracer:
    """The process-wide tracer every instrumented module records into.
    Disabled by default; enable with :func:`enable_tracing` or by setting
    ``REPRO_TRACE=1`` in the environment before import."""
    return _GLOBAL


def enable_tracing(sample_rate: Optional[float] = None) -> Tracer:
    """Enable the process-wide tracer; ``sample_rate`` (optional) installs
    1-in-N root-span sampling for always-on production tracing."""
    if sample_rate is not None:
        _GLOBAL.set_sample_rate(sample_rate)
    return _GLOBAL.enable()


def disable_tracing() -> Tracer:
    return _GLOBAL.disable()


def span(name: str, **attrs):
    """Module-level convenience: a span on the process-wide tracer."""
    return _GLOBAL.span(name, **attrs)
