"""Distributed substrate: gradient compression + parameter sharding specs.

Split out of the trainer so the launch dry-run and the serving stack can
reuse the same sharding rules without importing training code.
"""
from repro.dist import compress, sharding  # noqa: F401
