"""int8 gradient compression with error feedback (DCN-crossing gradients).

Cross-pod gradient all-reduce rides the DCN, which is ~50x slower per byte
than ICI — int8 quantization cuts that traffic 4x vs f32.  Plain
quantization biases training; error feedback (Seide et al. 2014, Karimireddy
et al. 2019) keeps the *accumulated* compressed gradient unbiased: each step
adds the previous step's quantization error back in before quantizing, so
errors telescope instead of compounding.

    g_q, ef = compress_grads(grads, ef)     # tree-structured, jit-safe

The error-feedback state is stored in bfloat16: the residual is at most one
quantization step, so bf16's 8 mantissa bits lose nothing that matters while
halving the state's memory.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Quantized(NamedTuple):
    q: jnp.ndarray       # int8 payload
    scale: jnp.ndarray   # f32 per-tensor max-abs scale


def quantize(x: jnp.ndarray) -> Quantized:
    """Symmetric per-tensor int8: q = round(x / scale * 127)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round(x / scale * 127.0), -127, 127).astype(jnp.int8)
    return Quantized(q=q, scale=scale.astype(jnp.float32))


def dequantize(z: Quantized) -> jnp.ndarray:
    return z.q.astype(jnp.float32) * (z.scale / 127.0)


def init_error_feedback(params):
    """Zero residual state, one bf16 buffer per parameter."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)


def _compress_one(g, e):
    total = g.astype(jnp.float32) + e.astype(jnp.float32)
    y = dequantize(quantize(total))
    return y, (total - y).astype(jnp.bfloat16)


def compress_grads(grads, ef_state):
    """Quantize-dequantize every gradient leaf with error feedback.

    Returns (compressed f32 gradient tree, new bf16 error tree).  Invariant
    (tested): sum over steps of compressed grads + final error == sum of
    true grads, up to bf16 rounding of the residual.
    """
    pairs = jax.tree.map(_compress_one, grads, ef_state)
    outer = jax.tree.structure(grads)
    inner = jax.tree.structure((0, 0))
    return jax.tree.transpose(outer, inner, pairs)
