"""FSDP + tensor-parallel PartitionSpecs for the model parameter tree.

Rules are keyed on parameter *names* (the stacked-layer trees of
``models/model.py``), with divisibility guards so the same rules work on
any mesh: a dim is only sharded when its size divides the axis size, and
falls back to replication otherwise.

Mesh axes (see ``launch/mesh.py``):
  * 'model'        — tensor parallel (heads / ffn / expert dims),
  * 'data' (+'pod') — FSDP: parameters sharded over the data axes on their
    largest remaining dim, all-gathered per layer at use time.
"""
from __future__ import annotations

import numpy as np
from jax import tree_util
from jax.sharding import PartitionSpec as P

# TP over the *last* dim (output-expanding projections).
_TP_LAST = {"w1", "w3", "router", "in_proj", "x_proj", "lm_head",
            "frontend_proj"}
# TP over the head dim [..., d, heads, hd] (QKV projections).
_TP_HEAD = {"wq", "wk", "wv"}
# TP over dim -2 (input-contracting projections; output needs a psum).
_TP_IN = {"wo", "w2", "out_proj", "dt_proj"}
# MoE tensors carry a leading [layers, experts, ...] pair.
_MOE = {"w1", "w2", "w3", "router"}


def _fsdp_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def param_pspecs(params, mesh, expert_shard: bool = False):
    """PartitionSpec tree for ``params`` (arrays or ShapeDtypeStructs).

    ``expert_shard=True`` shards MoE expert tensors over 'model' on the
    expert dim (expert parallel) instead of their ffn dim.
    """
    fsdp = _fsdp_axes(mesh)
    fsdp_size = int(np.prod([mesh.shape[a] for a in fsdp])) if fsdp else 1
    fsdp_spec = fsdp if len(fsdp) > 1 else (fsdp[0] if fsdp else None)
    tp_size = int(mesh.shape.get("model", 1))

    def rule(path, x):
        name = str(getattr(path[-1], "key", path[-1]))
        in_moe = any(str(getattr(k, "key", k)) == "moe" for k in path)
        dims = [None] * x.ndim
        if x.ndim < 2:
            return P()  # norms / biases / scalars: replicate

        # -- tensor parallel dim --------------------------------------------
        tp_dim = None
        if tp_size > 1:
            if in_moe and expert_shard and name in _MOE and x.ndim >= 3:
                tp_dim = 1                     # [layers, E, ...] expert dim
            elif name in _TP_HEAD and x.ndim >= 3:
                tp_dim = x.ndim - 2
            elif name in _TP_LAST:
                tp_dim = x.ndim - 1
            elif name in _TP_IN and x.ndim >= 2:
                tp_dim = x.ndim - 2
            elif name == "embed":
                tp_dim = 0                     # vocab-sharded embedding
            if tp_dim is not None and x.shape[tp_dim] % tp_size == 0:
                dims[tp_dim] = "model"
            else:
                tp_dim = None

        # -- FSDP dim: largest remaining divisible dim (skip the layer-stack
        #    leading dim of per-layer tensors so scan slicing stays local) ---
        if fsdp and fsdp_size > 1:
            start = 1 if x.ndim >= 3 else 0
            cands = [d for d in range(start, x.ndim)
                     if d != tp_dim and x.shape[d] % fsdp_size == 0
                     and x.shape[d] >= fsdp_size]
            if cands:
                best = max(cands, key=lambda d: x.shape[d])
                dims[best] = fsdp_spec
        return P(*dims)

    return tree_util.tree_map_with_path(rule, params)
