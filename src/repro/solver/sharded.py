"""Mesh-sharded solve plane: row-sharded batched PCG + Chebyshev V-cycle.

The single-device solve plane (:mod:`repro.solver.device_pcg`) caps the
"millions of users" target at one accelerator's HBM.  This module runs the
*same* algorithms under ``shard_map`` on the mesh that
:mod:`repro.core.distributed` already uses for recovery, so one mesh covers
sparsify + precondition + solve end to end:

  * **Row sharding.** Every level's ELL slabs — and every solve vector —
    are row-sharded over the mesh axis (``P(axis, None)``), padded so the
    axis size divides the row count.  Padding rows are self-loops of weight
    zero: a zero operator block that provably never leaks into the live
    rows (their matvec output is zero and nothing gathers from them).
  * **Halo matvec.** The ELL column indices are rewritten *per shard* into
    local coordinates at closure-build time: targets inside the shard's own
    row block index the local slab directly, remote targets index a
    precomputed per-shard **halo** list (the sorted unique remote rows that
    shard's slab actually references).  The exchange itself is one
    ``all_gather`` of the sharded ``x`` followed by a local halo gather —
    on a real mesh the halo bounds what each shard touches, and the
    transport can specialize to a neighborhood exchange without changing
    the slab layout.
  * **Collective reductions.** PCG dot products and norms are local partial
    sums + ``psum``; centering (the Laplacian nullspace projection) masks
    the padding rows and divides by the *true* row count.
  * **Sharded V-cycle.** Restriction is a local segment-sum into the full
    coarse vector + ``psum`` (then each shard keeps its own coarse block);
    prolongation is an ``all_gather`` + aggregation-tree gather; the tiny
    coarsest Cholesky solve is replicated on every shard.  Smoother
    coefficients (per-level Chebyshev spectral radius) are estimated on the
    *unsharded* slabs at build time, so the sharded cycle applies the
    identical polynomial — which is what keeps per-column iteration counts
    within noise of the single-device solver.

:func:`make_sharded_solver` returns a closure with the exact signature of
:func:`repro.solver.device_pcg.make_solver`'s product — ``solve(b [n, k],
tol, maxiter) -> BatchedPCGResult`` on *global* arrays — so the service
swaps it in purely by passing ``SolverService(mesh=...)``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.graph_ops import shard_map_compat
from repro.kernels import ops
from repro.obs import get_tracer
from repro.obs.device import named_scope
from repro.solver.device_pcg import (BatchedPCGResult, _pcg_loop,
                                     estimate_dinv_rho_device,
                                     make_chebyshev_smoother, make_matvec)
from repro.solver.hierarchy import Hierarchy


class ShardedSlab(NamedTuple):
    """Row-sharded ELL slabs with per-shard local coordinates.

    ``idx`` entries are *local*: ``t < n_loc`` addresses the shard's own
    row ``t``; ``t >= n_loc`` addresses slot ``t - n_loc`` of the shard's
    halo.  ``halo`` is flat ``[n_sh * H]`` (``P(axis)`` hands each shard
    its ``[H]`` slice of global row ids to gather after the all_gather).
    """

    idx: jnp.ndarray    # [n_pad, L] int32 local coords
    val: jnp.ndarray    # [n_pad, L] f32
    halo: jnp.ndarray   # [n_sh * H] int32 global row ids


class SlabMeta(NamedTuple):
    n: int        # true row count
    n_pad: int    # padded row count (divisible by n_sh)
    n_loc: int    # rows per shard
    halo: int     # halo slots per shard


class ShardedLevel(NamedTuple):
    """One sharded V-cycle level: slabs + smoother diagonal + aggregation."""

    slab: ShardedSlab
    diag: jnp.ndarray   # [n_pad] f32, 1.0 on padding rows
    agg: jnp.ndarray    # [n_pad] int32 coarse ids; nc_pad on padding rows


class LevelMeta(NamedTuple):
    slab: SlabMeta
    rho: float          # Chebyshev spectral-radius bound (unsharded estimate)
    nc: int             # true coarse row count
    nc_pad: int
    nc_loc: int


def shard_ell_slabs(idx, val, n_sh: int):
    """Host-side prep: global ELL slabs -> (:class:`ShardedSlab` arrays,
    :class:`SlabMeta`).

    Pads rows to a multiple of ``n_sh`` with weight-zero self-loops, then
    rewrites every shard's column indices into [own rows | halo] local
    coordinates.  The halo of shard ``s`` is the sorted unique set of
    global rows outside its block that its slab references — precomputed
    once here, gathered on every matvec.
    """
    idx = np.asarray(idx)
    val = np.asarray(val)
    n, L = idx.shape
    n_loc = -(-n // n_sh)
    n_pad = n_loc * n_sh
    idx_g = np.empty((n_pad, L), np.int32)
    val_p = np.zeros((n_pad, L), val.dtype)
    idx_g[:n] = idx
    val_p[:n] = val
    idx_g[n:] = np.arange(n, n_pad, dtype=np.int32)[:, None]

    halos = []
    for s in range(n_sh):
        r0 = s * n_loc
        blk = idx_g[r0:r0 + n_loc]
        own = (blk >= r0) & (blk < r0 + n_loc)
        halos.append(np.unique(blk[~own]))
    H = max(1, max(h.shape[0] for h in halos))
    halo = np.empty((n_sh, H), np.int32)
    idx_l = np.empty_like(idx_g)
    for s, h in enumerate(halos):
        r0 = s * n_loc
        halo[s, :h.shape[0]] = h
        halo[s, h.shape[0]:] = r0          # own row: never referenced
        blk = idx_g[r0:r0 + n_loc]
        own = (blk >= r0) & (blk < r0 + n_loc)
        idx_l[r0:r0 + n_loc] = np.where(
            own, blk - r0, n_loc + np.searchsorted(h, blk))
    slab = ShardedSlab(idx=jnp.asarray(idx_l), val=jnp.asarray(val_p),
                       halo=jnp.asarray(halo.reshape(-1)))
    return slab, SlabMeta(n=n, n_pad=n_pad, n_loc=n_loc, halo=H)


def _prep_level(lev, n_sh: int):
    """One hierarchy level -> (:class:`ShardedLevel`, :class:`LevelMeta`,
    device rho estimate).  The meta's ``rho`` is a placeholder: the caller
    batches every level's device estimate into one ``device_get`` and
    patches the metas, instead of blocking once per level here."""
    slab, meta = shard_ell_slabs(lev.idx, lev.val, n_sh)
    diag = np.ones((meta.n_pad,), np.float32)
    diag[:meta.n] = np.asarray(lev.diag, np.float32)
    nc_loc = -(-lev.n_coarse // n_sh)
    nc_pad = nc_loc * n_sh
    agg = np.full((meta.n_pad,), nc_pad, np.int32)   # pad rows: dropped
    agg[:meta.n] = np.asarray(lev.agg, np.int32)
    rho_dev = estimate_dinv_rho_device(
        make_matvec(lev.idx, lev.val, "ref"), lev.diag)
    return (ShardedLevel(slab=slab, diag=jnp.asarray(diag),
                         agg=jnp.asarray(agg)),
            LevelMeta(slab=meta, rho=0.0, nc=lev.n_coarse,
                      nc_pad=nc_pad, nc_loc=nc_loc),
            rho_dev)


def _local_matvec(slab_loc: ShardedSlab, axis: str, impl: str = "ref",
                  tile_n: int = 256, interpret=None):
    """Sharded ELL matvec ``[n_loc, k] -> [n_loc, k]`` for shard_map bodies:
    one all_gather of the sharded ``x``, a halo gather, a local contraction.

    ``impl="fused"`` contracts each shard's slab with the batched-RHS
    Pallas kernel (:func:`repro.kernels.ops.spmv_batched`) instead of the
    jnp einsum.  Fusion on the sharded plane stops at the per-shard
    contraction: the halo ``all_gather`` between successive matvecs is a
    collective, so the Chebyshev sweep cannot fuse across matvecs the way
    the single-device :func:`~repro.kernels.vcycle_fused.make_fused_chebyshev`
    kernel does — the smoother stays composed from fused local matvecs.
    """
    def mv(x_loc):
        xg = jax.lax.all_gather(x_loc, axis, tiled=True)     # [n_pad, k]
        x_ext = jnp.concatenate([x_loc, xg[slab_loc.halo]], axis=0)
        if impl == "fused":
            return ops.spmv_batched(slab_loc.idx, slab_loc.val, x_ext,
                                    tile_n=tile_n, interpret=interpret)
        return jnp.einsum("nl,nlk->nk", slab_loc.val, x_ext[slab_loc.idx])

    return mv


def make_sharded_solver(idx, val, hierarchy: Optional[Hierarchy] = None,
                        precond: str = "hierarchy", *, mesh,
                        shard_axis: str = "data",
                        degree: int = 2, matvec_impl: str = "ref",
                        tile_n: int = 256, interpret=None):
    """Build the jit'd mesh-sharded ``solve(b, tol, maxiter)`` closure.

    Same contract as :func:`repro.solver.device_pcg.make_solver`: global
    ``[n, k]`` right-hand sides in, :class:`BatchedPCGResult` out (mean-zero
    solutions, per-column iteration counts, true relative residuals).  The
    matvec is the local-slab contraction of :func:`_local_matvec`;
    ``matvec_impl="fused"`` swaps in the batched-RHS Pallas kernel for each
    shard's local contraction (see :func:`_local_matvec` for why sharded
    fusion stops at the per-shard matvec).  ``precond`` supports
    ``"hierarchy"`` and ``"none"``; ``"jacobi"`` is a single-device
    comparison baseline and is not sharded.
    """
    if matvec_impl not in ("ref", "fused"):
        raise ValueError(
            f"sharded matvec_impl must be 'ref' or 'fused', got "
            f"{matvec_impl!r}")
    if precond == "hierarchy" and hierarchy is None:
        raise ValueError("precond='hierarchy' needs a Hierarchy")
    if precond == "jacobi":
        raise NotImplementedError(
            "precond='jacobi' is a single-device comparison baseline — "
            "the sharded path supports 'hierarchy' and 'none'")
    if precond not in ("hierarchy", "none"):
        raise ValueError(f"unknown precond {precond!r}")
    axis = shard_axis
    n_sh = int(mesh.shape[axis])
    n = int(np.asarray(idx).shape[0])

    tracer = get_tracer()
    with tracer.span("sharded.shard_slabs", n=n, n_sh=n_sh):
        top_slab, top_meta = shard_ell_slabs(idx, val, n_sh)
    levels: tuple = ()
    level_meta: tuple = ()
    coarse_chol = None
    coarse_n = n
    if precond == "hierarchy":
        with tracer.span("sharded.prep_levels",
                         levels=len(hierarchy.levels), n_sh=n_sh):
            prepped = [_prep_level(lev, n_sh) for lev in hierarchy.levels]
        levels = tuple(p[0] for p in prepped)
        # the ONE designated build-time sync: all level rho estimates in a
        # single device_get (they queue and overlap on device)
        rhos = jax.device_get([p[2] for p in prepped])
        level_meta = tuple(p[1]._replace(rho=float(r))
                           for p, r in zip(prepped, rhos))
        coarse_chol = hierarchy.coarse_chol
        coarse_n = hierarchy.coarse_n
    ncs_loc = -(-coarse_n // n_sh)
    ncs_pad = ncs_loc * n_sh
    n_levels = len(levels)
    have_chol = coarse_chol is not None
    if not have_chol:
        coarse_chol = jnp.zeros((1, 1), jnp.float32)  # placeholder arg

    def _colsum(x_loc):
        return jax.lax.psum(jnp.sum(x_loc, axis=0), axis)

    def _pcenter(x_loc):
        """Mean-zero projection over the TRUE rows (padding masked out);
        the constant shift lands on padding rows too, harmlessly — they
        are sliced away on the way out."""
        my = jax.lax.axis_index(axis)
        rows = my * top_meta.n_loc + jnp.arange(top_meta.n_loc,
                                                dtype=jnp.int32)
        valid = (rows < n)[:, None]
        s = jax.lax.psum(
            jnp.sum(jnp.where(valid, x_loc, 0.0), axis=0), axis)
        return x_loc - s / n

    def _core(b_loc, tol, maxiter, top_loc, levels_loc, chol):
        k = b_loc.shape[1]
        matvec = _local_matvec(top_loc, axis, matvec_impl, tile_n, interpret)

        # -- preconditioner ------------------------------------------------
        lev_mvs = [_local_matvec(ll.slab, axis, matvec_impl, tile_n,
                                 interpret) for ll in levels_loc]
        smoothers = [make_chebyshev_smoother(mv, ll.diag, lm.rho,
                                             degree=degree)
                     for mv, ll, lm in zip(lev_mvs, levels_loc, level_meta)]

        def coarse_solve(r_loc):
            rg = jax.lax.all_gather(r_loc, axis, tiled=True)[:coarse_n]
            if not have_chol:                # single-vertex coarse graph
                return jnp.zeros_like(r_loc)
            y = jax.scipy.linalg.cho_solve((chol, True), rg[1:])
            z = jnp.concatenate([jnp.zeros_like(rg[:1]), y], axis=0)
            z = z - jnp.mean(z, axis=0, keepdims=True)
            zp = jnp.zeros((ncs_pad, k), r_loc.dtype).at[:coarse_n].set(z)
            my = jax.lax.axis_index(axis)
            return jax.lax.dynamic_slice_in_dim(zp, my * ncs_loc, ncs_loc)

        def cycle(l, r_loc):
            if l == n_levels:
                with named_scope("sharded_vcycle.coarse"):
                    return coarse_solve(r_loc)
            ll, lm = levels_loc[l], level_meta[l]
            mv, smooth = lev_mvs[l], smoothers[l]
            with named_scope(f"sharded_vcycle.L{l}.down"):
                z = smooth(r_loc)                             # pre-smooth
                resid = r_loc - mv(z)
                rc = jax.lax.psum(                            # restrict
                    jnp.zeros((lm.nc_pad, k), r_loc.dtype)
                    .at[ll.agg].add(resid, mode="drop"), axis)
                my = jax.lax.axis_index(axis)
                rc_loc = jax.lax.dynamic_slice_in_dim(
                    rc, my * lm.nc_loc, lm.nc_loc)
            zc = cycle(l + 1, rc_loc)                         # coarse correct
            with named_scope(f"sharded_vcycle.L{l}.up"):
                zc_full = jax.lax.all_gather(zc, axis, tiled=True)
                z = z + zc_full[jnp.minimum(ll.agg, lm.nc_pad - 1)]  # prolong
                return smooth(r_loc, z)                       # post-smooth

        if precond == "hierarchy":
            def msolve(r_loc):
                return _pcenter(cycle(0, r_loc))
        else:
            def msolve(r_loc):
                return r_loc

        # the SAME while_loop as the single-device plane — only the column
        # reduction (psum) and the centering (pad-masked) differ, so
        # per-column iteration counts agree up to f32 reduction-order noise
        res = _pcg_loop(matvec, b_loc, msolve, tol, maxiter,
                        colsum=_colsum, center=_pcenter)
        return res.x, res.iters, res.relres, res.converged

    slab_spec = ShardedSlab(idx=P(axis, None), val=P(axis, None),
                            halo=P(axis))
    level_spec = tuple(
        ShardedLevel(slab=slab_spec, diag=P(axis), agg=P(axis))
        for _ in range(n_levels))
    in_specs = (P(axis, None), P(), P(), slab_spec, level_spec, P())
    out_specs = (P(axis, None), P(), P(), P())

    sharded = shard_map_compat(
        _core, mesh=mesh, in_specs=in_specs, out_specs=out_specs)

    n_pad = top_meta.n_pad

    @jax.jit
    def solve(b, tol=1e-5, maxiter=2000):
        b = b - jnp.mean(b, axis=0, keepdims=True)
        k = b.shape[1]
        bp = jnp.zeros((n_pad, k), b.dtype).at[:n].set(b)
        tol_a = jnp.broadcast_to(jnp.asarray(tol, b.dtype), (k,))
        mi_a = jnp.broadcast_to(jnp.asarray(maxiter, jnp.int32), (k,))
        x, iters, relres, conv = sharded(bp, tol_a, mi_a, top_slab,
                                         levels, coarse_chol)
        return BatchedPCGResult(x=x[:n], iters=iters, relres=relres,
                                converged=conv)

    return solve
