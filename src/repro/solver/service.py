"""Request/response Laplacian solve engine with slot batching.

The serving counterpart of ``serve/engine.py`` for the pdGRASS pipeline:
clients submit (graph, rhs) requests; the service groups pending requests
by graph fingerprint, builds (or cache-hits) the sparsifier hierarchy + ELL
slabs once per graph, stacks all right-hand sides of a group into one
``[n, k]`` batch, and runs a single jit'd device PCG for the whole group.

    svc = SolverService(alpha=0.05)
    t0 = svc.submit(SolveRequest(graph=g, b=b0))
    t1 = svc.submit(SolveRequest(graph=g, b=b1))
    responses = svc.flush()          # one batched solve for both tickets

RHS batches are padded to the next power of two so the jit cache sees a
handful of shapes instead of one per request count (the slot idiom of the
LM engine: fixed slots, variable occupancy).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph
from repro.pipeline import PipelineConfig, pdgrass_config
from repro.solver.cache import LRUCache, pipeline_fingerprint
from repro.solver.device_pcg import (default_matvec_impl, ell_laplacian,
                                     make_solver)
from repro.solver.hierarchy import build_hierarchy


@dataclasses.dataclass
class SolveRequest:
    graph: Graph
    b: np.ndarray            # [n] or [n, k]
    tol: float = 1e-5
    maxiter: int = 2000


@dataclasses.dataclass
class SolveResponse:
    x: np.ndarray            # same trailing shape as the request's b
    iters: np.ndarray        # [k] per-column PCG iterations (all passes)
    relres: np.ndarray       # [k] f64-measured true relative residuals
    converged: bool
    cache: str               # "mem" | "disk" | "miss" (artifacts source)
    refinements: int         # mixed-precision refinement passes taken
    setup_ms: float          # hierarchy+ELL build (0.0 on a cache hit path)
    solve_ms: float


def _next_pow2(k: int) -> int:
    p = 1
    while p < k:
        p *= 2
    return p


class SolverService:
    """Cached, batched sparsifier-preconditioned Laplacian solver."""

    def __init__(self, alpha: Optional[float] = None,
                 precond: str = "hierarchy",
                 coarse_n: int = 64, cache_capacity: int = 16,
                 disk_dir: Optional[str] = None,
                 matvec_impl: Optional[str] = None, tile_n: int = 256,
                 max_refine: int = 3,
                 pipeline: Optional[PipelineConfig] = None):
        """``pipeline`` selects the sparsification pipeline backing the
        preconditioner (any family member — pdGRASS, feGRASS, custom stage
        mixes); when omitted, a pdGRASS config is built from ``alpha``
        (default 0.05).  Passing both is a conflict: alpha lives inside the
        config."""
        if pipeline is not None and alpha is not None:
            raise ValueError(
                "pass either alpha or pipeline, not both — alpha is "
                "pipeline.alpha (use pipeline.replace(alpha=...))")
        self.pipeline = (pipeline if pipeline is not None
                         else pdgrass_config(
                             alpha=0.05 if alpha is None else alpha,
                             chunk=512))
        self.alpha = self.pipeline.alpha
        self.precond = precond
        self.coarse_n = coarse_n
        self.max_refine = max_refine
        self.matvec_impl = matvec_impl or default_matvec_impl()
        self.tile_n = tile_n
        self.cache = LRUCache(capacity=cache_capacity, disk_dir=disk_dir)
        # fingerprint -> jit'd solve closure, LRU-bounded (see _solver_for)
        self._solvers: "collections.OrderedDict[str, object]" = \
            collections.OrderedDict()
        self._pending: List[SolveRequest] = []

    # -- artifact plane ------------------------------------------------------

    def _key(self, graph: Graph) -> str:
        return pipeline_fingerprint(graph, self.pipeline, extra=(
            "solver-v3", self.precond, self.coarse_n))

    def artifacts(self, graph: Graph, key: Optional[str] = None):
        """(idx, val, hierarchy), source — cached pipeline steps 1-4 and the
        multilevel chain, keyed by (graph content, PipelineConfig, precond).

        ``key`` lets callers that already fingerprinted the graph skip the
        second O(m) hash."""
        if key is None:
            key = self._key(graph)

        def build():
            idx, val = ell_laplacian(graph)
            hier = (build_hierarchy(graph, config=self.pipeline,
                                    coarse_n=self.coarse_n)
                    if self.precond == "hierarchy" else None)
            return idx, val, hier

        value, source = self.cache.get_or_build(key, build)
        return key, value, source

    def _solver_for(self, key: str, artifacts):
        """jit'd solve closures are process-local (not picklable), so they
        live beside — not inside — the artifact cache, LRU-bounded to the
        same capacity (each closure retains device arrays + executables)."""
        fn = self._solvers.get(key)
        if fn is None:
            idx, val, hier = artifacts
            fn = make_solver(idx, val, hierarchy=hier, precond=self.precond,
                             matvec_impl=self.matvec_impl, tile_n=self.tile_n)
            self._solvers[key] = fn
        self._solvers.move_to_end(key)
        while len(self._solvers) > self.cache.capacity:
            self._solvers.popitem(last=False)
        return fn

    # -- request plane -------------------------------------------------------

    @staticmethod
    def _validate(request: SolveRequest) -> None:
        b = np.asarray(request.b)
        if b.ndim not in (1, 2) or b.shape[0] != request.graph.n:
            raise ValueError(
                f"rhs shape {b.shape} does not match graph with "
                f"{request.graph.n} vertices (want [n] or [n, k])")

    def submit(self, request: SolveRequest) -> int:
        """Queue a request; returns a ticket resolved by the next flush()."""
        self._validate(request)
        self._pending.append(request)
        return len(self._pending) - 1

    def flush(self) -> Dict[int, SolveResponse]:
        """Solve everything pending — one batched PCG per distinct graph."""
        pending, self._pending = self._pending, []
        return self._solve_batch(pending)

    def solve(self, graph: Graph, b: np.ndarray, tol: float = 1e-5,
              maxiter: int = 2000) -> SolveResponse:
        """Convenience single-request path.  Does NOT touch the pending
        queue — other submitted tickets stay queued for the next flush()."""
        req = SolveRequest(graph=graph, b=b, tol=tol, maxiter=maxiter)
        self._validate(req)
        return self._solve_batch([req])[0]

    def _solve_batch(self, pending: List[SolveRequest]) -> Dict[int, SolveResponse]:
        groups: Dict[str, List[int]] = {}
        for ticket, req in enumerate(pending):
            groups.setdefault(self._key(req.graph), []).append(ticket)

        out: Dict[int, SolveResponse] = {}
        for key, tickets in groups.items():
            reqs = [pending[t] for t in tickets]
            g = reqs[0].graph

            t0 = time.perf_counter()
            _, artifacts, source = self.artifacts(g, key=key)
            setup_ms = (time.perf_counter() - t0) * 1e3
            solve = self._solver_for(key, artifacts)

            cols, owner = [], []          # owner[j] = (ticket, col-in-request)
            for t, req in zip(tickets, reqs):
                b = np.asarray(req.b, dtype=np.float32)
                b = b[:, None] if b.ndim == 1 else b
                for j in range(b.shape[1]):
                    cols.append(b[:, j])
                    owner.append((t, j))
            k = len(cols)
            k_pad = _next_pow2(k)
            B = np.zeros((g.n, k_pad), np.float32)
            B[:, :k] = np.stack(cols, axis=1)
            # L is singular with nullspace = constants: only the mean-zero
            # component of b is solvable.  Center here so the residual
            # measurement below targets the solvable system (else the
            # unsolvable mean would read as non-convergence).
            B -= B.mean(axis=0)
            # Per-column tolerance and iteration budget: each request keeps
            # its own contract even when batched with stricter/larger
            # neighbors (pad columns inherit the group extremes; their zero
            # RHS converges instantly regardless).
            tol_col = np.full(k_pad, min(r.tol for r in reqs))
            maxiter_col = np.full(k_pad, max(r.maxiter for r in reqs),
                                  np.int32)
            for j, (t, _) in enumerate(owner):
                tol_col[j] = pending[t].tol
                maxiter_col[j] = pending[t].maxiter
            # The f32 device solve floors around 1e-7 relative residual; ask
            # it only for what it can deliver and let the f64 refinement
            # passes close the rest (each pass multiplies the true residual
            # by ~inner_tol).  Per column: a loose-tol request batched with
            # a strict one stops at its own contract instead of riding along
            # to the group minimum.
            inner_tol = jnp.asarray(
                np.maximum(tol_col, 1e-5).astype(np.float32))

            t0 = time.perf_counter()
            res = solve(jnp.asarray(B), tol=inner_tol,
                        maxiter=jnp.asarray(maxiter_col))
            x = np.asarray(res.x, dtype=np.float64)
            iters = np.asarray(res.iters).copy()

            # Mixed-precision iterative refinement: the f32 device solve hits
            # its attainable-accuracy floor on large/ill-conditioned graphs,
            # so measure the true residual in f64 on the host and re-solve
            # for the correction on the device until tol is genuinely met.
            # The residual matvec runs over the Graph's own CSR arrays
            # (numpy f64, no scipy on the solve path).
            B64 = B.astype(np.float64)
            bn = np.maximum(np.linalg.norm(B64, axis=0),
                            np.finfo(np.float64).tiny)
            refinements = 0
            resid = B64 - g.laplacian_matvec(x)
            relres = np.linalg.norm(resid, axis=0) / bn
            while refinements < self.max_refine and np.any(relres > tol_col):
                rc = resid - resid.mean(axis=0)
                # corrections draw from each column's remaining budget
                corr = solve(jnp.asarray(rc.astype(np.float32)),
                             tol=inner_tol,
                             maxiter=jnp.asarray(np.maximum(
                                 maxiter_col - iters, 0)))
                x_new = x + np.asarray(corr.x, dtype=np.float64)
                resid_new = B64 - g.laplacian_matvec(x_new)
                relres_new = np.linalg.norm(resid_new, axis=0) / bn
                # accept per column whenever the correction improved it ...
                take = relres_new < relres
                x = np.where(take, x_new, x)
                resid = np.where(take, resid_new, resid)
                halved = np.any(relres_new < 0.5 * relres)
                relres = np.where(take, relres_new, relres)
                iters = iters + np.asarray(corr.iters)
                refinements += 1
                if not halved:
                    break  # ... but stop once passes stall at the f32 floor
            solve_ms = (time.perf_counter() - t0) * 1e3
            conv = relres <= tol_col
            for t, req in zip(tickets, reqs):
                mine = [j for j, (tt, _) in enumerate(owner) if tt == t]
                xs = x[:, mine]
                if np.asarray(req.b).ndim == 1:
                    xs = xs[:, 0]
                out[t] = SolveResponse(
                    x=xs, iters=iters[mine], relres=relres[mine],
                    converged=bool(conv[mine].all()), cache=source,
                    refinements=refinements, setup_ms=setup_ms,
                    solve_ms=solve_ms)
        return out
