"""Request/response Laplacian solve engine with slot batching.

The serving counterpart of ``serve/engine.py`` for the pdGRASS pipeline,
v2 request plane (handles / tickets / per-request configs):

    svc = SolverService(pipeline=pdgrass_config(alpha=0.05))
    h = svc.register(g)                       # content hash paid ONCE
    t0 = svc.submit(SolveRequest(graph=h, b=b0))
    t1 = svc.submit(SolveRequest(graph=h, b=b1,
                                 pipeline=fegrass_config(alpha=0.05)))
    svc.flush()                               # one flush, two groups
    x0, x1 = t0.result().x, t1.result().x     # resolvable in any order

The scheduler groups pending requests by ``(graph_fingerprint,
config_fingerprint)``: all right-hand sides of a group stack into one
``[n, k]`` batch served by a single jit'd device PCG against that group's
cached hierarchy, so pdGRASS- and feGRASS-preconditioned requests for the
same mesh coexist in one flush and each hit the right artifacts.
``warmup(handle, configs=[...])`` prefetches artifacts + solver closures
ahead of traffic; ``stats()`` snapshots the cache, store, scheduler, and
per-config solve counters.

RHS batches are padded to the next power of two so the jit cache sees a
handful of shapes instead of one per request count (the slot idiom of the
LM engine: fixed slots, variable occupancy).

v1 compatibility: ``submit``/``solve`` still accept raw ``Graph``s (they
are registered on the fly), tickets subclass ``int`` so ``flush()[ticket]``
indexing keeps working, and ticket ids are service-wide monotonic — stable
across flushes instead of per-flush list positions.
"""
from __future__ import annotations

import collections
import copy
import os
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph
from repro.obs import Metrics, get_metrics, get_tracer
from repro.obs.device import trace_annotation
from repro.pipeline import PipelineConfig, pdgrass_config
from repro.pipeline import validate as validate_config
from repro.solver import cache as cache_mod
from repro.solver.cache import LRUCache, artifact_key, mesh_descriptor
from repro.solver.device_pcg import (default_matvec_impl, ell_laplacian,
                                     make_solver)
from repro.solver.hierarchy import build_hierarchy
from repro.solver.requests import (AdmissionError, GraphHandle, GraphStore,
                                   SolveRequest, SolveResponse, SolveTicket)

# artifact schema tag: bump on layout changes
# v5: device-resident hierarchy contraction (propose/accept matching) +
#     Chebyshev-smoothed V-cycle; the contraction mode joins the key extras
# v6: mesh-sharded solve plane — the mesh descriptor (axis name, shard
#     count; None when single-device) joins the key extras, and
#     contraction="sharded" is a distinct mode.  v5 on-disk entries miss
#     cleanly and rebuild.
# v7: Pallas-fused V-cycle — ``matvec_impl`` ("fused" / "kernel" / "ref")
#     joins the key extras so fused- and unfused-built artifacts never
#     alias even though the hierarchy arrays are identical today (the key
#     must cover everything that shaped the cached value, and future fused
#     builds may bake kernel-specific layouts).  v6 on-disk entries miss
#     cleanly and rebuild.
_SCHEMA = "solver-v7"


def _next_pow2(k: int) -> int:
    p = 1
    while p < k:
        p *= 2
    return p


class SolverService:
    """Cached, batched sparsifier-preconditioned Laplacian solver."""

    def __init__(self, alpha: Optional[float] = None,
                 precond: str = "hierarchy",
                 coarse_n: int = 64, cache_capacity: int = 16,
                 disk_dir: Optional[str] = None,
                 disk_max_entries: Optional[int] = None,
                 disk_max_bytes: Optional[int] = None,
                 matvec_impl: Optional[str] = None, tile_n: int = 256,
                 max_refine: int = 3,
                 pipeline: Optional[PipelineConfig] = None,
                 store: Optional[GraphStore] = None,
                 store_max_entries: Optional[int] = None,
                 store_max_bytes: Optional[int] = None,
                 contraction: Optional[str] = None,
                 max_pending_columns: Optional[int] = None,
                 mesh=None, shard_axis: str = "data",
                 metrics: Optional[Metrics] = None,
                 interpret: Optional[bool] = None):
        """``pipeline`` selects the default sparsification pipeline backing
        the preconditioner (any family member — pdGRASS, feGRASS, custom
        stage mixes); individual requests may override it with
        ``SolveRequest(pipeline=...)``.  When omitted, a pdGRASS config is
        built from ``alpha`` (default 0.05).  Passing both is a conflict:
        alpha lives inside the config.  ``store`` shares a
        :class:`GraphStore` between services;
        ``store_max_entries``/``store_max_bytes`` cap the default store's
        persisted ``graphstore/`` tier (mtime-LRU eviction, mirroring the
        artifact ``disk_max_*`` caps) and are a conflict with an explicit
        ``store`` — caps live on the store you build.

        ``contraction`` selects the hierarchy-build matching path
        (``"device"`` propose/accept rounds, ``"host"`` sequential oracle,
        or ``"sharded"`` mesh-distributed rounds); it participates in the
        artifact fingerprint, so the modes never share cache entries.
        ``max_pending_columns`` bounds the scheduler: a ``submit`` that
        would push the queued RHS column count past the budget raises
        :class:`AdmissionError` instead of growing the next flush without
        limit (``None`` = unbounded).

        ``mesh`` switches the whole solve plane onto a device mesh: the
        hierarchy build contracts with mesh-sharded propose/accept rounds
        (``contraction`` defaults to ``"sharded"``), and the batched PCG +
        V-cycle run row-sharded under ``shard_map`` over ``shard_axis``
        (see :mod:`repro.solver.sharded`).  The mesh descriptor joins the
        artifact cache key (schema v6), so single-device and sharded
        artifacts never alias.

        ``matvec_impl`` selects the solve plane's kernel path — ``"fused"``
        (Pallas-fused V-cycle: batched spmv + fused Chebyshev + fused
        restrict+residual), ``"kernel"`` (per-column Pallas spmv), or
        ``"ref"`` (jnp composition, the parity oracle); ``None``
        auto-selects via :func:`~repro.solver.device_pcg.default_matvec_impl`
        ("fused" when the kernels compile, "ref" under interpret).  The
        impl joins the artifact key (schema v7).  ``interpret`` forces
        Pallas interpret/compiled mode for all kernels this service builds;
        ``None`` resolves from the backend (see
        :func:`repro.kernels.ops.resolve_interpret`)."""
        if pipeline is not None and alpha is not None:
            raise ValueError(
                "pass either alpha or pipeline, not both — alpha is "
                "pipeline.alpha (use pipeline.replace(alpha=...))")
        if contraction is None:
            contraction = "sharded" if mesh is not None else "device"
        if contraction not in ("device", "host", "sharded"):
            raise ValueError(
                f"unknown contraction mode {contraction!r}; "
                f"want 'device', 'host' or 'sharded'")
        if contraction == "sharded" and mesh is None:
            raise ValueError("contraction='sharded' needs a mesh")
        if mesh is not None and precond == "jacobi":
            # fail at construction, not first flush: the sharded plane
            # supports 'hierarchy' and 'none' (jacobi is a single-device
            # comparison baseline)
            raise NotImplementedError(
                "precond='jacobi' is not supported with mesh= — "
                "use precond='hierarchy' or 'none'")
        self.pipeline = (pipeline if pipeline is not None
                         else pdgrass_config(
                             alpha=0.05 if alpha is None else alpha,
                             chunk=512))
        self.alpha = self.pipeline.alpha
        self.precond = precond
        self.coarse_n = coarse_n
        self.contraction = contraction
        self.mesh = mesh
        self.shard_axis = shard_axis
        self.max_refine = max_refine
        self.max_pending_columns = max_pending_columns
        self.matvec_impl = matvec_impl or default_matvec_impl()
        self.tile_n = tile_n
        self.interpret = interpret
        # With a disk tier configured, the default store persists beside it
        # (``<disk_dir>/graphstore/<fingerprint>.npz``): a restarted service
        # rehydrates its handles AND hits the persisted artifacts — no
        # caller re-registers edge arrays, no O(m) re-fingerprints.
        if store is None:
            store = GraphStore(
                persist_dir=(os.path.join(disk_dir, "graphstore")
                             if disk_dir else None),
                max_entries=store_max_entries, max_bytes=store_max_bytes)
        elif store_max_entries is not None or store_max_bytes is not None:
            raise ValueError(
                "store_max_entries/store_max_bytes configure the default "
                "store — with an explicit store=, set the caps on it "
                "(GraphStore(max_entries=..., max_bytes=...))")
        self.store = store
        # Per-service metrics registry (``solver.*`` / ``cache.*``
        # namespaces): two services never share counters, so fresh-service
        # stats start from zero.  Module-level instrumentation (pipeline,
        # hierarchy, distributed) lands in the process-wide registry and is
        # merged into ``stats()["metrics"]`` read-only.
        self.metrics = metrics if metrics is not None else Metrics()
        self.cache = LRUCache(capacity=cache_capacity, disk_dir=disk_dir,
                              disk_max_entries=disk_max_entries,
                              disk_max_bytes=disk_max_bytes,
                              metrics=self.metrics)
        # fingerprint -> jit'd solve closure, LRU-bounded (see _solver_for)
        self._solvers: "collections.OrderedDict[str, object]" = \
            collections.OrderedDict()
        # [(ticket, handle, request)] — the scheduler's input queue.
        # Guarded by _lock: submits may race the daemon's background
        # flusher (and each other) once a SolverDaemon wraps this service.
        self._pending: List[Tuple[SolveTicket, GraphHandle, SolveRequest]] = []
        self._pending_columns = 0
        self._next_ticket = 0
        # Canonical shared-state inventory, machine-checked by
        # repro.analysis.lock_lint: every field below may only be touched
        # inside `with self._lock` or from a *_locked method.
        # lock: self._lock
        #   _pending _pending_columns _next_ticket _sched
        #   _solvers _warmed _timing _conv_digests _solves_by_config
        self._lock = threading.RLock()
        # "submitted" counts admitted requests (rejected ones never enter
        # the queue), so submitted/rejected is the admission split.
        self._sched = {"submitted": 0, "flushes": 0, "groups": 0,
                       "requests_solved": 0, "group_failures": 0,
                       "rejected": 0}
        self._warmed: set = set()   # (key, k_pad) buckets warmup has run
        self._solves_by_config: "collections.Counter[str]" = \
            collections.Counter()
        # cumulative compile-vs-solve wall-time split (ms), see stats()
        self._timing = {"warmup_compile_ms": 0.0, "setup_ms": 0.0,
                        "solve_ms": 0.0}
        # config digests with convergence histograms (see stats())
        self._conv_digests: set = set()

    # -- graph plane ---------------------------------------------------------

    def register(self, graph: Union[Graph, GraphHandle]) -> GraphHandle:
        """Register a graph with the service's store; the returned handle
        carries the memoized content fingerprint, so requests built from it
        never re-hash the edge arrays."""
        return self.store.register(graph)

    # -- artifact plane ------------------------------------------------------

    def _config_for(self, request: SolveRequest) -> PipelineConfig:
        return request.pipeline if request.pipeline is not None \
            else self.pipeline

    def _key(self, handle: GraphHandle, config: PipelineConfig) -> str:
        return artifact_key(handle.fingerprint, config, extra=(
            _SCHEMA, self.precond, self.coarse_n, self.contraction,
            self.matvec_impl,
            mesh_descriptor(self.mesh, self.shard_axis)))

    def artifacts(self, graph: Union[Graph, GraphHandle],
                  key: Optional[str] = None,
                  pipeline: Optional[PipelineConfig] = None):
        """(idx, val, hierarchy), source — cached pipeline steps 1-4 and the
        multilevel chain, keyed by (graph content, PipelineConfig, precond).

        ``pipeline`` defaults to the service-wide config; ``key`` lets the
        scheduler skip recomputing the group key it already holds."""
        handle = self.store.register(graph)
        config = pipeline if pipeline is not None else self.pipeline
        if key is None:
            key = self._key(handle, config)

        def build():
            g = handle.graph
            idx, val = ell_laplacian(g)
            hier = (build_hierarchy(g, config=config, coarse_n=self.coarse_n,
                                    contraction=self.contraction,
                                    mesh=self.mesh,
                                    shard_axis=self.shard_axis)
                    if self.precond == "hierarchy" else None)
            return idx, val, hier

        value, source = self.cache.get_or_build(key, build)
        return key, value, source

    def _solver_for(self, key: str, artifacts):
        """jit'd solve closures are process-local (not picklable), so they
        live beside — not inside — the artifact cache, LRU-bounded to the
        same capacity (each closure retains device arrays + executables)."""
        with self._lock:
            fn = self._solvers.get(key)
            if fn is not None:
                self._solvers.move_to_end(key)
                return fn
        # build OUTSIDE the lock: make_solver stages device arrays and can
        # take a while — holding _lock here would stall every submit
        idx, val, hier = artifacts
        fn = make_solver(idx, val, hierarchy=hier, precond=self.precond,
                         matvec_impl=self.matvec_impl, tile_n=self.tile_n,
                         mesh=self.mesh, shard_axis=self.shard_axis,
                         interpret=self.interpret)
        with self._lock:
            # two racing builders: first insert wins, both get one closure
            fn = self._solvers.setdefault(key, fn)
            self._solvers.move_to_end(key)
            while len(self._solvers) > self.cache.capacity:
                self._solvers.popitem(last=False)
            return fn

    def warmup(self, graph: Union[Graph, GraphHandle],
               configs: Optional[Sequence[PipelineConfig]] = None,
               widths: Optional[Sequence[int]] = None) -> Dict[str, str]:
        """Prefetch artifacts + solver closures for ``graph`` under each
        config (default: the service-wide one) ahead of traffic.  Returns
        ``{config_digest: artifact_source}`` — "miss" means built now,
        "mem"/"disk" mean the cache already held it.

        ``widths`` additionally jit-warms the solve itself: for every
        requested RHS width the corresponding power-of-two slot bucket runs
        one zero-RHS solve (a zero column converges in zero iterations, so
        the cost is pure XLA compilation), moving compile time out of the
        first real flush.  The cumulative compile wall time lands in
        ``stats()["timing"]["warmup_compile_ms"]`` — compare against
        ``timing["solve_ms"]`` for the compile-vs-solve split."""
        handle = self.register(graph)
        sources: Dict[str, str] = {}
        if widths is not None and any(int(w) < 1 for w in widths):
            raise ValueError(f"widths must be >= 1, got {list(widths)}")
        buckets = sorted({_next_pow2(int(w)) for w in (widths or ())})
        tracer = get_tracer()
        for config in (configs if configs is not None else [self.pipeline]):
            validate_config(config)
            key = self._key(handle, config)
            with tracer.span("solver.warmup", config=config.digest(),
                             buckets=buckets):
                _, artifacts, source = self.artifacts(handle, key=key,
                                                      pipeline=config)
                solve = self._solver_for(key, artifacts)
            sources[config.digest()] = source
            for k_pad in buckets:
                # Mirror the flush call signature exactly ([n, k_pad] f32
                # rhs, [k_pad] f32 tol, [k_pad] int32 maxiter) so the jit
                # cache entry compiled here is the one traffic hits.
                size_before = (solve._cache_size()
                               if hasattr(solve, "_cache_size") else None)
                t0 = time.perf_counter()
                res = solve(
                    jnp.zeros((handle.n, k_pad), jnp.float32),
                    tol=jnp.full((k_pad,), 1e-5, jnp.float32),
                    maxiter=jnp.full((k_pad,), 1, jnp.int32))
                jax.block_until_ready(res.x)
                # Book the wall time as compile only when this bucket
                # actually compiled — a re-warmed (or traffic-compiled)
                # bucket is a jit cache hit and must not inflate the split.
                # Without jit cache introspection (older jax), fall back to
                # first-warmup-per-bucket accounting (traffic-compiled
                # buckets may then book once; re-warms never double-count).
                compile_ms = (time.perf_counter() - t0) * 1e3
                with self._lock:
                    compiled = (solve._cache_size() > size_before
                                if size_before is not None
                                else (key, k_pad) not in self._warmed)
                    self._warmed.add((key, k_pad))
                    if compiled:
                        self._timing["warmup_compile_ms"] += compile_ms
                if compiled:
                    self.metrics.observe("solver.warmup.compile_ms",
                                         compile_ms)
                    self.metrics.inc("solver.warmup.compiles")
        return sources

    # -- request plane -------------------------------------------------------

    @staticmethod
    def _validate(request: SolveRequest) -> None:
        g = request.graph.graph if isinstance(request.graph, GraphHandle) \
            else request.graph
        b = np.asarray(request.b)
        if b.ndim not in (1, 2) or b.shape[0] != g.n:
            raise ValueError(
                f"rhs shape {b.shape} does not match graph with "
                f"{g.n} vertices (want [n] or [n, k])")
        # Validate in the f32 dtype the device solve actually runs in: this
        # catches NaN/inf in the input AND f64 magnitudes that overflow to
        # inf on the cast (both would silently poison the PCG iteration and
        # read back as non-convergence).
        with np.errstate(over="ignore"):
            finite = np.isfinite(b.astype(np.float32, copy=False)
                                 if b.dtype != np.float32 else b)
        if not finite.all():
            bad = int(b.size - finite.sum())
            raise ValueError(
                f"rhs contains {bad} value(s) that are non-finite in the "
                f"f32 solve precision (NaN/inf, or magnitude > f32 max) — "
                f"clean or rescale the rhs before submitting")
        if request.pipeline is not None:
            if not isinstance(request.pipeline, PipelineConfig):
                raise TypeError(
                    f"request.pipeline wants a PipelineConfig, got "
                    f"{type(request.pipeline).__name__}")
            validate_config(request.pipeline)
        if request.deadline_ms is not None and not request.deadline_ms > 0:
            raise ValueError(
                f"deadline_ms must be positive, got {request.deadline_ms}")

    def submit(self, request: SolveRequest) -> SolveTicket:
        """Queue a request; returns a :class:`SolveTicket` future resolved
        by the next flush() (or by ``ticket.result()``, which flushes).

        With ``max_pending_columns`` set, a submit whose RHS columns would
        push the queue past the budget raises :class:`AdmissionError`
        (counted in ``stats()["scheduler"]["rejected"]``) — backpressure
        instead of an unbounded flush."""
        self._validate(request)
        shape = np.shape(request.b)   # no copy — b may be device-resident
        cols = 1 if len(shape) == 1 else int(shape[1])
        handle = self.store.register(request.graph)
        with self._lock:
            if (self.max_pending_columns is not None
                    and self._pending_columns + cols
                    > self.max_pending_columns):
                self._sched["rejected"] += 1
                self.metrics.inc("solver.rejected")
                raise AdmissionError(self._pending_columns, cols,
                                     self.max_pending_columns)
            ticket = SolveTicket(self._next_ticket, service=self,
                                 request=request)
            self._next_ticket += 1
            self._sched["submitted"] += 1
            self._pending.append((ticket, handle, request))
            self._pending_columns += cols
        self.metrics.inc("solver.submitted")
        return ticket

    def _new_ticket(self, request: SolveRequest,
                    handle: Optional[GraphHandle] = None,
    ) -> Tuple[SolveTicket, GraphHandle]:
        """Validate + register + allocate a service-wide ticket id WITHOUT
        queueing: the entry point for external schedulers (the async daemon
        keeps its own fairness-ordered queue and hands batches straight to
        :meth:`_solve_batch`).  The ticket carries no service back-ref, so
        ``result()`` never triggers a caller-thread flush."""
        self._validate(request)
        if handle is None:
            handle = self.store.register(request.graph)
        with self._lock:
            ticket = SolveTicket(self._next_ticket, service=None,
                                 request=request)
            self._next_ticket += 1
        return ticket, handle

    def _has_pending(self, ticket: SolveTicket) -> bool:
        """Identity membership in the pending queue (``result()`` uses this
        to distinguish a flushable ticket from a stale/foreign one)."""
        with self._lock:
            return any(t is ticket for t, _, _ in self._pending)

    def flush(self) -> Dict[SolveTicket, SolveResponse]:
        """Solve everything pending — one batched PCG per distinct
        (graph, pipeline-config) group."""
        with self._lock:
            pending, self._pending = self._pending, []
            self._pending_columns = 0
            self._sched["flushes"] += 1
        self.metrics.inc("solver.flushes")
        with get_tracer().span("solver.flush", requests=len(pending)):
            return self._solve_batch(pending)

    def solve(self, graph: Union[Graph, GraphHandle], b: np.ndarray,
              tol: float = 1e-5, maxiter: int = 2000,
              pipeline: Optional[PipelineConfig] = None) -> SolveResponse:
        """Convenience single-request path.  Does NOT touch the pending
        queue — other submitted tickets stay queued for the next flush()."""
        req = SolveRequest(graph=graph, b=b, tol=tol, maxiter=maxiter,
                           pipeline=pipeline)
        ticket, handle = self._new_ticket(req)
        out = self._solve_batch([(ticket, handle, req)])
        if ticket not in out:      # single group: surface its failure
            raise ticket.error()
        return out[ticket]

    def stats(self) -> dict:
        """Snapshot of the serving planes: artifact cache (+ disk tier),
        graph store, scheduler counters, and per-config solve counts
        (keyed by ``PipelineConfig.digest()``).  ``store.hash_events``
        counts the O(m) content hashes this service's store triggered
        (``process_hash_events`` is the process-wide total) — traffic over
        registered graphs keeps both flat.

        Telemetry keys (see README "Observability"):

        * ``"metrics"`` — the flat namespaced registry: this service's
          ``solver.*`` / ``cache.*`` instruments merged over the
          process-wide ``pipeline.*`` / ``hierarchy.*`` / ``dist.*`` /
          ``store.hash_events`` ones (the namespaces are disjoint, so the
          merge never shadows).
        * ``"convergence"`` — per config digest: PCG iteration-count and
          final-relative-residual histograms plus setup/solve latency
          percentiles, observed once per flush group.

        The returned dict is a **deep copy**: callers may mutate it freely
        (diffing, annotating, json round-trips) without corrupting the
        service's live counters."""
        with self._lock:
            digests = sorted(self._conv_digests)
        convergence = {}
        for d in digests:
            convergence[d] = {
                "iters": self.metrics.histogram(
                    f"solver.pcg.iters.{d}").snapshot(),
                "relres": self.metrics.histogram(
                    f"solver.pcg.relres.{d}").snapshot(),
                "setup_ms": self.metrics.histogram(
                    f"solver.latency.setup_ms.{d}").snapshot(),
                "solve_ms": self.metrics.histogram(
                    f"solver.latency.solve_ms.{d}").snapshot(),
            }
        with self._lock:
            return copy.deepcopy({
                "cache": self.cache.stats,
                "store": {**self.store.stats,
                          "process_hash_events": cache_mod.HASH_EVENTS},
                "scheduler": {**self._sched, "pending": len(self._pending),
                              "pending_columns": self._pending_columns,
                              "max_pending_columns": self.max_pending_columns},
                "solves_by_config": dict(self._solves_by_config),
                "solvers": {"jit_closures": len(self._solvers),
                            "capacity": self.cache.capacity},
                "hierarchy": {"contraction": self.contraction,
                              "precond": self.precond},
                "mesh": {"descriptor": mesh_descriptor(self.mesh,
                                                       self.shard_axis)},
                "timing": dict(self._timing),
                "metrics": {**get_metrics().snapshot(),
                            **self.metrics.snapshot()},
                "convergence": convergence,
            })

    # -- scheduler -----------------------------------------------------------

    def _solve_batch(
        self, pending: List[Tuple[SolveTicket, GraphHandle, SolveRequest]],
    ) -> Dict[SolveTicket, SolveResponse]:
        groups: Dict[Tuple[str, str], List[int]] = {}
        keys: Dict[Tuple[str, str], str] = {}
        for i, (_, handle, req) in enumerate(pending):
            config = self._config_for(req)
            gid = (handle.fingerprint, config.fingerprint())
            if gid not in keys:
                keys[gid] = self._key(handle, config)
            groups.setdefault(gid, []).append(i)
        with self._lock:
            self._sched["groups"] += len(groups)
        self.metrics.inc("solver.groups", len(groups))

        # Groups fail independently: an exception while building or solving
        # one (graph, config) group fails only that group's tickets (their
        # result() re-raises it) — every other group still solves and
        # resolves.  A serving flush must never lose unrelated tickets.
        out: Dict[SolveTicket, SolveResponse] = {}
        for gid, members in groups.items():
            entries = [pending[i] for i in members]
            config = self._config_for(entries[0][2])
            try:
                solved = self._solve_group(entries, config, keys[gid])
            except Exception as e:
                with self._lock:
                    self._sched["group_failures"] += 1
                self.metrics.inc("solver.group_failures")
                for ticket, _, _ in entries:
                    ticket._fail(e)
                continue
            with self._lock:
                self._sched["requests_solved"] += len(entries)
                self._solves_by_config[config.digest()] += len(entries)
            self.metrics.inc("solver.requests_solved", len(entries))
            out.update(solved)
        return out

    def _solve_group(
        self, entries: List[Tuple[SolveTicket, GraphHandle, SolveRequest]],
        config: PipelineConfig, key: str,
    ) -> Dict[SolveTicket, SolveResponse]:
        """Build/fetch one (graph, config) group's artifacts and run its
        slot-batched solve, resolving every ticket in the group."""
        handle = entries[0][1]
        g = handle.graph
        config_digest = config.digest()
        tracer = get_tracer()
        with tracer.span("solver.group", config=config_digest,
                         n=g.n, requests=len(entries)) as group_span:
            return self._solve_group_inner(
                entries, config, key, g, config_digest, tracer, group_span)

    def _solve_group_inner(self, entries, config, key, g, config_digest,
                           tracer, group_span):
        """Body of :meth:`_solve_group`, factored out so the whole group —
        artifact fetch, batched solve, refinement — nests under one
        ``solver.group`` span."""
        handle = entries[0][1]
        with tracer.span("solver.artifacts", config=config_digest) as asp:
            t0 = time.perf_counter()
            _, artifacts, source = self.artifacts(handle, key=key,
                                                  pipeline=config)
            setup_ms = (time.perf_counter() - t0) * 1e3
            solve = self._solver_for(key, artifacts)
            asp.set(source=source)

        cols, owner = [], []       # owner[j] = (entry-idx, col-in-request)
        for e, (_, _, req) in enumerate(entries):
            b = np.asarray(req.b, dtype=np.float32)
            b = b[:, None] if b.ndim == 1 else b
            for j in range(b.shape[1]):
                cols.append(b[:, j])
                owner.append((e, j))
        k = len(cols)
        k_pad = _next_pow2(k)
        B = np.zeros((g.n, k_pad), np.float32)
        B[:, :k] = np.stack(cols, axis=1)
        # L is singular with nullspace = constants: only the mean-zero
        # component of b is solvable.  Center here so the residual
        # measurement below targets the solvable system (else the
        # unsolvable mean would read as non-convergence).
        B -= B.mean(axis=0)
        # Per-column tolerance and iteration budget: each request keeps
        # its own contract even when batched with stricter/larger
        # neighbors.  Padding columns are inert BY CONSTRUCTION — tol=inf
        # and maxiter=0 mean they can never drive batched_pcg's while-loop
        # (done from iteration zero) nor the refinement pass (zero
        # remaining budget, relres 0 <= inf), independent of the separate
        # zero-RHS short-circuit.
        reqs = [req for _, _, req in entries]
        tol_col = np.full(k_pad, np.inf)
        maxiter_col = np.zeros(k_pad, np.int32)
        for j, (e, _) in enumerate(owner):
            tol_col[j] = reqs[e].tol
            maxiter_col[j] = reqs[e].maxiter
        # The f32 device solve floors around 1e-7 relative residual; ask
        # it only for what it can deliver and let the f64 refinement
        # passes close the rest (each pass multiplies the true residual
        # by ~inner_tol).  Per column: a loose-tol request batched with
        # a strict one stops at its own contract instead of riding along
        # to the group minimum.
        inner_tol = jnp.asarray(
            np.maximum(tol_col, 1e-5).astype(np.float32))

        t0 = time.perf_counter()
        with tracer.span("solver.solve", k=k, k_pad=k_pad, n=g.n), \
                trace_annotation("solver.solve"):
            res = solve(jnp.asarray(B), tol=inner_tol,
                        maxiter=jnp.asarray(maxiter_col))
            x = np.asarray(res.x, dtype=np.float64)
            iters = np.asarray(res.iters).copy()

        # Mixed-precision iterative refinement: the f32 device solve hits
        # its attainable-accuracy floor on large/ill-conditioned graphs,
        # so measure the true residual in f64 on the host and re-solve
        # for the correction on the device until tol is genuinely met.
        # The residual matvec runs over the Graph's own CSR arrays
        # (numpy f64, no scipy on the solve path).
        B64 = B.astype(np.float64)
        bn = np.maximum(np.linalg.norm(B64, axis=0),
                        np.finfo(np.float64).tiny)
        refinements = 0
        resid = B64 - g.laplacian_matvec(x)
        relres = np.linalg.norm(resid, axis=0) / bn
        while refinements < self.max_refine and np.any(relres > tol_col):
            rc = resid - resid.mean(axis=0)
            # corrections draw from each column's remaining budget
            with tracer.span("solver.refine", pass_=refinements + 1,
                             k=k, k_pad=k_pad), \
                    trace_annotation("solver.refine"):
                corr = solve(jnp.asarray(rc.astype(np.float32)),
                             tol=inner_tol,
                             maxiter=jnp.asarray(np.maximum(
                                 maxiter_col - iters, 0)))
            x_new = x + np.asarray(corr.x, dtype=np.float64)
            resid_new = B64 - g.laplacian_matvec(x_new)
            relres_new = np.linalg.norm(resid_new, axis=0) / bn
            # accept per column whenever the correction improved it ...
            take = relres_new < relres
            x = np.where(take, x_new, x)
            resid = np.where(take, resid_new, resid)
            halved = np.any(relres_new < 0.5 * relres)
            relres = np.where(take, relres_new, relres)
            iters = iters + np.asarray(corr.iters)
            refinements += 1
            if not halved:
                break  # ... but stop once passes stall at the f32 floor
        solve_ms = (time.perf_counter() - t0) * 1e3
        with self._lock:
            self._timing["setup_ms"] += setup_ms
            self._timing["solve_ms"] += solve_ms
            self._conv_digests.add(config_digest)
        conv = relres <= tol_col
        # Convergence telemetry, fetched ONCE per flush group from arrays
        # this path already materializes (iters/relres came back with the
        # solution — no extra device round-trip).  Padding columns are
        # excluded: only the k real right-hand sides count.
        m = self.metrics
        m.observe_many(f"solver.pcg.iters.{config_digest}",
                       np.asarray(iters[:k], dtype=np.float64))
        m.observe_many(f"solver.pcg.relres.{config_digest}",
                       np.asarray(relres[:k], dtype=np.float64))
        m.observe(f"solver.latency.setup_ms.{config_digest}", setup_ms)
        m.observe(f"solver.latency.solve_ms.{config_digest}", solve_ms)
        m.inc("solver.refinement_passes", refinements)
        if not bool(conv[:k].all()):
            m.inc("solver.unconverged_columns",
                  int(k - int(conv[:k].sum())))
        group_span.set(k=k, k_pad=k_pad, source=source,
                       refinements=refinements,
                       max_iters=int(np.max(iters[:k])) if k else 0,
                       converged=bool(conv[:k].all()))
        out: Dict[SolveTicket, SolveResponse] = {}
        for e, (ticket, _, req) in enumerate(entries):
            mine = [j for j, (ee, _) in enumerate(owner) if ee == e]
            xs = x[:, mine]
            if np.asarray(req.b).ndim == 1:
                xs = xs[:, 0]
            response = SolveResponse(
                x=xs, iters=iters[mine], relres=relres[mine],
                converged=bool(conv[mine].all()), cache=source,
                refinements=refinements, setup_ms=setup_ms,
                solve_ms=solve_ms, config=config_digest)
            ticket._resolve(response)
            out[ticket] = response
        return out
