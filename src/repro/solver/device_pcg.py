"""Fully jit'd batched-RHS PCG on the device, preconditioned by the hierarchy.

This replaces the per-call host loop of ``core/pcg.py`` for the serving
path: one ``lax.while_loop`` advances all ``k`` right-hand sides of a
``[n, k]`` batch simultaneously (per-column alpha/beta, converged columns
frozen), and the matvec routes through the Pallas ELL kernel
(``kernels/spmv_ell.py``) or a pure-``jnp`` reference path with identical
numerics.

The Laplacian is singular (nullspace = constants), so instead of grounding
a vertex (which reshuffles indices) the solve stays in ``range(L)``: the
right-hand sides are centered and every preconditioner output is centered.
Solutions are determined up to a constant; compare against the host solver
after re-basing (``x - x[0]``).

The hierarchy preconditioner is a symmetric V(1,1)-cycle over the
:class:`repro.solver.hierarchy.Hierarchy` chain: a forward sweep down the
aggregation tree (Chebyshev polynomial smooth + residual restriction), a
tiny dense Cholesky solve at the coarsest level, and a backward sweep up
(prolongation + smooth).  The smoother is a degree-2/3 Chebyshev polynomial
in the Jacobi-preconditioned operator ``D^-1 L`` targeting the upper part
of its spectrum, with the spectral radius estimated per level by a cheap
power iteration at closure-build time — no ``omega`` to tune, and equal or
fewer PCG iterations than the weighted-Jacobi smoother it replaced.  The
polynomial is a fixed symmetric operator, so pre/post-smoothing with the
same polynomial keeps the V-cycle SPD on the mean-zero subspace, which PCG
requires.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.vcycle_fused import (cheby_coeffs, cheby_recurrence,
                                        make_fused_chebyshev,
                                        make_fused_restrict_residual,
                                        resolve_interpret)
from repro.obs.device import named_scope
from repro.solver.hierarchy import Hierarchy


class BatchedPCGResult(NamedTuple):
    x: jnp.ndarray        # [n, k] mean-zero solutions
    iters: jnp.ndarray    # [k] int32 per-column iteration counts
    relres: jnp.ndarray   # [k] true relative residuals ||b - Lx|| / ||b||
    converged: jnp.ndarray  # [k] bool


def default_matvec_impl() -> str:
    """Fused Pallas kernel path on real accelerators; jnp reference under
    interpret mode (the interpreted kernels are correct but slow on CPU
    containers).  The split follows :func:`resolve_interpret` — explicit
    ``REPRO_KERNEL_INTERPRET`` wins, else ``jax.default_backend()``."""
    return "ref" if resolve_interpret(None) else "fused"


def ell_laplacian(graph):
    """ELL slabs of a Graph's Laplacian (thin alias kept here so solver
    consumers never import the kernels package directly)."""
    return ops.to_ell(graph)


def make_matvec(idx, val, impl: str = "ref", tile_n: int = 256,
                interpret: Optional[bool] = None) -> Callable:
    """Batched ELL matvec ``[n, k] -> [n, k]``.

    ``impl="fused"`` routes the whole ``[n, k]`` block through the
    batched-RHS Pallas kernel (one dispatch, x VMEM resident);
    ``impl="kernel"`` unrolls the (static, small) column dimension through
    the single-column Pallas kernel; ``impl="ref"`` is the one-gather jnp
    path.  All compute y[i, j] = sum_l val[i, l] * x[idx[i, l], j].
    """
    if impl == "fused":
        def matvec(x):
            return ops.spmv_batched(idx, val, x, tile_n=tile_n,
                                    interpret=interpret)
    elif impl == "kernel":
        def matvec(x):
            cols = [ops.spmv(idx, val, x[:, j], tile_n=tile_n,
                             interpret=interpret)
                    for j in range(x.shape[1])]
            return jnp.stack(cols, axis=1)
    elif impl == "ref":
        def matvec(x):
            return jnp.einsum("nl,nlk->nk", val, x[idx])
    else:
        raise ValueError(f"unknown matvec impl {impl!r}")
    return matvec


def _center(x):
    return x - jnp.mean(x, axis=0, keepdims=True)


def estimate_dinv_rho_device(matvec: Callable, diag, iters: int = 12):
    """Power-iteration estimate of ``rho(D^-1 L)`` as a DEVICE scalar.

    Deterministic start vector, ~``iters`` gather/scatter sweeps; no host
    sync — callers that estimate several levels (the V-cycle builders)
    batch all estimates into one ``jax.device_get`` instead of blocking
    once per level.  The constant nullspace has eigenvalue 0 and decays
    under iteration, so no explicit projection is needed.
    """
    n = diag.shape[0]
    v = jnp.sin(jnp.arange(n, dtype=jnp.float32) * 1.7 + 0.3)
    v = v / jnp.linalg.norm(v)
    d = diag

    def body(_, v):
        w = matvec(v[:, None])[:, 0] / d
        return w / jnp.maximum(jnp.linalg.norm(w), jnp.float32(1e-30))

    v = jax.lax.fori_loop(0, iters, body, v)
    w = matvec(v[:, None])[:, 0] / d
    return jnp.linalg.norm(w)


def estimate_dinv_rho(matvec: Callable, diag, iters: int = 12) -> float:
    """Host-scalar convenience over :func:`estimate_dinv_rho_device` for
    single-level callers (tests, benchmarks).  Runs once per level at
    closure-build time, so the designated sync below is amortized over
    every solve the closure serves."""
    return float(jax.device_get(estimate_dinv_rho_device(matvec, diag,
                                                         iters)))


def make_chebyshev_smoother(matvec: Callable, diag, rho: float,
                            degree: int = 3) -> Callable:
    """Degree-``degree`` Chebyshev smoother for ``L z = r`` with Jacobi
    scaling, targeting eigenvalues of ``D^-1 L`` in ``[lmax/4, lmax]``
    (``lmax = 1.1 * rho`` for safety — overestimating is benign,
    underestimating can amplify the top mode).  The upper-quarter band is
    the classic smoothing choice: the coarse correction owns the low modes,
    so the polynomial concentrates its damping where aggregation cannot
    reach.

    Returns ``smooth(r, z=None)``: ``degree`` recurrence steps from initial
    guess ``z`` (``None`` = zero).  The correction is a fixed polynomial in
    ``D^-1 L`` applied to ``D^-1 (r - L z)``, i.e. a symmetric operator —
    using the same polynomial pre and post keeps the V-cycle SPD.

    The polynomial itself (:func:`repro.kernels.vcycle_fused.cheby_recurrence`)
    is shared with the fused Pallas kernel, so the unfused composition and
    the fused kernel are the same computation by construction.
    """
    theta, delta, sigma = cheby_coeffs(rho)
    inv_d = (1.0 / diag)[:, None]

    def smooth(r, z=None):
        return cheby_recurrence(matvec, inv_d, r, z, degree=degree,
                                theta=theta, delta=delta, sigma=sigma)

    return smooth


def make_vcycle(hier: Hierarchy, *, degree: int = 2,
                matvec_impl: str = "ref", tile_n: int = 256,
                interpret: Optional[bool] = None) -> Callable:
    """Symmetric V(1,1)-cycle apply ``r [n, k] -> z ~= L_P^+ r``.

    Forward sweep (fine -> coarse): Chebyshev pre-smooth from zero,
    restrict the residual through the aggregation tree (segment-sum).
    Coarsest: dense triangular solves against the grounded Cholesky factor.
    Backward sweep (coarse -> fine): prolong (gather), Chebyshev
    post-smooth.  The level structure is static, so the recursion unrolls
    under jit.  ``degree`` is the Chebyshev polynomial degree (2 or 3 are
    the sweet spot); each level's spectral radius bound comes from
    :func:`estimate_dinv_rho` at build time — always over the jnp
    reference matvec, so every ``matvec_impl`` bakes in the *identical*
    polynomial coefficients (the fused-vs-unfused iteration-count parity
    contract rests on this).

    ``matvec_impl="fused"`` swaps each level's smoother for the fused
    Pallas Chebyshev kernel (one read of the idx/val slabs per sweep
    instead of per matvec) and the down-sweep residual + restriction for
    the fused restrict+residual kernel — the V-cycle's HBM traffic drops
    from ``(2*degree + 1)`` slab streams per level to 3.
    """
    fused = matvec_impl == "fused"
    rho_dev = [estimate_dinv_rho_device(
        make_matvec(lev.idx, lev.val, "ref"), lev.diag)
        for lev in hier.levels]
    # the ONE designated build-time sync: every level's spectral-radius
    # estimate lands in a single device_get instead of one blocking
    # round-trip per level (the estimates are queued, so they overlap)
    rhos = [float(r) for r in jax.device_get(rho_dev)]
    if fused:
        matvecs = [make_matvec(lev.idx, lev.val, "fused", tile_n,
                               interpret=interpret) for lev in hier.levels]
        smoothers = [
            make_fused_chebyshev(lev.idx, lev.val, lev.diag, rho,
                                 degree=degree, interpret=interpret)
            for lev, rho in zip(hier.levels, rhos)]
        restricts = [
            make_fused_restrict_residual(lev.idx, lev.val, lev.agg,
                                         lev.n_coarse, interpret=interpret)
            for lev in hier.levels]
    else:
        matvecs = [make_matvec(lev.idx, lev.val, matvec_impl, tile_n,
                               interpret=interpret) for lev in hier.levels]
        smoothers = [
            make_chebyshev_smoother(mv, lev.diag, rho, degree=degree)
            for mv, lev, rho in zip(matvecs, hier.levels, rhos)]

    def coarse_solve(r):
        with named_scope("vcycle.coarse"):
            if hier.coarse_chol is None:  # single-vertex coarse graph
                return jnp.zeros_like(r)
            y = jax.scipy.linalg.cho_solve((hier.coarse_chol, True), r[1:])
            z = jnp.concatenate([jnp.zeros_like(r[:1]), y], axis=0)
            return _center(z)

    # named_scope labels are attached at trace time (zero runtime cost):
    # device timelines and HLO dumps show vcycle.L<l>.down/up per level
    # instead of one anonymous fusion soup.
    def cycle(l: int, r):
        if l == len(hier.levels):
            return coarse_solve(r)
        lev = hier.levels[l]
        mv, smooth = matvecs[l], smoothers[l]
        with named_scope(f"vcycle.L{l}.down"):
            z = smooth(r)                                   # pre-smooth
            if fused:                                       # restrict
                rc = restricts[l](r, z)
            else:
                rc = jax.ops.segment_sum(r - mv(z), lev.agg,
                                         num_segments=lev.n_coarse)
        zc = cycle(l + 1, rc)                               # coarse correct
        with named_scope(f"vcycle.L{l}.up"):
            z = z + zc[lev.agg]                             # prolong
            return smooth(r, z)                             # post-smooth

    def msolve(r):
        return _center(cycle(0, r))

    return msolve


def make_jacobi(diag) -> Callable:
    """Diagonal preconditioner (cheap middle ground for comparisons)."""
    d = diag[:, None]

    def msolve(r):
        return _center(r / d)

    return msolve


def _pcg_loop(matvec: Callable, b, msolve: Callable, tol, maxiter,
              colsum: Callable, center: Callable) -> BatchedPCGResult:
    """The one batched-PCG ``lax.while_loop``, parameterized over its
    reductions so the single-device and mesh-sharded planes share it.

    ``colsum(v) -> [k]`` sums a ``[rows, k]`` array over rows (plain
    ``jnp.sum`` on one device; local partial sum + ``psum`` under
    ``shard_map``) and ``center`` projects out the Laplacian nullspace.
    Everything else — per-column alpha/beta with converged columns frozen,
    the ``tol_inner = 0.5 * tol`` target, the periodic van der Vorst
    residual replacement — is identical by construction, which is what the
    sharded plane's iteration-count parity contract (counts within ±2 of
    the single-device solver) rests on.
    """
    k = b.shape[1]
    bnorm = jnp.sqrt(colsum(b * b))
    bn = jnp.maximum(bnorm, jnp.finfo(b.dtype).tiny)
    maxiter = jnp.broadcast_to(jnp.asarray(maxiter, jnp.int32), (k,))
    # The loop tracks the *recurrence* residual, which drifts away from the
    # true residual in f32.  Two defenses so the reported true relres
    # (recomputed at the end) still meets the caller's target: aim below tol,
    # and periodically replace the recurrence residual with the true one
    # (van der Vorst-style residual replacement).
    tol_inner = 0.5 * tol
    replace_every = 50

    x0 = jnp.zeros_like(b)
    z0 = msolve(b)
    rz0 = colsum(b * z0)
    done0 = (bnorm <= 0) | (maxiter <= 0)
    iters0 = jnp.zeros((k,), jnp.int32)
    state = (x0, b, z0, rz0, iters0, done0, jnp.int32(0))

    def cond(s):
        _, _, _, _, _, done, it = s
        return jnp.any(~done) & (it < jnp.max(maxiter))

    def body(s):
        x, r, p, rz, iters, done, it = s
        active = ~done
        Ap = matvec(p)
        pAp = colsum(p * Ap)
        alpha = jnp.where(active, rz / jnp.where(pAp != 0, pAp, 1.0), 0.0)
        x = x + alpha * p
        r = r - alpha * Ap
        r = jax.lax.cond((it + 1) % replace_every == 0,
                         lambda: b - matvec(x), lambda: r)
        relres = jnp.sqrt(colsum(r * r)) / bn
        iters = iters + active.astype(jnp.int32)
        done = done | (relres <= tol_inner) | (iters >= maxiter)
        z = msolve(r)
        rz_new = colsum(r * z)
        beta = rz_new / jnp.where(rz != 0, rz, 1.0)
        p = jnp.where(active, z + beta * p, p)
        rz = jnp.where(active, rz_new, rz)
        return x, r, p, rz, iters, done, it + 1

    x, _, _, _, iters, _, _ = jax.lax.while_loop(cond, body, state)
    x = center(x)
    relres = jnp.sqrt(colsum((b - matvec(x)) ** 2)) / bn  # true residual
    return BatchedPCGResult(x=x, iters=iters, relres=relres,
                            converged=relres <= tol)


def batched_pcg(matvec: Callable, b, msolve: Optional[Callable] = None,
                tol=1e-5, maxiter=2000) -> BatchedPCGResult:
    """PCG over a ``[n, k]`` RHS batch in one ``lax.while_loop``.

    Per-column step sizes; a converged column freezes (alpha forced to 0)
    while the rest keep iterating, so the loop runs until every column meets
    ``||b - Lx|| <= tol * ||b||`` or its iteration cap.  ``maxiter`` may be
    a scalar or a ``[k]`` array (per-column budgets for batched requests
    with different contracts).  Columns of ``b`` must be mean-zero (in
    ``range(L)``); use :func:`make_solver` for the end-to-end wrapper that
    centers and reports true residuals.
    """
    if msolve is None:
        msolve = lambda r: r  # noqa: E731
    return _pcg_loop(matvec, b, msolve, tol, maxiter,
                     colsum=lambda v: jnp.sum(v, axis=0), center=_center)


def make_solver(idx, val, hierarchy: Optional[Hierarchy] = None,
                precond: str = "hierarchy", matvec_impl: Optional[str] = None,
                tile_n: int = 256, mesh=None,
                shard_axis: str = "data",
                interpret: Optional[bool] = None) -> Callable:
    """Build the jit'd end-to-end solve ``(b [n, k], tol, maxiter) -> result``.

    ``precond``: "hierarchy" (V-cycle over ``hierarchy``), "jacobi", or
    "none".  The returned function is a plain ``jax.jit`` closure — callers
    (the service) cache it per graph so repeated solves pay zero setup.

    ``matvec_impl``: "fused" (batched-RHS Pallas spmv + fused Chebyshev /
    restrict+residual kernels), "kernel" (per-column Pallas spmv), "ref"
    (jnp composition, the parity oracle), or ``None`` to auto-select via
    :func:`default_matvec_impl`.  ``interpret`` forces Pallas interpret
    (``True``) or compiled Mosaic (``False``) mode; ``None`` resolves from
    the backend (see :func:`repro.kernels.ops.resolve_interpret`).

    ``mesh`` switches to the mesh-sharded plane: the ELL slabs (top level
    and every hierarchy level) are row-sharded over ``shard_axis`` and the
    whole PCG + V-cycle runs under ``shard_map`` — see
    :mod:`repro.solver.sharded`.  ``matvec_impl="fused"`` there contracts
    each shard's slab with the batched Pallas kernel.  The returned
    closure keeps this exact signature and global-array contract either
    way.
    """
    if mesh is not None:
        if matvec_impl == "kernel":
            import warnings
            warnings.warn(
                "matvec_impl='kernel' is ignored on the sharded path: each "
                "shard's ELL slab is contracted with the jnp reference "
                "matvec under shard_map (use matvec_impl='fused' for the "
                "batched per-shard Pallas contraction)", stacklevel=2)
            matvec_impl = "ref"
        # local import: sharded builds on this module's smoother/estimator
        from repro.solver.sharded import make_sharded_solver
        return make_sharded_solver(idx, val, hierarchy=hierarchy,
                                   precond=precond, mesh=mesh,
                                   shard_axis=shard_axis,
                                   matvec_impl=matvec_impl,
                                   tile_n=tile_n, interpret=interpret)
    if matvec_impl is None:
        matvec_impl = default_matvec_impl()
    matvec = make_matvec(idx, val, matvec_impl, tile_n, interpret=interpret)
    if precond == "hierarchy":
        if hierarchy is None:
            raise ValueError("precond='hierarchy' needs a Hierarchy")
        msolve = make_vcycle(hierarchy, matvec_impl=matvec_impl,
                             tile_n=tile_n, interpret=interpret)
    elif precond == "jacobi":
        n = idx.shape[0]
        diag = jnp.sum(val * (idx == jnp.arange(n)[:, None]), axis=1)
        msolve = make_jacobi(diag)
    elif precond == "none":
        msolve = None
    else:
        raise ValueError(f"unknown precond {precond!r}")

    @jax.jit
    def solve(b, tol=1e-5, maxiter=2000):
        with named_scope("batched_pcg"):
            b = _center(b)
            return batched_pcg(matvec, b, msolve, tol=tol, maxiter=maxiter)

    return solve
