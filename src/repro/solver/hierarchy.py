"""Multilevel pdGRASS: recursive sparsify -> contract -> re-sparsify.

The pdGRASS sparsifier is a preconditioner, not an end product, and it
composes (SF-GRASS, Zhang et al. 2020): the sparsifier of a graph is itself
a graph that can be contracted by heavy-edge matching and sparsified again.
Recursing until the graph is tiny yields a chain of ultra-sparse Laplacians

    L_0 (sparsifier of G)  ->  L_1 (sparsifier of contract(L_0))  ->  ...

that :mod:`repro.solver.device_pcg` applies as a symmetric V-cycle — a
forward fine-to-coarse sweep (smooth, restrict), a tiny dense solve at the
coarsest level, and a backward coarse-to-fine sweep (prolong, smooth).  The
apply is O(sum_l m_l) = O(m) and fully jittable, replacing the dense
Cholesky preconditioner of ``pcg_jax`` which is O(n^3)/O(n^2) and cannot
scale past a few thousand vertices.

Every level stores its Laplacian in the ELL [n, L] slab layout of
``kernels/spmv_ell.py`` so the per-level matvecs route through the same
Pallas kernel as the outer PCG loop.

Contraction runs in one of two modes (``build_hierarchy(contraction=...)``):

  * ``"device"`` (default) — a jit'd heavy-edge propose/accept matching
    with heaviest-neighbor absorption, composed from the
    :mod:`repro.core.graph_ops` primitives and operating on the
    sparsifier's :class:`DeviceGraph` end to end.  No per-edge host Python
    loops anywhere; the only host materializations per level are the
    coalesced coarse edge list (one vectorized ``build_graph`` to seed the
    next level's pipeline run) and, at the bottom, the dense coarse
    Cholesky factor.
  * ``"host"`` — the original sequential greedy matching over numpy
    arrays, kept as the parity oracle.  Both modes follow the same strict
    (weight, -edge id) total order, so they produce the *identical*
    clustering — the device path is the host path with its serial data
    dependencies replaced by propose/accept rounds, exactly the pdGRASS
    move applied to the hierarchy build.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.device_graph import DeviceGraph
from repro.core.graph import Graph, build_graph
from repro.core.graph_ops import (coalesce_edges, propose_accept_matching,
                                  segment_argmax, shard_map_compat,
                                  sharded_coalesce_edges, sharded_matching,
                                  sharded_segment_argmax)
from repro.obs import get_metrics, get_tracer
from repro.obs.device import trace_annotation
from repro.pipeline import Pipeline, PipelineConfig, pdgrass_config


@dataclasses.dataclass(frozen=True)
class Level:
    """One fine level of the hierarchy (everything above the coarsest).

    Attributes:
      n:        vertex count at this level.
      idx/val:  ELL [n, L] slabs of this level's *sparsifier* Laplacian.
      diag:     [n] weighted degrees (Laplacian diagonal) — Jacobi smoother.
      agg:      [n] int32 coarse vertex id of each fine vertex (restriction/
                prolongation operator in index form: P[i, agg[i]] = 1).
      n_coarse: vertex count of the next level.
      stats:    per-level build statistics.
    """

    n: int
    idx: jnp.ndarray
    val: jnp.ndarray
    diag: jnp.ndarray
    agg: jnp.ndarray
    n_coarse: int
    stats: dict


@dataclasses.dataclass(frozen=True)
class Hierarchy:
    """A multilevel preconditioner chain: fine levels + coarsest dense factor."""

    levels: Tuple[Level, ...]
    coarse_n: int
    coarse_chol: Optional[jnp.ndarray]  # [coarse_n-1, coarse_n-1] lower factor
    coarse_stats: dict

    @property
    def stats(self) -> Tuple[dict, ...]:
        return tuple(lev.stats for lev in self.levels) + (self.coarse_stats,)

    @property
    def depth(self) -> int:
        return len(self.levels) + 1

    @property
    def level_sizes(self) -> list:
        return [lev.n for lev in self.levels] + [self.coarse_n]


def subgraph(g: Graph, edge_mask: np.ndarray) -> Graph:
    """The graph induced by keeping ``edge_mask`` edges (must stay connected,
    which any pdGRASS sparsifier is — it contains a spanning tree)."""
    keep = np.asarray(edge_mask, dtype=bool)
    return build_graph(g.n, g.src[keep], g.dst[keep], g.weight[keep])


def heavy_edge_matching(g: Graph) -> np.ndarray:
    """Greedy maximal matching preferring heavy edges (host parity oracle).

    Returns ``mate[v]`` = matched partner of v, or -1.  Heavy edges are the
    spectrally important ones (they dominate the Laplacian quadratic form),
    so collapsing them first keeps the coarse graph spectrally close.

    The serving path uses :func:`device_matching` — the propose/accept
    reformulation of this exact scan (same strict total order, same
    matching); this sequential version stays as the reference that the
    device path is tested against.
    """
    order = np.argsort(-g.weight, kind="stable")
    mate = np.full(g.n, -1, dtype=np.int64)
    # The greedy scan is inherently sequential; run it over python ints
    # (one .tolist() each) rather than per-edge numpy scalar extraction —
    # ~an order of magnitude less interpreter overhead on 1e5+ edge levels.
    src_l = g.src[order].tolist()
    dst_l = g.dst[order].tolist()
    mate_l = mate.tolist()
    for u, v in zip(src_l, dst_l):
        if mate_l[u] < 0 and mate_l[v] < 0:
            mate_l[u] = v
            mate_l[v] = u
    return np.asarray(mate_l, dtype=np.int64)


def contract(g: Graph) -> Tuple[np.ndarray, Graph]:
    """Contract a heavy-edge matching into clusters: returns (agg [n] ->
    coarse id, coarse graph).  Host parity oracle for
    :func:`device_contract`.

    Matched pairs seed the clusters; every unmatched vertex then joins its
    heaviest neighbor's cluster (the matching is maximal, so every neighbor
    of an unmatched vertex is matched).  This guarantees coarse_n = #pairs
    <= n/2 per level even on hub graphs, where pairwise-only contraction
    stalls (one pair per level on a star) and would push a nearly-unshrunk
    graph into the dense coarse factor.  Parallel coarse edges are summed
    by ``build_graph`` (Laplacian semantics); intra-cluster edges drop.
    """
    mate = heavy_edge_matching(g)
    agg = np.full(g.n, -1, dtype=np.int64)
    # Matched pairs seed the clusters (vectorized: number the pair's lower
    # endpoint, mirror onto its mate).
    verts = np.arange(g.n)
    lo_end = np.flatnonzero((mate >= 0) & (verts < mate))
    agg[lo_end] = np.arange(lo_end.shape[0])
    agg[mate[lo_end]] = np.arange(lo_end.shape[0])
    nxt = lo_end.shape[0]
    # Unmatched vertices join their heaviest neighbor's cluster.  Heaviest
    # neighbor per vertex, vectorized: sort directed slots by (head, -w);
    # the first slot of each CSR row is then that row's heaviest edge.
    un = agg < 0
    if np.any(un):
        deg = np.diff(g.indptr)
        heads = np.repeat(verts, deg)
        slot_order = np.lexsort((-g.adj_w, heads))[::-1]
        # reversed fancy assignment: the last write per head is its first
        # (heaviest, CSR-order tie-broken) slot in the forward order
        best = np.full(g.n, -1, dtype=np.int64)
        best[heads[slot_order]] = g.adj[slot_order]
        # maximal matching => every neighbor of an unmatched vertex is
        # matched, so agg[best[un]] is always >= 0 on connected graphs
        agg[un] = agg[best[un]]
    cu, cv = agg[g.src], agg[g.dst]
    keep = cu != cv
    coarse = build_graph(nxt, cu[keep], cv[keep], g.weight[keep])
    return agg.astype(np.int32), coarse


@functools.partial(jax.jit, static_argnums=0)
def _device_contract_arrays(n: int, src, dst, weight):
    """jit'd matching + clustering + edge coalesce over flat device arrays.

    Returns ``(mate, agg, n_pairs, csrc, cdst, cw, m_coarse)`` — all device
    arrays, shapes static in (n, m); only ``n_pairs``/``m_coarse`` are read
    back (they are shapes of the next level, necessarily concrete).
    """
    m = src.shape[0]
    verts = jnp.arange(n, dtype=jnp.int32)
    mate = propose_accept_matching(n, src, dst, weight)
    matched = mate >= 0
    # Matched pairs seed the clusters, numbered by their lower endpoint —
    # the same order the host oracle assigns.
    is_lo = matched & (verts < mate)
    pid = jnp.cumsum(is_lo.astype(jnp.int32)) - 1
    pair_of = jnp.where(is_lo, pid, pid[jnp.where(matched, mate, 0)])
    pair_of = jnp.where(matched, pair_of, -1)
    # Unmatched vertices absorb into their heaviest neighbor's cluster
    # (maximal matching => that neighbor is matched).  The concat layout
    # [src-side | dst-side] makes the default element-index tie-break
    # reproduce the host CSR slot order exactly.
    heads = jnp.concatenate([src, dst])
    tails = jnp.concatenate([dst, src])
    w2 = jnp.concatenate([weight, weight])
    pick, _ = segment_argmax(w2, heads, n)
    target = tails[jnp.where(pick < 2 * m, pick, 0)]
    agg = jnp.where(matched, pair_of, pair_of[target])
    csrc, cdst, cw, m_coarse = coalesce_edges(src, dst, weight, agg, n)
    return mate, agg, is_lo.sum(), csrc, cdst, cw, m_coarse


def device_matching(dg: DeviceGraph) -> jnp.ndarray:
    """Heavy-edge maximal matching on the device; ``mate[v]`` int32 or -1.

    Propose/accept rounds under the strict (weight, -edge id) total order —
    bit-for-bit equal to :func:`heavy_edge_matching` on the same graph.
    """
    return propose_accept_matching(dg.n, dg.src, dg.dst, dg.weight)


def device_contract(dg: DeviceGraph) -> Tuple[jnp.ndarray, Graph]:
    """Device counterpart of :func:`contract`: (agg [n] device int32, coarse
    host Graph).

    Matching, cluster aggregation and edge relabel+coalesce all run inside
    one jit'd function of flat device arrays; the host only slices the
    coalesced coarse edge list (already unique and canonical) to build the
    next level's :class:`Graph` — a vectorized ``build_graph``, no per-edge
    Python loops.
    """
    _, agg, n_pairs, csrc, cdst, cw, m_coarse = _device_contract_arrays(
        dg.n, dg.src, dg.dst, dg.weight)
    nc, mc = int(n_pairs), int(m_coarse)
    coarse = build_graph(nc, np.asarray(csrc[:mc]), np.asarray(cdst[:mc]),
                         np.asarray(cw[:mc]))
    return agg, coarse


def _sharded_contract_core(n: int, m_total: int, axis: str):
    """Build the shard_map body for one contraction round: matching +
    clustering + two-phase coalesce, edges sharded over ``axis``.

    Local args are the shard's edge slice (``eids`` global edge ids, -1 on
    padding; padding slots carry ``src == dst == 0`` so the coalesce drops
    them).  Outputs are replicated.  The clustering math is the replicated
    [n]-array mirror of :func:`_device_contract_arrays` — same pair
    numbering, same concat slot order for the absorption tie-break — so the
    sharded rounds produce the *identical* agg the device (and host) paths
    do.
    """

    def fn(src, dst, weight, eids):
        verts = jnp.arange(n, dtype=jnp.int32)
        valid = eids >= 0
        mate = sharded_matching(n, src, dst, weight, eids, axis=axis)
        matched = mate >= 0
        is_lo = matched & (verts < mate)
        pid = jnp.cumsum(is_lo.astype(jnp.int32)) - 1
        pair_of = jnp.where(is_lo, pid, pid[jnp.where(matched, mate, 0)])
        pair_of = jnp.where(matched, pair_of, -1)
        # Unmatched vertices absorb into their heaviest neighbor's cluster.
        # Global slot ids reproduce the device path's [src-side | dst-side]
        # concat layout: src-side slot of edge e is e, dst-side is
        # m_total + e — the pmin tie-break then matches the element-index
        # tie-break of the single-device segment_argmax exactly.
        heads = jnp.concatenate([src, dst])
        tails = jnp.concatenate([dst, src])
        slots = jnp.concatenate(
            [eids, jnp.where(valid, eids + m_total, -1)])
        w2 = jnp.where(jnp.concatenate([valid, valid]),
                       jnp.concatenate([weight, weight]), -jnp.inf)
        big = jnp.iinfo(jnp.int32).max
        pick, _ = sharded_segment_argmax(w2, heads, n, axis=axis,
                                         element_ids=slots, sentinel=big)
        # resolve tails[pick] across shards: the shard owning the winning
        # slot scatters its tail; pmax merges (one winner per vertex).
        won = (slots >= 0) & (pick[heads] == slots)
        tgt = jnp.full((n,), -1, jnp.int32).at[
            jnp.where(won, heads, n)].set(
            jnp.where(won, tails, 0), mode="drop")
        tgt = jax.lax.pmax(tgt, axis)
        agg = jnp.where(matched, pair_of,
                        pair_of[jnp.where(tgt >= 0, tgt, 0)])
        csrc, cdst, cw, m_coarse = sharded_coalesce_edges(
            src, dst, weight, agg, n, axis=axis)
        return mate, agg, is_lo.sum(), csrc, cdst, cw, m_coarse

    return fn


def sharded_contract(dg: DeviceGraph, mesh, axis: str = "data"
                     ) -> Tuple[jnp.ndarray, Graph]:
    """Mesh-sharded counterpart of :func:`device_contract`: the
    propose/accept rounds run under ``shard_map`` with the edge list
    row-sharded over ``axis``.

    Returns ``(agg [n] replicated device int32, coarse host Graph)`` — the
    identical clustering the device path produces (the strict total order
    survives the collectives), with coarse weights equal up to f32 sum
    order (the two-phase coalesce sums per shard first).
    """
    n_sh = int(mesh.shape[axis])
    m = dg.m
    m_loc = max(1, -(-m // n_sh))
    m_pad = m_loc * n_sh

    def pad(x, fill, dtype):
        out = np.full((m_pad,), fill, dtype)
        out[:m] = np.asarray(x)
        return jnp.asarray(out)

    src_p = pad(dg.src, 0, np.int32)
    dst_p = pad(dg.dst, 0, np.int32)
    w_p = pad(dg.weight, 0.0, np.float32)
    eids = pad(np.arange(m, dtype=np.int32), -1, np.int32)

    fn = shard_map_compat(
        _sharded_contract_core(dg.n, m, axis), mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis)),
        out_specs=(P(), P(), P(), P(), P(), P(), P()))
    _, agg, n_pairs, csrc, cdst, cw, m_coarse = fn(src_p, dst_p, w_p, eids)
    nc, mc = int(n_pairs), int(m_coarse)
    coarse = build_graph(nc, np.asarray(csrc[:mc]), np.asarray(cdst[:mc]),
                         np.asarray(cw[:mc]))
    return agg, coarse


def _laplacian_diag(g: Graph) -> np.ndarray:
    deg = np.zeros(g.n, dtype=np.float64)
    np.add.at(deg, g.src, g.weight)
    np.add.at(deg, g.dst, g.weight)
    return deg


def _grounded_chol(g: Graph) -> Optional[jnp.ndarray]:
    """Lower Cholesky factor of the grounded (node-0-removed) Laplacian."""
    if g.n < 2:
        return None
    w = g.weight.astype(np.float64)
    L = np.zeros((g.n, g.n), dtype=np.float64)
    np.add.at(L, (g.src, g.dst), -w)
    np.add.at(L, (g.dst, g.src), -w)
    L[np.arange(g.n), np.arange(g.n)] = _laplacian_diag(g)
    return jnp.asarray(np.linalg.cholesky(L[1:, 1:]).astype(np.float32))


def build_hierarchy(
    graph: Graph,
    alpha: float = 0.05,
    *,
    config: Optional[PipelineConfig] = None,
    coarse_n: int = 64,
    max_levels: int = 16,
    chunk: int = 512,
    contraction: str = "device",
    mesh=None,
    shard_axis: str = "data",
    **pdgrass_kwargs,
) -> Hierarchy:
    """Sparsify/contract recursively until the graph fits a dense coarse solve.

    Each level sparsifies through the staged :class:`repro.pipeline.Pipeline`
    (``config`` if given — any family member works, feGRASS included —
    else a pdGRASS config from ``alpha``/``chunk``/``pdgrass_kwargs``),
    stores the sparsifier Laplacian in ELL form via the device-resident
    ``Sparsifier.to_ell()`` path (no scipy), then contracts the sparsifier
    by heavy-edge matching to produce the next level's graph.  Vertex counts
    shrink by the matching ratio (~2x on meshes) every level, so the chain
    has O(log n) levels and O(m) total edges.

    ``contraction`` selects the matching/contraction implementation:
    ``"device"`` (default) runs the jit'd propose/accept path of
    :func:`device_contract` on the sparsifier's :class:`DeviceGraph`;
    ``"host"`` runs the sequential greedy oracle :func:`contract`;
    ``"sharded"`` runs :func:`sharded_contract` — the propose/accept
    rounds under ``shard_map`` with the edge list sharded over
    ``mesh``/``shard_axis`` (required for this mode).  All three follow
    the same strict total order and produce the same clustering — the host
    path exists for parity testing and as the no-JAX fallback; the sharded
    path is what lets a 1e6+-vertex build compose with the distributed
    solve on one mesh.
    """
    if contraction not in ("device", "host", "sharded"):
        raise ValueError(
            f"unknown contraction mode {contraction!r}; "
            f"want 'device', 'host' or 'sharded'")
    if contraction == "sharded" and mesh is None:
        raise ValueError("contraction='sharded' needs a mesh")
    if config is None:
        config = pdgrass_config(alpha=alpha, chunk=chunk, **pdgrass_kwargs)
    pipe = Pipeline(config)
    tracer = get_tracer()
    levels = []
    g = graph
    with tracer.span("hierarchy.build", contraction=contraction,
                     n=graph.n, m=graph.m) as build_span:
        for _ in range(max_levels):
            if g.n <= coarse_n:
                break
            with tracer.span("hierarchy.level", level=len(levels),
                             n=g.n, m=g.m) as lev_span:
                m_off = g.m - (g.n - 1)
                if m_off > 0:
                    with tracer.span("hierarchy.sparsify", n=g.n, m=g.m):
                        sp = pipe.run(g)
                    edge_mask = sp.edge_mask
                    dg = sp.device_graph
                else:
                    edge_mask = None  # already a tree — nothing to sparsify
                    dg = DeviceGraph.from_graph(g)
                with tracer.span("hierarchy.contract", mode=contraction), \
                        trace_annotation(f"hierarchy.contract.{contraction}"):
                    if contraction == "device":
                        agg_dev, coarse = device_contract(dg)
                        m_sparsifier = dg.m
                    elif contraction == "sharded":
                        agg_dev, coarse = sharded_contract(
                            dg, mesh, axis=shard_axis)
                        m_sparsifier = dg.m
                    else:
                        sg = subgraph(g, edge_mask) \
                            if edge_mask is not None else g
                        agg_host, coarse = contract(sg)
                        agg_dev = jnp.asarray(agg_host)
                        m_sparsifier = sg.m
                lev_span.set(n_coarse=coarse.n)
            if coarse.n >= g.n:  # no progress — stop rather than loop
                break
            idx, val = dg.to_ell()
            lev_stats = {
                "n": g.n, "m": g.m, "m_sparsifier": m_sparsifier,
                "n_coarse": coarse.n, "shrink": coarse.n / g.n,
                "contraction": contraction,
            }
            levels.append(Level(
                n=g.n, idx=idx, val=val, diag=dg.diag,
                agg=agg_dev, n_coarse=coarse.n, stats=lev_stats,
            ))
            g = coarse
        coarse_stats = {"n": g.n, "m": g.m, "m_sparsifier": g.m,
                        "n_coarse": g.n, "shrink": 1.0,
                        "contraction": contraction}
        with tracer.span("hierarchy.coarse_chol", n=g.n):
            chol = _grounded_chol(g)
        build_span.set(depth=len(levels) + 1)
    m = get_metrics()
    m.inc("hierarchy.builds")
    m.inc("hierarchy.levels_built", len(levels))
    m.set_gauge("hierarchy.last_depth", len(levels) + 1)
    return Hierarchy(levels=tuple(levels), coarse_n=g.n,
                     coarse_chol=chol, coarse_stats=coarse_stats)
