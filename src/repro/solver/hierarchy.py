"""Multilevel pdGRASS: recursive sparsify -> contract -> re-sparsify.

The pdGRASS sparsifier is a preconditioner, not an end product, and it
composes (SF-GRASS, Zhang et al. 2020): the sparsifier of a graph is itself
a graph that can be contracted by heavy-edge matching and sparsified again.
Recursing until the graph is tiny yields a chain of ultra-sparse Laplacians

    L_0 (sparsifier of G)  ->  L_1 (sparsifier of contract(L_0))  ->  ...

that :mod:`repro.solver.device_pcg` applies as a symmetric V-cycle — a
forward fine-to-coarse sweep (smooth, restrict), a tiny dense solve at the
coarsest level, and a backward coarse-to-fine sweep (prolong, smooth).  The
apply is O(sum_l m_l) = O(m) and fully jittable, replacing the dense
Cholesky preconditioner of ``pcg_jax`` which is O(n^3)/O(n^2) and cannot
scale past a few thousand vertices.

Every level stores its Laplacian in the ELL [n, L] slab layout of
``kernels/spmv_ell.py`` so the per-level matvecs route through the same
Pallas kernel as the outer PCG loop.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph, build_graph
from repro.core.sparsify import pdgrass
from repro.kernels.spmv_ell import to_ell


@dataclasses.dataclass(frozen=True)
class Level:
    """One fine level of the hierarchy (everything above the coarsest).

    Attributes:
      n:        vertex count at this level.
      idx/val:  ELL [n, L] slabs of this level's *sparsifier* Laplacian.
      diag:     [n] weighted degrees (Laplacian diagonal) — Jacobi smoother.
      agg:      [n] int32 coarse vertex id of each fine vertex (restriction/
                prolongation operator in index form: P[i, agg[i]] = 1).
      n_coarse: vertex count of the next level.
      stats:    per-level build statistics.
    """

    n: int
    idx: jnp.ndarray
    val: jnp.ndarray
    diag: jnp.ndarray
    agg: jnp.ndarray
    n_coarse: int
    stats: dict


@dataclasses.dataclass(frozen=True)
class Hierarchy:
    """A multilevel preconditioner chain: fine levels + coarsest dense factor."""

    levels: Tuple[Level, ...]
    coarse_n: int
    coarse_chol: Optional[jnp.ndarray]  # [coarse_n-1, coarse_n-1] lower factor
    coarse_stats: dict

    @property
    def stats(self) -> Tuple[dict, ...]:
        return tuple(lev.stats for lev in self.levels) + (self.coarse_stats,)

    @property
    def depth(self) -> int:
        return len(self.levels) + 1

    @property
    def level_sizes(self) -> list:
        return [lev.n for lev in self.levels] + [self.coarse_n]


def subgraph(g: Graph, edge_mask: np.ndarray) -> Graph:
    """The graph induced by keeping ``edge_mask`` edges (must stay connected,
    which any pdGRASS sparsifier is — it contains a spanning tree)."""
    keep = np.asarray(edge_mask, dtype=bool)
    return build_graph(g.n, g.src[keep], g.dst[keep], g.weight[keep])


def heavy_edge_matching(g: Graph) -> np.ndarray:
    """Greedy maximal matching preferring heavy edges.

    Returns ``mate[v]`` = matched partner of v, or -1.  Heavy edges are the
    spectrally important ones (they dominate the Laplacian quadratic form),
    so collapsing them first keeps the coarse graph spectrally close.
    """
    order = np.argsort(-g.weight, kind="stable")
    mate = np.full(g.n, -1, dtype=np.int64)
    src, dst = g.src, g.dst
    for e in order:
        u, v = int(src[e]), int(dst[e])
        if mate[u] < 0 and mate[v] < 0:
            mate[u] = v
            mate[v] = u
    return mate


def contract(g: Graph) -> Tuple[np.ndarray, Graph]:
    """Contract a heavy-edge matching into clusters: returns (agg [n] ->
    coarse id, coarse graph).

    Matched pairs seed the clusters; every unmatched vertex then joins its
    heaviest neighbor's cluster (the matching is maximal, so every neighbor
    of an unmatched vertex is matched).  This guarantees coarse_n = #pairs
    <= n/2 per level even on hub graphs, where pairwise-only contraction
    stalls (one pair per level on a star) and would push a nearly-unshrunk
    graph into the dense coarse factor.  Parallel coarse edges are summed
    by ``build_graph`` (Laplacian semantics); intra-cluster edges drop.
    """
    mate = heavy_edge_matching(g)
    agg = np.full(g.n, -1, dtype=np.int64)
    nxt = 0
    for v in range(g.n):
        if agg[v] < 0 and mate[v] >= 0:
            agg[v] = agg[mate[v]] = nxt
            nxt += 1
    for v in range(g.n):
        if agg[v] >= 0:
            continue
        lo, hi = g.indptr[v], g.indptr[v + 1]
        nbrs = g.adj[lo:hi]
        best = nbrs[np.argmax(g.adj_w[lo:hi])] if hi > lo else None
        if best is not None and agg[best] >= 0:
            agg[v] = agg[best]
        else:  # isolated vertex (cannot happen for connected n>=2)
            agg[v] = nxt
            nxt += 1
    cu, cv = agg[g.src], agg[g.dst]
    keep = cu != cv
    coarse = build_graph(nxt, cu[keep], cv[keep], g.weight[keep])
    return agg.astype(np.int32), coarse


def _laplacian_diag(g: Graph) -> np.ndarray:
    deg = np.zeros(g.n, dtype=np.float64)
    np.add.at(deg, g.src, g.weight)
    np.add.at(deg, g.dst, g.weight)
    return deg


def _grounded_chol(g: Graph) -> Optional[jnp.ndarray]:
    """Lower Cholesky factor of the grounded (node-0-removed) Laplacian."""
    if g.n < 2:
        return None
    L = g.laplacian().toarray()[1:, 1:]
    return jnp.asarray(np.linalg.cholesky(L).astype(np.float32))


def build_hierarchy(
    graph: Graph,
    alpha: float = 0.05,
    *,
    coarse_n: int = 64,
    max_levels: int = 16,
    chunk: int = 512,
    **pdgrass_kwargs,
) -> Hierarchy:
    """Sparsify/contract recursively until the graph fits a dense coarse solve.

    Each level sparsifies with the full pdGRASS pipeline (spanning tree +
    strict-similarity recovery at density ``alpha``), stores the sparsifier
    Laplacian in ELL form, then contracts the sparsifier by heavy-edge
    matching to produce the next level's graph.  Vertex counts shrink by the
    matching ratio (~2x on meshes) every level, so the chain has O(log n)
    levels and O(m) total edges.
    """
    levels = []
    g = graph
    for _ in range(max_levels):
        if g.n <= coarse_n:
            break
        m_off = g.m - (g.n - 1)
        if m_off > 0:
            sp = pdgrass(g, alpha=alpha, chunk=chunk, **pdgrass_kwargs)
            sg = subgraph(g, sp.edge_mask)
        else:
            sg = g  # already a tree — nothing to sparsify away
        agg, coarse = contract(sg)
        if coarse.n >= g.n:  # no progress — stop rather than loop
            break
        idx, val = to_ell(sg)
        lev_stats = {
            "n": g.n, "m": g.m, "m_sparsifier": sg.m,
            "n_coarse": coarse.n, "shrink": coarse.n / g.n,
        }
        levels.append(Level(
            n=g.n, idx=idx, val=val,
            diag=jnp.asarray(_laplacian_diag(sg).astype(np.float32)),
            agg=jnp.asarray(agg), n_coarse=coarse.n, stats=lev_stats,
        ))
        g = coarse
    coarse_stats = {"n": g.n, "m": g.m, "m_sparsifier": g.m,
                    "n_coarse": g.n, "shrink": 1.0}
    return Hierarchy(levels=tuple(levels), coarse_n=g.n,
                     coarse_chol=_grounded_chol(g), coarse_stats=coarse_stats)
