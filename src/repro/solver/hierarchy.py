"""Multilevel pdGRASS: recursive sparsify -> contract -> re-sparsify.

The pdGRASS sparsifier is a preconditioner, not an end product, and it
composes (SF-GRASS, Zhang et al. 2020): the sparsifier of a graph is itself
a graph that can be contracted by heavy-edge matching and sparsified again.
Recursing until the graph is tiny yields a chain of ultra-sparse Laplacians

    L_0 (sparsifier of G)  ->  L_1 (sparsifier of contract(L_0))  ->  ...

that :mod:`repro.solver.device_pcg` applies as a symmetric V-cycle — a
forward fine-to-coarse sweep (smooth, restrict), a tiny dense solve at the
coarsest level, and a backward coarse-to-fine sweep (prolong, smooth).  The
apply is O(sum_l m_l) = O(m) and fully jittable, replacing the dense
Cholesky preconditioner of ``pcg_jax`` which is O(n^3)/O(n^2) and cannot
scale past a few thousand vertices.

Every level stores its Laplacian in the ELL [n, L] slab layout of
``kernels/spmv_ell.py`` so the per-level matvecs route through the same
Pallas kernel as the outer PCG loop.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.device_graph import DeviceGraph
from repro.core.graph import Graph, build_graph
from repro.pipeline import Pipeline, PipelineConfig, pdgrass_config


@dataclasses.dataclass(frozen=True)
class Level:
    """One fine level of the hierarchy (everything above the coarsest).

    Attributes:
      n:        vertex count at this level.
      idx/val:  ELL [n, L] slabs of this level's *sparsifier* Laplacian.
      diag:     [n] weighted degrees (Laplacian diagonal) — Jacobi smoother.
      agg:      [n] int32 coarse vertex id of each fine vertex (restriction/
                prolongation operator in index form: P[i, agg[i]] = 1).
      n_coarse: vertex count of the next level.
      stats:    per-level build statistics.
    """

    n: int
    idx: jnp.ndarray
    val: jnp.ndarray
    diag: jnp.ndarray
    agg: jnp.ndarray
    n_coarse: int
    stats: dict


@dataclasses.dataclass(frozen=True)
class Hierarchy:
    """A multilevel preconditioner chain: fine levels + coarsest dense factor."""

    levels: Tuple[Level, ...]
    coarse_n: int
    coarse_chol: Optional[jnp.ndarray]  # [coarse_n-1, coarse_n-1] lower factor
    coarse_stats: dict

    @property
    def stats(self) -> Tuple[dict, ...]:
        return tuple(lev.stats for lev in self.levels) + (self.coarse_stats,)

    @property
    def depth(self) -> int:
        return len(self.levels) + 1

    @property
    def level_sizes(self) -> list:
        return [lev.n for lev in self.levels] + [self.coarse_n]


def subgraph(g: Graph, edge_mask: np.ndarray) -> Graph:
    """The graph induced by keeping ``edge_mask`` edges (must stay connected,
    which any pdGRASS sparsifier is — it contains a spanning tree)."""
    keep = np.asarray(edge_mask, dtype=bool)
    return build_graph(g.n, g.src[keep], g.dst[keep], g.weight[keep])


def heavy_edge_matching(g: Graph) -> np.ndarray:
    """Greedy maximal matching preferring heavy edges.

    Returns ``mate[v]`` = matched partner of v, or -1.  Heavy edges are the
    spectrally important ones (they dominate the Laplacian quadratic form),
    so collapsing them first keeps the coarse graph spectrally close.
    """
    order = np.argsort(-g.weight, kind="stable")
    mate = np.full(g.n, -1, dtype=np.int64)
    # The greedy scan is inherently sequential; run it over python ints
    # (one .tolist() each) rather than per-edge numpy scalar extraction —
    # ~an order of magnitude less interpreter overhead on 1e5+ edge levels.
    src_l = g.src[order].tolist()
    dst_l = g.dst[order].tolist()
    mate_l = mate.tolist()
    for u, v in zip(src_l, dst_l):
        if mate_l[u] < 0 and mate_l[v] < 0:
            mate_l[u] = v
            mate_l[v] = u
    return np.asarray(mate_l, dtype=np.int64)


def contract(g: Graph) -> Tuple[np.ndarray, Graph]:
    """Contract a heavy-edge matching into clusters: returns (agg [n] ->
    coarse id, coarse graph).

    Matched pairs seed the clusters; every unmatched vertex then joins its
    heaviest neighbor's cluster (the matching is maximal, so every neighbor
    of an unmatched vertex is matched).  This guarantees coarse_n = #pairs
    <= n/2 per level even on hub graphs, where pairwise-only contraction
    stalls (one pair per level on a star) and would push a nearly-unshrunk
    graph into the dense coarse factor.  Parallel coarse edges are summed
    by ``build_graph`` (Laplacian semantics); intra-cluster edges drop.
    """
    mate = heavy_edge_matching(g)
    agg = np.full(g.n, -1, dtype=np.int64)
    # Matched pairs seed the clusters (vectorized: number the pair's lower
    # endpoint, mirror onto its mate).
    verts = np.arange(g.n)
    lo_end = np.flatnonzero((mate >= 0) & (verts < mate))
    agg[lo_end] = np.arange(lo_end.shape[0])
    agg[mate[lo_end]] = np.arange(lo_end.shape[0])
    nxt = lo_end.shape[0]
    # Unmatched vertices join their heaviest neighbor's cluster.  Heaviest
    # neighbor per vertex, vectorized: sort directed slots by (head, -w);
    # the first slot of each CSR row is then that row's heaviest edge.
    un = agg < 0
    if np.any(un):
        deg = np.diff(g.indptr)
        heads = np.repeat(verts, deg)
        slot_order = np.lexsort((-g.adj_w, heads))[::-1]
        # reversed fancy assignment: the last write per head is its first
        # (heaviest, CSR-order tie-broken) slot in the forward order
        best = np.full(g.n, -1, dtype=np.int64)
        best[heads[slot_order]] = g.adj[slot_order]
        # maximal matching => every neighbor of an unmatched vertex is
        # matched, so agg[best[un]] is always >= 0 on connected graphs
        agg[un] = agg[best[un]]
    cu, cv = agg[g.src], agg[g.dst]
    keep = cu != cv
    coarse = build_graph(nxt, cu[keep], cv[keep], g.weight[keep])
    return agg.astype(np.int32), coarse


def _laplacian_diag(g: Graph) -> np.ndarray:
    deg = np.zeros(g.n, dtype=np.float64)
    np.add.at(deg, g.src, g.weight)
    np.add.at(deg, g.dst, g.weight)
    return deg


def _grounded_chol(g: Graph) -> Optional[jnp.ndarray]:
    """Lower Cholesky factor of the grounded (node-0-removed) Laplacian."""
    if g.n < 2:
        return None
    w = g.weight.astype(np.float64)
    L = np.zeros((g.n, g.n), dtype=np.float64)
    np.add.at(L, (g.src, g.dst), -w)
    np.add.at(L, (g.dst, g.src), -w)
    L[np.arange(g.n), np.arange(g.n)] = _laplacian_diag(g)
    return jnp.asarray(np.linalg.cholesky(L[1:, 1:]).astype(np.float32))


def build_hierarchy(
    graph: Graph,
    alpha: float = 0.05,
    *,
    config: Optional[PipelineConfig] = None,
    coarse_n: int = 64,
    max_levels: int = 16,
    chunk: int = 512,
    **pdgrass_kwargs,
) -> Hierarchy:
    """Sparsify/contract recursively until the graph fits a dense coarse solve.

    Each level sparsifies through the staged :class:`repro.pipeline.Pipeline`
    (``config`` if given — any family member works, feGRASS included —
    else a pdGRASS config from ``alpha``/``chunk``/``pdgrass_kwargs``),
    stores the sparsifier Laplacian in ELL form via the device-resident
    ``Sparsifier.to_ell()`` path (no scipy), then contracts the sparsifier
    by heavy-edge matching to produce the next level's graph.  Vertex counts
    shrink by the matching ratio (~2x on meshes) every level, so the chain
    has O(log n) levels and O(m) total edges.
    """
    if config is None:
        config = pdgrass_config(alpha=alpha, chunk=chunk, **pdgrass_kwargs)
    pipe = Pipeline(config)
    levels = []
    g = graph
    for _ in range(max_levels):
        if g.n <= coarse_n:
            break
        m_off = g.m - (g.n - 1)
        if m_off > 0:
            sp = pipe.run(g)
            sg = subgraph(g, sp.edge_mask)
            dg = sp.device_graph
        else:
            sg = g  # already a tree — nothing to sparsify away
            dg = DeviceGraph.from_graph(g)
        agg, coarse = contract(sg)
        if coarse.n >= g.n:  # no progress — stop rather than loop
            break
        idx, val = dg.to_ell()
        lev_stats = {
            "n": g.n, "m": g.m, "m_sparsifier": sg.m,
            "n_coarse": coarse.n, "shrink": coarse.n / g.n,
        }
        levels.append(Level(
            n=g.n, idx=idx, val=val, diag=dg.diag,
            agg=jnp.asarray(agg), n_coarse=coarse.n, stats=lev_stats,
        ))
        g = coarse
    coarse_stats = {"n": g.n, "m": g.m, "m_sparsifier": g.m,
                    "n_coarse": g.n, "shrink": 1.0}
    return Hierarchy(levels=tuple(levels), coarse_n=g.n,
                     coarse_chol=_grounded_chol(g), coarse_stats=coarse_stats)
