"""Request-plane objects for the serving-grade solver API.

Three ideas, one module:

  * :class:`GraphHandle` / :class:`GraphStore` — register a graph once,
    pay its O(m) content hash once, and pass the handle on every request.
    The store dedupes by content digest, so two structurally identical
    graphs resolve to the same handle (and therefore the same cache keys).
  * :class:`SolveRequest` — a (graph-or-handle, rhs) pair plus its solve
    contract (``tol``/``maxiter``) and an optional per-request
    ``pipeline=PipelineConfig(...)`` override: requests with different
    stage mixes batch through one service and each hit their own cached
    hierarchy.
  * :class:`SolveTicket` — the future handed back by ``submit``.  Tickets
    are monotonically numbered per service (stable across flushes, unlike
    the v1 per-flush list indices), expose ``done()`` / ``result()``, and
    subclass ``int`` so v1 code that indexed the flush dict with the bare
    ticket keeps working unchanged.
  * :class:`AdmissionError` — raised by ``submit`` when a bounded scheduler
    (``SolverService(max_pending_columns=...)``) is over budget; callers
    back off or ``flush()`` and retry, instead of queueing unboundedly.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
import threading
import time
from typing import Dict, List, Optional, Union

import numpy as np

from repro.core.graph import Graph, build_graph
from repro.pipeline import PipelineConfig
from repro.solver import cache as _cache
from repro.solver.cache import content_fingerprint


@dataclasses.dataclass(frozen=True)
class GraphHandle:
    """A registered graph plus its memoized content digest.

    Handles are cheap value objects: equality/hash follow the fingerprint,
    so they key dicts and dedupe naturally.  Obtain them from
    :meth:`GraphStore.register` (or ``SolverService.register``).
    """

    graph: Graph
    fingerprint: str

    @property
    def n(self) -> int:
        return self.graph.n

    @property
    def m(self) -> int:
        return self.graph.m

    def __eq__(self, other) -> bool:
        return isinstance(other, GraphHandle) and \
            self.fingerprint == other.fingerprint

    def __hash__(self) -> int:
        return hash(self.fingerprint)

    def __repr__(self) -> str:
        return (f"GraphHandle(n={self.n}, m={self.m}, "
                f"fingerprint={self.fingerprint[:12]}...)")


class GraphStore:
    """Registry of content-addressed graphs behind a solver service.

    ``register`` is idempotent: re-registering the same graph object is a
    memo lookup, and registering a structurally identical copy returns the
    *existing* handle (one graph in the store, one set of cache entries).

    With ``persist_dir`` set the store survives restarts: every newly
    registered graph is written as ``<fingerprint>.npz`` (the canonical
    edge arrays, atomic tmp-file + ``os.replace`` write), and construction
    rehydrates every persisted graph back into handles.  The on-disk tier
    is bounded by ``max_entries`` / ``max_bytes`` (``None`` = unbounded)
    with least-recently-used eviction, exactly like the artifact disk
    tier: registering a graph whose file already exists refreshes its
    mtime, pruning evicts oldest-mtime files first, and the file just
    written is never the victim — a single graph larger than ``max_bytes``
    still persists.  Eviction only trims disk; live in-memory handles are
    untouched (a re-register of an evicted graph simply re-persists it).  Rehydration
    trusts the persisted digest (the filename, cross-checked against the
    digest stored *inside* the file) instead of re-hashing the edge
    arrays, so a restarted service hits its disk artifact cache with zero
    new ``hash_events`` — the whole point of persisting the store beside
    the artifact tier.  Torn or corrupt files (near-impossible given the
    atomic writes) are skipped, not fatal.

    Thread-safe: ``register``/``get`` may be called concurrently from
    producer threads feeding a background flusher.
    """

    def __init__(self, persist_dir: Optional[str] = None,
                 max_entries: Optional[int] = None,
                 max_bytes: Optional[int] = None):
        self._handles: Dict[str, GraphHandle] = {}
        self._lock = threading.Lock()
        self.hash_events = 0   # O(m) content hashes this store triggered
        self.persist_dir = persist_dir
        self.max_entries = max_entries
        self.max_bytes = max_bytes
        self.persisted = 0     # graphs written to persist_dir by this store
        self.rehydrated = 0    # handles loaded from persist_dir at init
        self.persist_evictions = 0  # files pruned by the entries/bytes caps
        if persist_dir:
            os.makedirs(persist_dir, exist_ok=True)
            self._rehydrate()

    def _path(self, fingerprint: str) -> str:
        return os.path.join(self.persist_dir, f"{fingerprint}.npz")

    def _rehydrate(self) -> None:
        for name in sorted(os.listdir(self.persist_dir)):
            if not name.endswith(".npz"):
                continue
            fp = name[:-4]
            try:
                with np.load(self._path(fp)) as z:
                    stored_fp = str(z["fingerprint"])
                    if stored_fp != fp:
                        continue   # filename/content mismatch: ignore
                    g = build_graph(int(z["n"]), z["src"], z["dst"],
                                    z["weight"])
            except Exception:
                continue   # torn/corrupt/foreign file: skip, never crash
            # Adopt the persisted digest as the memo — no O(m) re-hash —
            # and freeze the arrays exactly like content_fingerprint does.
            object.__setattr__(g, "_content_fp", fp)
            for arr in (g.src, g.dst, g.weight):
                arr.flags.writeable = False
            self._handles[fp] = GraphHandle(graph=g, fingerprint=fp)
            self.rehydrated += 1

    def _disk_entries(self):
        """[(path, mtime, bytes)] for every graph file in ``persist_dir``."""
        out = []
        for name in os.listdir(self.persist_dir):
            if not name.endswith(".npz"):
                continue
            path = os.path.join(self.persist_dir, name)
            try:
                st = os.stat(path)
            except OSError:
                continue  # concurrently evicted by another process
            out.append((path, st.st_mtime, st.st_size))
        return out

    def _prune_disk(self, keep: str) -> None:
        """Evict least-recently-used graph files until under both caps;
        never evicts ``keep`` (the path just written/refreshed)."""
        if self.max_entries is None and self.max_bytes is None:
            return
        entries = sorted(self._disk_entries(), key=lambda e: e[1])
        total = sum(size for _, _, size in entries)
        count = len(entries)
        for path, _, size in entries:
            over = ((self.max_entries is not None
                     and count > self.max_entries)
                    or (self.max_bytes is not None
                        and total > self.max_bytes))
            if not over:
                break
            if path == keep:
                continue
            try:
                os.remove(path)
            except OSError:
                continue
            self.persist_evictions += 1
            count -= 1
            total -= size

    def _persist(self, handle: GraphHandle) -> None:
        path = self._path(handle.fingerprint)
        if os.path.exists(path):
            try:
                os.utime(path)  # refresh recency for mtime eviction
            except OSError:
                pass
            return
        g = handle.graph
        fd, tmp = tempfile.mkstemp(dir=self.persist_dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, fingerprint=handle.fingerprint, n=g.n,
                         src=g.src, dst=g.dst, weight=g.weight)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.remove(tmp)
            except OSError:
                pass
            raise
        self.persisted += 1
        self._prune_disk(keep=path)

    def register(self, graph: Union[Graph, GraphHandle]) -> GraphHandle:
        if isinstance(graph, GraphHandle):
            with self._lock:
                handle = self._handles.setdefault(graph.fingerprint, graph)
                if self.persist_dir:
                    self._persist(handle)
                return handle
        if not isinstance(graph, Graph):
            raise TypeError(
                f"register wants a Graph or GraphHandle, got "
                f"{type(graph).__name__}")
        before = _cache.HASH_EVENTS
        fp = content_fingerprint(graph)
        with self._lock:
            self.hash_events += _cache.HASH_EVENTS - before
            handle = self._handles.get(fp)
            if handle is None:
                handle = GraphHandle(graph=graph, fingerprint=fp)
                self._handles[fp] = handle
            if self.persist_dir:
                self._persist(handle)
            return handle

    def get(self, fingerprint: str) -> Optional[GraphHandle]:
        with self._lock:
            return self._handles.get(fingerprint)

    def handles(self) -> List[GraphHandle]:
        """Snapshot of every registered handle (rehydrated ones included)."""
        with self._lock:
            return list(self._handles.values())

    def __len__(self) -> int:
        return len(self._handles)

    def __contains__(self, item) -> bool:
        """Content-based membership, mirroring ``register``'s dedup: a
        structurally identical Graph is "in" the store even if this
        particular object was never registered (its fingerprint is computed
        — and memoized — on demand)."""
        if isinstance(item, GraphHandle):
            return item.fingerprint in self._handles
        if isinstance(item, Graph):
            return content_fingerprint(item) in self._handles
        return item in self._handles

    @property
    def stats(self) -> dict:
        out = {"graphs": len(self._handles),
               "hash_events": self.hash_events}
        if self.persist_dir:
            entries = self._disk_entries()
            out.update({"persist_dir": self.persist_dir,
                        "persisted": self.persisted,
                        "rehydrated": self.rehydrated,
                        "persist_entries": len(entries),
                        "persist_bytes": sum(s for _, _, s in entries),
                        "persist_evictions": self.persist_evictions,
                        "max_entries": self.max_entries,
                        "max_bytes": self.max_bytes})
        return out


class AdmissionError(RuntimeError):
    """A submit was rejected because the scheduler's pending-column budget
    (``SolverService(max_pending_columns=...)``) would be exceeded.

    Carries the shape of the decision: ``pending`` columns already queued,
    ``requested`` columns in the rejected submit, and the ``budget``.
    """

    def __init__(self, pending: int, requested: int, budget: int,
                 tenant: Optional[str] = None):
        self.pending = pending
        self.requested = requested
        self.budget = budget
        self.tenant = tenant
        who = f"tenant {tenant!r}" if tenant is not None else "scheduler"
        super().__init__(
            f"admission rejected for {who}: {pending} column(s) pending + "
            f"{requested} requested > budget={budget} — "
            f"wait for the pending work to drain (or raise the budget) "
            f"and resubmit")


class DeadlineExceededError(RuntimeError):
    """A queued request expired before any flusher picked it up.

    Raised out of ``ticket.result()`` when a :class:`SolveRequest` carried
    ``deadline_ms`` and spent longer than that in the daemon's queue — the
    work was dropped unsolved (solving it would be wasted effort: the
    caller has already moved on).  Carries the contract and the overrun.
    """

    def __init__(self, ticket_id: int, deadline_ms: float, waited_ms: float,
                 tenant: Optional[str] = None):
        self.ticket_id = ticket_id
        self.deadline_ms = deadline_ms
        self.waited_ms = waited_ms
        self.tenant = tenant
        who = f" (tenant {tenant!r})" if tenant is not None else ""
        super().__init__(
            f"ticket {ticket_id}{who} expired in queue: waited "
            f"{waited_ms:.1f}ms against a {deadline_ms:.1f}ms deadline — "
            f"the daemon is saturated or the deadline is too tight")


@dataclasses.dataclass
class SolveRequest:
    """One Laplacian solve: ``L_G x = b`` under a per-request contract.

    ``graph`` may be a raw :class:`Graph` (v1 style — the service registers
    it on submit) or a :class:`GraphHandle`.  ``pipeline`` overrides the
    service-wide :class:`PipelineConfig` for this request only; requests
    with distinct configs are scheduled as separate groups sharing the
    flush.

    ``deadline_ms`` is a *queue-side* TTL honored by the daemon: a request
    still waiting in the queue that long past submit is expired with
    :class:`DeadlineExceededError` instead of being solved.  It bounds
    staleness, not solve time — once batched, a solve always completes.
    The synchronous service ignores it (flushes there happen on the
    caller's own thread, so there is no queue to go stale in).
    """

    graph: Union[Graph, GraphHandle]
    b: np.ndarray            # [n] or [n, k]
    tol: float = 1e-5
    maxiter: int = 2000
    pipeline: Optional[PipelineConfig] = None
    deadline_ms: Optional[float] = None


@dataclasses.dataclass
class SolveResponse:
    x: np.ndarray            # same trailing shape as the request's b
    iters: np.ndarray        # [k] per-column PCG iterations (all passes)
    relres: np.ndarray       # [k] f64-measured true relative residuals
    converged: bool
    cache: str               # "mem" | "disk" | "miss" (artifacts source)
    refinements: int         # mixed-precision refinement passes taken
    setup_ms: float          # hierarchy+ELL build (0.0 on a cache hit path)
    solve_ms: float
    config: str = ""         # digest of the PipelineConfig that served this


class SolveTicket(int):
    """Future for a submitted request.  ``done()`` says whether a flush has
    settled it (with a response or a failure); ``result()`` returns the
    :class:`SolveResponse` — or raises the group's build/solve exception —
    flushing the owning service first if the ticket is still pending.
    Tickets are resolvable in any order — each holds its own outcome.

    Tickets issued through a :class:`~repro.serve.solver_daemon.SolverDaemon`
    carry a per-ticket ``threading.Event`` instead of a service back-ref:
    ``result(timeout=...)`` then *blocks* until the background flusher
    resolves the ticket (raising ``TimeoutError`` on expiry) — no caller
    ever triggers a flush.  ``done()`` stays non-blocking in both modes.

    Subclasses ``int`` (the service-wide monotonic ticket id), so v1 code
    doing ``svc.flush()[ticket]`` keeps working: flush dicts are keyed by
    these same objects and ints hash by value.
    """

    def __new__(cls, ticket_id: int, service=None,
                request: Optional[SolveRequest] = None):
        self = super().__new__(cls, ticket_id)
        self._service = service
        self._request = request
        self._response: Optional[SolveResponse] = None
        self._error: Optional[BaseException] = None
        self._event: Optional[threading.Event] = None
        self._resolved_at: Optional[float] = None  # time.perf_counter()
        return self

    @property
    def request(self) -> Optional[SolveRequest]:
        return self._request

    def done(self) -> bool:
        return self._response is not None or self._error is not None

    def error(self) -> Optional[BaseException]:
        """The exception that failed this ticket's group, if any."""
        return self._error

    def result(self, timeout: Optional[float] = None) -> SolveResponse:
        if not self.done():
            if self._event is not None:
                # Async (daemon) mode: block on the per-ticket event the
                # background flusher sets at resolution — never flush from
                # the caller's thread.
                if not self._event.wait(timeout):
                    raise TimeoutError(
                        f"ticket {int(self)} unresolved after {timeout}s — "
                        f"the daemon may be saturated or shut down")
            elif self._service is not None:
                if self._service._has_pending(self):
                    self._service.flush()
                else:
                    # The flush that should have settled this ticket already
                    # ran without it (stale ticket from a restarted service,
                    # or a ticket submitted to a *different* service).
                    # Flushing here would pointlessly solve unrelated
                    # pending work and still leave this ticket unresolved.
                    raise RuntimeError(
                        f"ticket {int(self)} is not pending on its service "
                        f"and was never resolved — it is stale (its flush "
                        f"already ran without it) or belongs to another "
                        f"service; re-submit the request")
        if self._error is not None:
            raise self._error
        if self._response is None:
            raise RuntimeError(
                f"ticket {int(self)} was not resolved by flush() — was it "
                f"submitted to this service?")
        return self._response

    def _resolve(self, response: SolveResponse) -> None:
        self._response = response
        self._resolved_at = time.perf_counter()
        if self._event is not None:
            self._event.set()

    def _fail(self, error: BaseException) -> None:
        self._error = error
        self._resolved_at = time.perf_counter()
        if self._event is not None:
            self._event.set()

    def __repr__(self) -> str:
        return f"SolveTicket({int(self)}, done={self.done()})"
