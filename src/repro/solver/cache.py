"""Content-hash-keyed cache for sparsifiers / hierarchies / ELL slabs.

Building a preconditioner is the expensive part of a Laplacian solve
(pipeline steps 1-4: spanning tree, lifting, scores, recovery — then the
multilevel contraction).  Serving traffic hits the *same* graphs over and
over (same mesh, new right-hand sides), so the solver service keys every
built artifact by a SHA-256 fingerprint of the graph content plus the build
parameters and reuses it: a cache hit skips steps 1-4 entirely.

Two tiers:
  * in-memory LRU (capacity-bounded, per-process),
  * optional on-disk pickle directory (shared across processes/restarts).
"""
from __future__ import annotations

import collections
import hashlib
import os
import pickle
import tempfile
from typing import Any, Callable, Optional, Tuple

from repro.core.graph import Graph


def graph_fingerprint(graph: Graph, extra: tuple = ()) -> str:
    """SHA-256 over the canonical edge arrays + build parameters.

    ``build_graph`` canonicalizes (src < dst, sorted, deduped), so two
    logically identical graphs hash identically regardless of input edge
    order.  ``extra`` folds in solver parameters (alpha, precond, ...) so
    different builds of the same graph get distinct keys.
    """
    h = hashlib.sha256()
    h.update(b"pdgrass-graph-v1")
    h.update(int(graph.n).to_bytes(8, "little"))
    h.update(graph.src.tobytes())
    h.update(graph.dst.tobytes())
    h.update(graph.weight.tobytes())
    for item in extra:
        h.update(repr(item).encode())
    return h.hexdigest()


def pipeline_fingerprint(graph: Graph, config, extra: tuple = ()) -> str:
    """Fingerprint of (graph, PipelineConfig, extras).

    ``config.fingerprint()`` is the canonical JSON serialization of the
    staged pipeline config, so two services configured with equal config
    trees share cache entries, and any stage/knob difference (engine,
    score rule, alpha, ...) gets a distinct key.
    """
    return graph_fingerprint(graph,
                             extra=(config.fingerprint(),) + tuple(extra))


class LRUCache:
    """In-memory LRU with an optional on-disk second tier.

    ``get_or_build(key, build)`` returns ``(value, source)`` where source is
    "mem", "disk", or "miss" (built now).  The builder runs at most once per
    key per process; disk entries survive restarts.
    """

    def __init__(self, capacity: int = 16, disk_dir: Optional[str] = None):
        self.capacity = int(capacity)
        self.disk_dir = disk_dir
        self._mem: "collections.OrderedDict[str, Any]" = collections.OrderedDict()
        self.hits = 0
        self.disk_hits = 0
        self.misses = 0
        self.evictions = 0
        if disk_dir:
            os.makedirs(disk_dir, exist_ok=True)

    def __len__(self) -> int:
        return len(self._mem)

    def _disk_path(self, key: str) -> Optional[str]:
        return os.path.join(self.disk_dir, f"{key}.pkl") if self.disk_dir \
            else None

    def _put_mem(self, key: str, value: Any) -> None:
        self._mem[key] = value
        self._mem.move_to_end(key)
        while len(self._mem) > self.capacity:
            self._mem.popitem(last=False)
            self.evictions += 1

    def get(self, key: str) -> Tuple[Any, str]:
        """(value, "mem"|"disk") or (None, "miss") without building."""
        if key in self._mem:
            self._mem.move_to_end(key)
            self.hits += 1
            return self._mem[key], "mem"
        path = self._disk_path(key)
        if path and os.path.exists(path):
            with open(path, "rb") as f:
                value = pickle.load(f)
            self.disk_hits += 1
            self._put_mem(key, value)
            return value, "disk"
        return None, "miss"

    def put(self, key: str, value: Any) -> None:
        self._put_mem(key, value)
        path = self._disk_path(key)
        if path:
            # atomic write: never leave a torn pickle for a reader to load
            fd, tmp = tempfile.mkstemp(dir=self.disk_dir, suffix=".tmp")
            with os.fdopen(fd, "wb") as f:
                pickle.dump(value, f)
            os.replace(tmp, path)

    def get_or_build(self, key: str, build: Callable[[], Any]) -> Tuple[Any, str]:
        value, source = self.get(key)
        if source != "miss":
            return value, source
        self.misses += 1
        value = build()
        self.put(key, value)
        return value, "miss"

    @property
    def stats(self) -> dict:
        return {"hits": self.hits, "disk_hits": self.disk_hits,
                "misses": self.misses, "evictions": self.evictions,
                "size": len(self._mem), "capacity": self.capacity}
