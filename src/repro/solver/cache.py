"""Content-hash-keyed cache for sparsifiers / hierarchies / ELL slabs.

Building a preconditioner is the expensive part of a Laplacian solve
(pipeline steps 1-4: spanning tree, lifting, scores, recovery — then the
multilevel contraction).  Serving traffic hits the *same* graphs over and
over (same mesh, new right-hand sides), so the solver service keys every
built artifact by a SHA-256 fingerprint of the graph content plus the build
parameters and reuses it: a cache hit skips steps 1-4 entirely.

The graph-content part of the hash is O(m) and therefore memoized on the
``Graph`` instance itself (:func:`content_fingerprint`): the first request
for a graph pays one pass over the edge arrays, every later fingerprint —
any extras, any pipeline config — is a dict lookup plus a hash over two
short digests.  ``GraphStore.register`` in :mod:`repro.solver.requests`
builds on this to hand out handles that carry the digest explicitly.

Two tiers:
  * in-memory LRU (capacity-bounded, per-process),
  * optional on-disk pickle directory (shared across processes/restarts),
    bounded by ``disk_max_entries`` / ``disk_max_bytes`` with
    least-recently-used eviction (mtime is refreshed on every disk hit, so
    oldest-mtime == least recently used).
"""
from __future__ import annotations

import collections
import hashlib
import os
import pickle
import tempfile
from typing import Any, Callable, Optional, Tuple

from repro.core.graph import Graph
from repro.obs import Metrics, get_metrics, get_tracer

# Count of O(m) content hashes actually computed (memo misses).  Tests and
# ``SolverService.stats()`` read this to prove registered graphs are never
# re-fingerprinted on the request path.  Mirrored into the process-wide
# metrics registry as ``store.hash_events``.
HASH_EVENTS = 0


def content_fingerprint(graph: Graph) -> str:
    """SHA-256 over the canonical edge arrays, memoized per Graph instance.

    ``build_graph`` canonicalizes (src < dst, sorted, deduped), so two
    logically identical graphs hash identically regardless of input edge
    order.  The digest is cached in the instance ``__dict__`` (frozen
    dataclasses still own one), so the O(m) pass over the arrays runs at
    most once per graph object per process.  The hashed arrays are frozen
    (``writeable = False``) alongside the memo: an in-place edit that would
    silently desync the digest from the content now raises instead.
    """
    memo = graph.__dict__.get("_content_fp")
    if memo is not None:
        return memo
    global HASH_EVENTS
    HASH_EVENTS += 1
    get_metrics().inc("store.hash_events")
    h = hashlib.sha256()
    h.update(b"pdgrass-graph-v1")
    h.update(int(graph.n).to_bytes(8, "little"))
    h.update(graph.src.tobytes())
    h.update(graph.dst.tobytes())
    h.update(graph.weight.tobytes())
    fp = h.hexdigest()
    for arr in (graph.src, graph.dst, graph.weight):
        arr.flags.writeable = False
    object.__setattr__(graph, "_content_fp", fp)
    return fp


def graph_fingerprint(graph: Graph, extra: tuple = ()) -> str:
    """Fingerprint of (graph content, build parameters).

    ``extra`` folds in solver parameters (alpha, precond, ...) so different
    builds of the same graph get distinct keys.  Only the memoized content
    digest is rehashed here — never the edge arrays themselves.
    """
    h = hashlib.sha256()
    h.update(content_fingerprint(graph).encode())
    for item in extra:
        h.update(repr(item).encode())
    return h.hexdigest()


def mesh_descriptor(mesh, shard_axis: str):
    """Stable, hashable description of a solve mesh for artifact keying.

    The artifacts themselves (ELL slabs, hierarchy chain) are
    mesh-independent, but the *solver closures* built from them are not —
    and a restarted service on a different mesh must not adopt cache
    entries whose recorded parity guarantees were established under
    another shard count.  Keying by (axis name, axis size) is exactly the
    information that changes the sharded program; ``None`` (single-device)
    keys separately from every mesh.
    """
    if mesh is None:
        return None
    return ("mesh", str(shard_axis), int(mesh.shape[shard_axis]))


def artifact_key(content_fp: str, config, extra: tuple = ()) -> str:
    """Cache key from an already-computed content digest + PipelineConfig.

    The handle/scheduler path: ``GraphHandle`` carries ``content_fp``, the
    request carries the config, so keying a group is pure string hashing.
    ``config.fingerprint()`` is the canonical JSON serialization of the
    staged pipeline config — equal config trees share cache entries, any
    stage/knob difference (engine, score rule, alpha, ...) gets its own key.
    """
    h = hashlib.sha256()
    h.update(content_fp.encode())
    h.update(config.fingerprint().encode())
    for item in extra:
        h.update(repr(item).encode())
    return h.hexdigest()


def pipeline_fingerprint(graph: Graph, config, extra: tuple = ()) -> str:
    """Fingerprint of (graph, PipelineConfig, extras) — raw-Graph shim over
    :func:`artifact_key`."""
    return artifact_key(content_fingerprint(graph), config, extra)


class LRUCache:
    """In-memory LRU with an optional bounded on-disk second tier.

    ``get_or_build(key, build)`` returns ``(value, source)`` where source is
    "mem", "disk", or "miss" (built now).  The builder runs at most once per
    key per process; disk entries survive restarts.

    The disk tier is capped by ``disk_max_entries`` and/or ``disk_max_bytes``
    (``None`` = unbounded): after every write the directory is pruned,
    evicting least-recently-used pickles first (disk hits refresh mtime).
    The entry just written is never the eviction victim, so a single
    artifact larger than ``disk_max_bytes`` still round-trips.
    """

    def __init__(self, capacity: int = 16, disk_dir: Optional[str] = None,
                 disk_max_entries: Optional[int] = None,
                 disk_max_bytes: Optional[int] = None,
                 metrics: Optional[Metrics] = None):
        self.capacity = int(capacity)
        self.disk_dir = disk_dir
        self.disk_max_entries = disk_max_entries
        self.disk_max_bytes = disk_max_bytes
        self._mem: "collections.OrderedDict[str, Any]" = collections.OrderedDict()
        self.hits = 0
        self.disk_hits = 0
        self.misses = 0
        self.evictions = 0
        self.disk_evictions = 0
        # every legacy counter bump is mirrored into this registry under
        # ``cache.*`` (the service passes its per-service registry so two
        # services never share counters)
        self.metrics = metrics if metrics is not None else get_metrics()
        if disk_dir:
            os.makedirs(disk_dir, exist_ok=True)

    def __len__(self) -> int:
        return len(self._mem)

    def _disk_path(self, key: str) -> Optional[str]:
        return os.path.join(self.disk_dir, f"{key}.pkl") if self.disk_dir \
            else None

    def _disk_entries(self):
        """[(path, mtime, bytes)] for every pickle in the disk tier."""
        if not self.disk_dir:
            return []
        out = []
        for name in os.listdir(self.disk_dir):
            if not name.endswith(".pkl"):
                continue
            path = os.path.join(self.disk_dir, name)
            try:
                st = os.stat(path)
            except OSError:
                continue  # concurrently evicted by another process
            out.append((path, st.st_mtime, st.st_size))
        return out

    def _prune_disk(self, keep: str) -> None:
        """Evict least-recently-used pickles until under both caps; never
        evicts ``keep`` (the path just written)."""
        if self.disk_max_entries is None and self.disk_max_bytes is None:
            return
        entries = sorted(self._disk_entries(), key=lambda e: e[1])
        total = sum(size for _, _, size in entries)
        count = len(entries)
        for path, _, size in entries:
            over = ((self.disk_max_entries is not None
                     and count > self.disk_max_entries)
                    or (self.disk_max_bytes is not None
                        and total > self.disk_max_bytes))
            if not over:
                break
            if path == keep:
                continue
            try:
                os.remove(path)
            except OSError:
                continue
            self.disk_evictions += 1
            self.metrics.inc("cache.disk_evictions")
            count -= 1
            total -= size

    def _put_mem(self, key: str, value: Any) -> None:
        self._mem[key] = value
        self._mem.move_to_end(key)
        while len(self._mem) > self.capacity:
            self._mem.popitem(last=False)
            self.evictions += 1
            self.metrics.inc("cache.evictions")

    def get(self, key: str) -> Tuple[Any, str]:
        """(value, "mem"|"disk") or (None, "miss") without building."""
        with get_tracer().span("cache.get", key=key[:12]) as sp:
            if key in self._mem:
                self._mem.move_to_end(key)
                self.hits += 1
                self.metrics.inc("cache.mem_hits")
                sp.set(tier="mem")
                return self._mem[key], "mem"
            path = self._disk_path(key)
            if path:
                try:
                    with open(path, "rb") as f:
                        value = pickle.load(f)
                except (OSError, pickle.PickleError, EOFError, ValueError,
                        AttributeError, ImportError):
                    # not on disk — or evicted/torn/corrupted by a concurrent
                    # process between our stat and read, or pickled against a
                    # schema this process no longer has: a miss, rebuild
                    sp.set(tier="miss")
                    return None, "miss"
                try:
                    os.utime(path)  # refresh recency for mtime eviction
                except OSError:
                    pass
                self.disk_hits += 1
                self.metrics.inc("cache.disk_hits")
                self._put_mem(key, value)
                sp.set(tier="disk")
                return value, "disk"
            sp.set(tier="miss")
            return None, "miss"

    def put(self, key: str, value: Any) -> None:
        self._put_mem(key, value)
        path = self._disk_path(key)
        if path:
            # atomic write: never leave a torn pickle for a reader to load
            with get_tracer().span("cache.put_disk", key=key[:12]):
                fd, tmp = tempfile.mkstemp(dir=self.disk_dir, suffix=".tmp")
                with os.fdopen(fd, "wb") as f:
                    pickle.dump(value, f)
                os.replace(tmp, path)
                self._prune_disk(keep=path)

    def get_or_build(self, key: str, build: Callable[[], Any]) -> Tuple[Any, str]:
        value, source = self.get(key)
        if source != "miss":
            return value, source
        self.misses += 1
        self.metrics.inc("cache.misses")
        with get_tracer().span("cache.build", key=key[:12]):
            value = build()
        self.put(key, value)
        return value, "miss"

    @property
    def stats(self) -> dict:
        out = {"hits": self.hits, "disk_hits": self.disk_hits,
               "misses": self.misses, "evictions": self.evictions,
               "size": len(self._mem), "capacity": self.capacity}
        if self.disk_dir:
            entries = self._disk_entries()
            out.update({
                "disk_entries": len(entries),
                "disk_bytes": sum(size for _, _, size in entries),
                "disk_evictions": self.disk_evictions,
                "disk_max_entries": self.disk_max_entries,
                "disk_max_bytes": self.disk_max_bytes,
            })
        return out
