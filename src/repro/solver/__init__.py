"""repro.solver: multilevel sparsifier-preconditioned Laplacian solver service.

The first real *consumer* subsystem of the pdGRASS pipeline.  Four layers:

  * :mod:`repro.solver.hierarchy`  — recursive pdGRASS: sparsify, contract,
    re-sparsify (SF-GRASS-style) into a multilevel preconditioner chain.
  * :mod:`repro.solver.device_pcg` — fully jit'd batched-RHS PCG whose matvec
    routes through the Pallas ELL kernel and whose preconditioner applies the
    hierarchy via forward/backward tree sweeps (symmetric V-cycle).
  * :mod:`repro.solver.cache`      — content-hash-keyed sparsifier/hierarchy
    cache (in-memory LRU + optional on-disk) so repeated solves on the same
    graph skip pipeline steps 1-4 entirely.
  * :mod:`repro.solver.service`    — request/response solve engine with
    slot batching over right-hand sides (the serve/engine.py idiom).
"""
from repro.solver.cache import (LRUCache, graph_fingerprint,
                                pipeline_fingerprint)
from repro.solver.device_pcg import (BatchedPCGResult, batched_pcg,
                                     ell_laplacian, make_matvec, make_solver)
from repro.solver.hierarchy import Hierarchy, Level, build_hierarchy, subgraph
from repro.solver.service import SolveRequest, SolveResponse, SolverService

__all__ = [
    "Hierarchy", "Level", "build_hierarchy", "subgraph",
    "BatchedPCGResult", "batched_pcg", "ell_laplacian", "make_matvec",
    "make_solver",
    "LRUCache", "graph_fingerprint", "pipeline_fingerprint",
    "SolveRequest", "SolveResponse", "SolverService",
]
