"""repro.solver: multilevel sparsifier-preconditioned Laplacian solver service.

The first real *consumer* subsystem of the pdGRASS pipeline.  Five layers:

  * :mod:`repro.solver.hierarchy`  — recursive pdGRASS: sparsify, contract,
    re-sparsify (SF-GRASS-style) into a multilevel preconditioner chain.
    Contraction runs on the device by default (jit'd propose/accept
    heavy-edge matching composed from :mod:`repro.core.graph_ops`); the
    sequential host matching survives as the parity oracle.
  * :mod:`repro.solver.device_pcg` — fully jit'd batched-RHS PCG whose matvec
    routes through the Pallas ELL kernel and whose preconditioner applies the
    hierarchy via forward/backward tree sweeps (symmetric V-cycle with
    Chebyshev polynomial smoothing).
  * :mod:`repro.solver.sharded`    — the same PCG + V-cycle row-sharded
    under ``shard_map`` on a device mesh (halo matvec, psum reductions,
    replicated coarse solve), behind ``make_solver(mesh=...)`` /
    ``SolverService(mesh=...)``.
  * :mod:`repro.solver.cache`      — content-hash-keyed sparsifier/hierarchy
    cache (in-memory LRU + bounded on-disk tier) so repeated solves on the
    same graph skip pipeline steps 1-4 entirely.
  * :mod:`repro.solver.requests`   — the serving request plane: GraphStore /
    GraphHandle (register once, hash once), SolveRequest with per-request
    PipelineConfig overrides, SolveTicket futures.
  * :mod:`repro.solver.service`    — request/response solve engine: a
    mixed-config scheduler groups pending work by (graph fingerprint,
    config fingerprint) and slot-batches each group's right-hand sides.
"""
from repro.solver.cache import (LRUCache, artifact_key, content_fingerprint,
                                graph_fingerprint, pipeline_fingerprint)
from repro.solver.device_pcg import (BatchedPCGResult, batched_pcg,
                                     ell_laplacian, make_matvec, make_solver,
                                     make_vcycle)
from repro.solver.hierarchy import (Hierarchy, Level, build_hierarchy,
                                    device_contract, device_matching,
                                    sharded_contract, subgraph)
from repro.solver.requests import (AdmissionError, DeadlineExceededError,
                                   GraphHandle, GraphStore, SolveRequest,
                                   SolveResponse, SolveTicket)
from repro.solver.service import SolverService
from repro.solver.sharded import make_sharded_solver, shard_ell_slabs

__all__ = [
    "Hierarchy", "Level", "build_hierarchy", "subgraph",
    "device_contract", "device_matching", "sharded_contract",
    "BatchedPCGResult", "batched_pcg", "ell_laplacian", "make_matvec",
    "make_solver", "make_vcycle", "make_sharded_solver", "shard_ell_slabs",
    "LRUCache", "artifact_key", "content_fingerprint", "graph_fingerprint",
    "pipeline_fingerprint",
    "AdmissionError", "DeadlineExceededError", "GraphHandle", "GraphStore",
    "SolveRequest", "SolveResponse", "SolveTicket", "SolverService",
]
