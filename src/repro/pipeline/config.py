"""One frozen config tree for the whole sparsification pipeline.

The paper frames pdGRASS and feGRASS as the *same* two-step pipeline
(spanning tree -> off-tree edge recovery) that differ only in how recovery
is organized.  :class:`PipelineConfig` makes that literal: a sparsifier is
described by three named, pluggable stages

  * ``tree``     — which spanning tree seeds the sparsifier
                   (``low_stretch`` effective-weight Boruvka / plain
                   ``boruvka`` max-weight ST),
  * ``score``    — how off-tree edges are ranked (``w_times_r`` spectral
                   criticality / raw ``r`` resistance / ``er_sample``
                   Gumbel-top-k effective-resistance sampling / ``er_exact``
                   true leverage scores via batched Laplacian solves),
  * ``recovery`` — which engine walks the ranked edges (``rounds`` JAX
                   round engine / ``serial`` numpy oracle / ``distributed``
                   mesh engine / ``multipass`` loose-similarity feGRASS),

plus the scalar knobs they share (``alpha``, ``c``, ``chunk``).  Stage
implementations live in :mod:`repro.pipeline.stages` and are looked up by
name, so pdGRASS-vs-feGRASS is a config diff:

    >>> config_diff(pdgrass_config(), fegrass_config())
    {'recovery.kind': ('rounds', 'multipass'),
     'recovery.stop_at_target': (True, False)}

Configs serialize losslessly (``to_dict``/``from_dict``) and canonically
(``fingerprint``), which is what the solver cache keys and
``SolverService`` requests consume.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Optional


@dataclasses.dataclass(frozen=True)
class TreeConfig:
    """Stage 1: the spanning tree seeding the sparsifier."""

    kind: str = "low_stretch"   # low_stretch | boruvka


@dataclasses.dataclass(frozen=True)
class ScoreConfig:
    """Stage 2: the off-tree edge ranking rule."""

    kind: str = "w_times_r"     # w_times_r | r | er_sample | er_exact
    seed: int = 0               # er_sample: Gumbel-top-k sampling seed
    tol: float = 1e-6           # er_exact: exact-resistance solve tolerance


@dataclasses.dataclass(frozen=True)
class RecoveryConfig:
    """Stage 3: the engine that walks the ranked off-tree edges."""

    kind: str = "rounds"        # rounds | serial | distributed | multipass
    block_size: int = 16        # rounds/distributed: candidates per subtask
    max_candidates: int = 128   # rounds: global per-round candidate cap
    stop_at_target: bool = True  # rounds: stop once target edges recovered
    max_passes: int = 200_000   # multipass (feGRASS): pass-count safety cap
    cutoff: Optional[int] = None  # distributed: giant-subtask edge cutoff
    axis: str = "data"          # distributed: mesh axis name


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """The full sparsification pipeline: shared knobs + one config per stage."""

    alpha: float = 0.02         # off-tree edge budget: ceil(alpha * |V|)
    c: int = 8                  # similarity BFS cap (beta <= c)
    chunk: int = 2048           # padding / marking-pass tile rows
    tree: TreeConfig = dataclasses.field(default_factory=TreeConfig)
    score: ScoreConfig = dataclasses.field(default_factory=ScoreConfig)
    recovery: RecoveryConfig = dataclasses.field(
        default_factory=RecoveryConfig)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "PipelineConfig":
        return validate(_from_dict(cls, d))

    def fingerprint(self) -> str:
        """Canonical serialization — feeds ``solver.cache`` content hashes."""
        return json.dumps(self.to_dict(), sort_keys=True)

    def digest(self, n: int = 12) -> str:
        """Short stable hash of :meth:`fingerprint` — a human-sized label
        for per-config stats keys and log lines."""
        return hashlib.sha256(self.fingerprint().encode()).hexdigest()[:n]

    def replace(self, **overrides) -> "PipelineConfig":
        return dataclasses.replace(self, **overrides)


_SUBCONFIGS = {"tree": TreeConfig, "score": ScoreConfig,
               "recovery": RecoveryConfig}


def _from_dict(cls, d):
    if not isinstance(d, dict):
        raise TypeError(f"{cls.__name__} wants a dict, got {type(d).__name__}")
    fields = {f.name for f in dataclasses.fields(cls)}
    unknown = set(d) - fields
    if unknown:
        raise ValueError(
            f"unknown {cls.__name__} keys {sorted(unknown)}; "
            f"valid: {sorted(fields)}")
    kw = {}
    for name, value in d.items():
        sub = _SUBCONFIGS.get(name) if cls is PipelineConfig else None
        kw[name] = _from_dict(sub, value) if sub is not None else value
    return cls(**kw)


def validate(cfg: PipelineConfig) -> PipelineConfig:
    """Check every stage name against its registry; raise on unknowns."""
    from repro.pipeline import stages  # late import: stages imports configs

    for label, kind, registry in (
            ("tree", cfg.tree.kind, stages.TREE_STAGES),
            ("score", cfg.score.kind, stages.SCORE_STAGES),
            ("recovery", cfg.recovery.kind, stages.RECOVERY_ENGINES)):
        if kind not in registry:
            raise ValueError(
                f"unknown {label} stage {kind!r}; registered: "
                f"{sorted(registry)}")
    if not cfg.alpha > 0:
        raise ValueError(f"alpha must be positive, got {cfg.alpha}")
    if cfg.c < 1:
        raise ValueError(f"c must be >= 1, got {cfg.c}")
    if cfg.chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {cfg.chunk}")
    return cfg


def config_diff(a: PipelineConfig, b: PipelineConfig) -> dict:
    """Flat ``{"stage.field": (a_value, b_value)}`` of differing leaves."""
    def flatten(d, prefix=""):
        out = {}
        for k, v in d.items():
            if isinstance(v, dict):
                out.update(flatten(v, f"{prefix}{k}."))
            else:
                out[f"{prefix}{k}"] = v
        return out

    fa, fb = flatten(a.to_dict()), flatten(b.to_dict())
    return {k: (fa[k], fb[k]) for k in fa if fa[k] != fb[k]}


# ---------------------------------------------------------------------------
# The two named family members, as config factories
# ---------------------------------------------------------------------------

def pdgrass_config(alpha: float = 0.02, *, c: int = 8, chunk: int = 2048,
                   engine: str = "rounds", score_mode: str = "w_times_r",
                   tree: str = "low_stretch", seed: int = 0,
                   block_size: int = 16, max_candidates: int = 128,
                   stop_at_target: bool = True,
                   cutoff: Optional[int] = None,
                   axis: str = "data") -> PipelineConfig:
    """The paper's Algorithm 1: strict similarity, single-pass engines."""
    return validate(PipelineConfig(
        alpha=alpha, c=c, chunk=chunk,
        tree=TreeConfig(kind=tree),
        score=ScoreConfig(kind=score_mode, seed=seed),
        recovery=RecoveryConfig(
            kind=engine, block_size=block_size,
            max_candidates=max_candidates, stop_at_target=stop_at_target,
            cutoff=cutoff, axis=axis),
    ))


def fegrass_config(alpha: float = 0.02, *, c: int = 8, chunk: int = 2048,
                   score_mode: str = "w_times_r", tree: str = "low_stretch",
                   max_passes: int = 200_000) -> PipelineConfig:
    """The baseline (paper Table II): loose similarity, multi-pass recovery.

    Same tree and score stages as :func:`pdgrass_config` — the paper shares
    steps 1-2 for an apples-to-apples recovery comparison — so the entire
    pdGRASS-vs-feGRASS story is the ``recovery`` stage diff.
    """
    return validate(PipelineConfig(
        alpha=alpha, c=c, chunk=chunk,
        tree=TreeConfig(kind=tree),
        score=ScoreConfig(kind=score_mode),
        recovery=RecoveryConfig(kind="multipass", stop_at_target=False,
                                max_passes=max_passes),
    ))
