"""repro.pipeline: the unified staged sparsification API.

One frozen :class:`PipelineConfig` tree describes a sparsifier as three
named, pluggable stages (tree / score / recovery); :class:`Pipeline` runs
it.  pdGRASS and feGRASS are both configurations of this harness — see
:func:`pdgrass_config` / :func:`fegrass_config` and ``config_diff``.

    from repro.pipeline import Pipeline, pdgrass_config
    sp = Pipeline(pdgrass_config(alpha=0.05)).run(graph)

The legacy entry points ``repro.core.pdgrass`` / ``repro.core.fegrass``
remain as thin wrappers over this package.
"""
from repro.pipeline.api import Pipeline, run_pipeline
from repro.pipeline.config import (PipelineConfig, RecoveryConfig,
                                   ScoreConfig, TreeConfig, config_diff,
                                   fegrass_config, pdgrass_config, validate)
from repro.pipeline.stages import (RECOVERY_ENGINES, SCORE_STAGES,
                                   TREE_STAGES, register)

__all__ = [
    "Pipeline", "run_pipeline",
    "PipelineConfig", "TreeConfig", "ScoreConfig", "RecoveryConfig",
    "pdgrass_config", "fegrass_config", "config_diff", "validate",
    "TREE_STAGES", "SCORE_STAGES", "RECOVERY_ENGINES", "register",
]
