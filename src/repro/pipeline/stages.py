"""Stage registries: named, pluggable implementations for each pipeline stage.

Three registries, looked up by the ``kind`` strings in
:mod:`repro.pipeline.config`:

  * ``TREE_STAGES``      — ``(n, src, dst, weight, TreeConfig) -> TreeResult``
  * ``SCORE_STAGES``     — ``(w_off, r_tree, ScoreConfig, **ctx) ->
                             score [m_off]``
  * ``RECOVERY_ENGINES`` — ``(prep, target, PipelineConfig, **ctx) ->
                             (recovered_mask [graph.m] bool, stats dict)``

Registering a new stage is one decorated function — the GRASS family
(GRASS, feGRASS, pdGRASS, SF-GRASS) is a grid of (scoring rule x tree
strategy x recovery engine), and every cell is a config, not a fork.
``ctx`` carries runtime-only objects that don't belong in a serializable
config (the device ``mesh`` for the distributed engine; for score stages,
the host ``graph``, the tree membership mask, and the off-tree endpoints
``u``/``v`` that ``er_exact`` solves against).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import recovery as rec_mod
from repro.core import spanning_tree as st_mod
from repro.pipeline.config import PipelineConfig, ScoreConfig, TreeConfig

TREE_STAGES: dict = {}
SCORE_STAGES: dict = {}
RECOVERY_ENGINES: dict = {}


def register(registry: dict, name: str):
    def deco(fn):
        registry[name] = fn
        return fn
    return deco


# ---------------------------------------------------------------------------
# Tree stages (paper step 1)
# ---------------------------------------------------------------------------

@register(TREE_STAGES, "low_stretch")
def tree_low_stretch(n, src, dst, weight, cfg: TreeConfig):
    """feGRASS Definition 1: max-ST over effective weights (low-stretch)."""
    return st_mod.build_spanning_tree(n, src, dst, weight,
                                      mode="low_stretch")


@register(TREE_STAGES, "boruvka")
def tree_boruvka(n, src, dst, weight, cfg: TreeConfig):
    """Plain maximum-weight spanning tree (Boruvka on the raw weights)."""
    return st_mod.build_spanning_tree(n, src, dst, weight, mode="boruvka")


# ---------------------------------------------------------------------------
# Score stages (paper step 2: spectral criticality ordering)
# ---------------------------------------------------------------------------

@register(SCORE_STAGES, "w_times_r")
def score_w_times_r(w, r_t, cfg: ScoreConfig, **_):
    """Spectral criticality w(e) * R_T(e) — the feGRASS/pdGRASS default."""
    return w * r_t


@register(SCORE_STAGES, "r")
def score_r(w, r_t, cfg: ScoreConfig, **_):
    """Raw tree resistance distance (ignores the edge weight)."""
    return r_t


@register(SCORE_STAGES, "er_sample")
def score_er_sample(w, r_t, cfg: ScoreConfig, **_):
    """Effective-resistance sampling order (Spielman-Srivastava style).

    Gumbel-top-k: ranking by ``log(w * R_T) + Gumbel(seed)`` and keeping the
    top ``target`` draws a sample *without replacement* with inclusion
    probability proportional to w(e) * R_T(e) — the leverage-score proxy —
    instead of the deterministic top scores.  Deterministic per seed.
    """
    key = jax.random.PRNGKey(cfg.seed)
    gumbel = jax.random.gumbel(key, w.shape, dtype=w.dtype)
    return jnp.log(jnp.maximum(w * r_t, 1e-30)) + gumbel


@register(SCORE_STAGES, "er_exact")
def score_er_exact(w, r_t, cfg: ScoreConfig, *, graph=None, in_tree=None,
                   u=None, v=None, **_):
    """True leverage scores w(e) * R_G(e) from batched Laplacian solves.

    Replaces the tree-resistance proxy ``R_T`` (an upper bound that can
    badly over-rank edges shortcut elsewhere) with the exact effective
    resistance of the *full* graph, computed on the spanning-tree-
    preconditioned solver — the ground truth ``er_sample`` approximates.
    ``cfg.tol`` is the per-column solve tolerance.
    """
    if graph is None:
        raise ValueError("er_exact needs graph context (graph, in_tree, "
                         "u, v) from the pipeline; bare calls only get "
                         "the tree proxy")
    # Late import: pipeline <- spectral <- solver <- pipeline would cycle
    # at module load; by call time every module is initialized.
    from repro.spectral.resistance import exact_offtree_resistances

    r = exact_offtree_resistances(graph, in_tree, u, v, tol=cfg.tol)
    return w * jnp.asarray(r, dtype=w.dtype)


# ---------------------------------------------------------------------------
# Recovery engines (paper step 4)
# ---------------------------------------------------------------------------

def mask_from_status(prep, status, target) -> np.ndarray:
    """Top-``target`` recovered rows by score -> [graph.m] bool edge mask."""
    keep = np.asarray(rec_mod.select_top(
        jnp.asarray(status), prep.problem.score, target))
    keep = keep[: prep.m_off]
    mask = np.zeros(prep.graph.m, dtype=bool)
    mask[prep.off_edge_id[keep]] = True
    return mask


@register(RECOVERY_ENGINES, "rounds")
def engine_rounds(prep, target, cfg: PipelineConfig, **ctx):
    """The JAX round engine (strict similarity, single logical pass)."""
    r = cfg.recovery
    status, stats = rec_mod.recover_rounds(
        prep.problem, jnp.int32(target),
        block_size=r.block_size, max_candidates=r.max_candidates,
        stop_at_target=r.stop_at_target, chunk=cfg.chunk)
    # one designated sync for all three counters instead of three
    # sequential blocking scalarizations
    rounds, candidates, killed = jax.device_get(
        (stats.rounds, stats.candidates, stats.killed_in_block))
    return mask_from_status(prep, status, target), {
        "rounds": int(rounds),
        "candidates": int(candidates),
        "killed_in_block": int(killed),
    }


@register(RECOVERY_ENGINES, "serial")
def engine_serial(prep, target, cfg: PipelineConfig, **ctx):
    """The numpy oracle — the paper's sequential per-subtask greedy."""
    status = rec_mod.recover_serial(prep.problem)
    return mask_from_status(prep, status, target), {"rounds": -1}


@register(RECOVERY_ENGINES, "distributed")
def engine_distributed(prep, target, cfg: PipelineConfig, mesh=None, **ctx):
    """The mixed outer/inner mesh engine from :mod:`repro.core.distributed`.

    ``mesh`` comes through the runtime context (``Pipeline.run(..., mesh=m)``);
    without one, a 1-axis mesh over all local devices is built.
    """
    from repro.core import distributed as dist_mod

    r = cfg.recovery
    if mesh is None:
        from repro.launch.mesh import compat_make_mesh

        mesh = compat_make_mesh((jax.device_count(),), (r.axis,))
    status = dist_mod.recover_mixed(
        prep, mesh, axis=r.axis, block_size=r.block_size,
        max_candidates=r.max_candidates, chunk=cfg.chunk, cutoff=r.cutoff)
    return mask_from_status(prep, status, target), {
        "rounds": -1, "n_shards": int(mesh.shape[r.axis])}


@register(RECOVERY_ENGINES, "multipass")
def engine_multipass(prep, target, cfg: PipelineConfig, **ctx):
    """feGRASS recovery: loose (vertex-cover) similarity, multi-pass, host.

    This is the baseline the paper measures against (its Table II); running
    it under the same ``Pipeline`` harness makes pdGRASS-vs-feGRASS a pure
    recovery-stage diff.
    """
    from repro.core.fegrass import loose_multipass_recover

    return loose_multipass_recover(prep, target, c=cfg.c,
                                   max_passes=cfg.recovery.max_passes)
