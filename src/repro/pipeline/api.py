"""The staged sparsification pipeline: one object, three pluggable stages.

    from repro.pipeline import Pipeline, pdgrass_config, fegrass_config

    pipe = Pipeline(pdgrass_config(alpha=0.05))
    sparsifier = pipe.run(graph)

    # feGRASS is the same harness with a different recovery stage:
    base = Pipeline(fegrass_config(alpha=0.05)).run(graph)

``prepare`` runs the shared steps 1-3 (tree stage, binary lifting, score
stage, subtask grouping) and returns a :class:`repro.core.sparsify.Prepared`
that any engine can consume — comparing engines on identical inputs (the
paper's apples-to-apples protocol) is ``run(g, prepared=shared_prep)``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import lifting as lift_mod
from repro.core import recovery as rec_mod
from repro.core.graph import Graph
from repro.core.sparsify import Prepared, Sparsifier
from repro.obs import get_metrics, get_tracer
from repro.pipeline.config import PipelineConfig, validate
from repro.pipeline.stages import RECOVERY_ENGINES, SCORE_STAGES, TREE_STAGES


class Pipeline:
    """A configured sparsification pipeline; stateless apart from its config."""

    def __init__(self, config: Optional[PipelineConfig] = None):
        self.config = validate(config if config is not None
                               else PipelineConfig())

    def __repr__(self) -> str:
        c = self.config
        return (f"Pipeline(tree={c.tree.kind!r}, score={c.score.kind!r}, "
                f"recovery={c.recovery.kind!r}, alpha={c.alpha})")

    # -- steps 1-3: tree, lifting, scores, subtask grouping ------------------

    def prepare(self, graph: Graph) -> Prepared:
        """Everything up to (and excluding) edge recovery — engine-agnostic."""
        cfg = self.config
        n, c, chunk = graph.n, cfg.c, cfg.chunk
        tracer = get_tracer()
        with tracer.span("pipeline.prepare", n=n, m=graph.m) as psp:
            src = jnp.asarray(graph.src)
            dst = jnp.asarray(graph.dst)
            w = jnp.asarray(graph.weight)

            with tracer.span("pipeline.tree", kind=cfg.tree.kind):
                tree = TREE_STAGES[cfg.tree.kind](n, src, dst, w, cfg.tree)
            with tracer.span("pipeline.lifting"):
                lift = lift_mod.build_lifting(n, tree.parent, tree.parent_w,
                                              tree.depth)

            in_tree = np.asarray(tree.in_tree)
            off_ids = np.flatnonzero(~in_tree)
            ou = jnp.asarray(graph.src[off_ids])
            ov = jnp.asarray(graph.dst[off_ids])
            ow = jnp.asarray(graph.weight[off_ids])

            with tracer.span("pipeline.scores", kind=cfg.score.kind,
                             m_off=int(off_ids.shape[0])):
                l = lift_mod.lca(lift, ou, ov)
                r_t = lift_mod.resistance_distance(lift, ou, ov, l)
                score = SCORE_STAGES[cfg.score.kind](
                    ow, r_t, cfg.score,
                    # runtime ctx for solver-backed stages (er_exact): the
                    # host graph, tree membership, off-tree endpoints
                    graph=graph, in_tree=in_tree,
                    u=graph.src[off_ids], v=graph.dst[off_ids])

                depth = lift.depth
                beta = jnp.minimum(
                    jnp.minimum(depth[ou] - depth[l], depth[ov] - depth[l]), c
                ).astype(jnp.int32)

                sig = lift_mod.ancestor_signatures(tree.parent, c)
                sig_u = sig[ou]
                sig_v = sig[ov]

            with tracer.span("pipeline.grouping"):
                # Host-side ordering: LCA ascending, score descending
                # (stable).
                l_np = np.asarray(l)
                score_np = np.asarray(score)
                order = np.lexsort((-score_np, l_np))
                l_sorted = l_np[order]
                if len(l_sorted):
                    seg_change = np.concatenate(
                        [[True], l_sorted[1:] != l_sorted[:-1]])
                    seg_ids = np.cumsum(seg_change) - 1
                    n_subtasks = int(seg_ids[-1]) + 1
                else:  # graph is a tree — no off-tree edges, no subtasks
                    seg_ids = np.zeros(0, dtype=np.int64)
                    n_subtasks = 0
                sizes = np.bincount(seg_ids, minlength=max(n_subtasks, 1))

                m_off = off_ids.shape[0]
                m_pad = max(chunk, int(math.ceil(m_off / chunk)) * chunk)
                pad = m_pad - m_off

                def pad_rows(x, fill, reorder=True):
                    x = np.asarray(x)
                    if reorder:
                        x = x[order]
                    if pad:
                        shape = (pad,) + x.shape[1:]
                        x = np.concatenate(
                            [x, np.full(shape, fill, dtype=x.dtype)])
                    return jnp.asarray(x)

                problem = rec_mod.RecoveryProblem(
                    sig_u=pad_rows(sig_u, -1),
                    sig_v=pad_rows(sig_v, -1),
                    beta=pad_rows(beta, -1),
                    # seg_ids already in sorted order (built from l_sorted)
                    seg=pad_rows(seg_ids.astype(np.int32), -1,
                                 reorder=False),
                    score=pad_rows(score_np, -np.inf),
                )
            psp.set(n_subtasks=n_subtasks, m_off=int(m_off))
        get_metrics().inc("pipeline.prepares")
        return Prepared(
            graph=graph, tree=tree, lift=lift,
            off_edge_id=off_ids[order],
            problem=problem, n_subtasks=n_subtasks,
            subtask_sizes=sizes,
        )

    # -- step 4: recovery through the configured engine ----------------------

    def run(self, graph: Graph, prepared: Optional[Prepared] = None,
            **ctx) -> Sparsifier:
        """Full pipeline -> :class:`Sparsifier`.

        ``prepared`` reuses shared steps 1-3 across configs/engines; ``ctx``
        forwards runtime-only objects to the engine (e.g. ``mesh=...`` for
        the distributed engine).
        """
        cfg = self.config
        prep = prepared if prepared is not None else self.prepare(graph)
        target = min(int(math.ceil(cfg.alpha * graph.n)), prep.m_off)

        engine = RECOVERY_ENGINES[cfg.recovery.kind]
        with get_tracer().span("pipeline.recovery", kind=cfg.recovery.kind,
                               target=target) as rsp:
            recovered_mask, engine_stats = engine(prep, target, cfg, **ctx)
            rsp.set(n_recovered=int(recovered_mask.sum()))
        m = get_metrics()
        m.inc("pipeline.runs")
        m.inc(f"pipeline.engine.{cfg.recovery.kind}")

        stats = dict(engine_stats)
        # Strict-similarity engines complete in one pass (the paper's claim);
        # the multipass engine reports its own pass count.
        stats.setdefault("passes", 1)
        stats.update(
            n_recovered=int(recovered_mask.sum()),
            target=target,
            n_subtasks=prep.n_subtasks,
            max_subtask=int(prep.subtask_sizes.max()) if prep.n_subtasks
            else 0,
        )
        return Sparsifier(graph=graph,
                          tree_mask=np.asarray(prep.tree.in_tree),
                          recovered_mask=recovered_mask, stats=stats)


def run_pipeline(graph: Graph, config: Optional[PipelineConfig] = None,
                 **ctx) -> Sparsifier:
    """One-shot convenience: ``Pipeline(config).run(graph, **ctx)``."""
    return Pipeline(config).run(graph, **ctx)
