"""Traffic replay: open-loop arrival schedules + latency measurement.

The daemon's figure of merit is wall-clock under a deadline, not just
algorithmic cost (LGRASS, arXiv 2212.07297), so it is stressed the way a
serving system is stressed: an **open-loop** workload submits requests at
pre-scheduled arrival times regardless of completions (offered load is
independent of the system's ability to keep up — a saturated system shows
queueing delay, not a silently throttled workload).

The schedule is fully deterministic: arrival gaps, tenant assignment, and
every RHS vector derive from one seed (``np.random.default_rng``) — no
wall-clock randomness anywhere in the workload.  The only nondeterminism
at replay time is the machine itself.

    schedule = make_schedule(n_requests=64, rate_hz=200.0, seed=7)
    rep = replay_daemon(daemon, handle, schedule)     # or replay_sync(svc, ...)
    rep.p50_ms, rep.p99_ms, rep.throughput_rps

Latency is measured from the *scheduled* arrival to ticket resolution
(daemon mode: the resolution timestamp the flusher stamped on the ticket;
sync mode: the flush return), so a driver that falls behind still charges
the system, as an open-loop harness must.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.solver.requests import GraphHandle, SolveRequest
from repro.solver.service import SolverService


@dataclasses.dataclass(frozen=True)
class ReplayEvent:
    """One scheduled arrival: time offset (s), tenant lane, RHS width."""

    t: float
    tenant: str
    width: int
    rhs_seed: int


def make_schedule(n_requests: int, rate_hz: float, seed: int = 0,
                  tenants: Sequence[Tuple[str, float]] = (("default", 1.0),),
                  width: int = 1) -> List[ReplayEvent]:
    """Deterministic open-loop schedule: exponential inter-arrival gaps at
    ``rate_hz`` offered load, tenants drawn with the given relative
    probabilities.  Same seed, same schedule — byte for byte."""
    if n_requests < 1:
        raise ValueError(f"n_requests must be >= 1, got {n_requests}")
    if rate_hz <= 0:
        raise ValueError(f"rate_hz must be > 0, got {rate_hz}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_hz, size=n_requests)
    arrivals = np.cumsum(gaps) - gaps[0]        # first arrival at t=0
    names = [t for t, _ in tenants]
    probs = np.asarray([w for _, w in tenants], dtype=np.float64)
    probs = probs / probs.sum()
    lanes = rng.choice(len(names), size=n_requests, p=probs)
    return [ReplayEvent(t=float(arrivals[i]), tenant=names[int(lanes[i])],
                        width=width, rhs_seed=seed * 1_000_003 + i)
            for i in range(n_requests)]


def make_rhs(n: int, event: ReplayEvent) -> np.ndarray:
    """The event's deterministic right-hand side(s): ``[n]`` (width 1) or
    ``[n, width]`` standard normals from the event's own seed."""
    rng = np.random.default_rng(event.rhs_seed)
    b = rng.standard_normal((n, event.width)).astype(np.float32)
    return b[:, 0] if event.width == 1 else b


@dataclasses.dataclass
class ReplayReport:
    """Per-run latency/throughput summary with the raw samples attached."""

    mode: str                    # "daemon" | "sync"
    rate_hz: float               # offered load
    n_requests: int
    latencies_ms: List[float]    # per request, scheduled-arrival -> resolved
    duration_s: float            # first arrival -> last resolution
    errors: int = 0
    tenant_latencies_ms: Dict[str, List[float]] = \
        dataclasses.field(default_factory=dict)

    def percentile(self, p: float) -> float:
        if not self.latencies_ms:
            return 0.0
        return float(np.percentile(np.asarray(self.latencies_ms), p))

    @property
    def p50_ms(self) -> float:
        return self.percentile(50)

    @property
    def p99_ms(self) -> float:
        return self.percentile(99)

    @property
    def throughput_rps(self) -> float:
        if self.duration_s <= 0:
            return 0.0
        return self.n_requests / self.duration_s

    def to_record(self) -> dict:
        """bench-v1 row: everything a dashboard needs, JSON-safe."""
        return {
            "mode": self.mode,
            "rate_hz": self.rate_hz,
            "n_requests": self.n_requests,
            "errors": self.errors,
            "p50_ms": self.p50_ms,
            "p90_ms": self.percentile(90),
            "p99_ms": self.p99_ms,
            "max_ms": max(self.latencies_ms) if self.latencies_ms else 0.0,
            "throughput_rps": self.throughput_rps,
            "duration_s": self.duration_s,
            "tenants": {t: {"n": len(ls),
                            "p50_ms": float(np.percentile(ls, 50)),
                            "p99_ms": float(np.percentile(ls, 99))}
                        for t, ls in sorted(self.tenant_latencies_ms.items())
                        if ls},
        }


def _drive(submit_one, schedule: List[ReplayEvent]):
    """Open-loop driver: sleep to each scheduled arrival (never waiting for
    completions), submit, and return per-event (scheduled_abs_time, token)
    pairs.  A driver running behind schedule submits immediately — the
    lateness is charged to the system via the scheduled-arrival latency
    convention."""
    t0 = time.perf_counter()
    out = []
    for ev in schedule:
        target = t0 + ev.t
        delay = target - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        out.append((target, ev, submit_one(ev)))
    return out


def replay_daemon(daemon, handle: GraphHandle, schedule: List[ReplayEvent],
                  tol: float = 1e-5, maxiter: int = 2000,
                  timeout: float = 120.0) -> ReplayReport:
    """Replay ``schedule`` through a :class:`SolverDaemon` (no flush calls
    anywhere): submit open-loop, then collect every ticket.  Latency uses
    the resolution timestamp the flusher stamped on each ticket, so late
    collection by this driver costs nothing."""
    n = handle.n

    def submit_one(ev: ReplayEvent):
        return daemon.submit(
            SolveRequest(graph=handle, b=make_rhs(n, ev), tol=tol,
                         maxiter=maxiter), tenant=ev.tenant)

    submitted = _drive(submit_one, schedule)
    lat, by_tenant, errors, t_last = [], {}, 0, 0.0
    for scheduled, ev, ticket in submitted:
        try:
            ticket.result(timeout=timeout)
        except Exception:
            errors += 1
            continue
        resolved = ticket._resolved_at       # perf_counter, set by flusher
        ms = (resolved - scheduled) * 1e3
        lat.append(ms)
        by_tenant.setdefault(ev.tenant, []).append(ms)
        t_last = max(t_last, resolved)
    t0 = submitted[0][0]
    return ReplayReport(
        mode="daemon", rate_hz=_offered_rate(schedule),
        n_requests=len(schedule), latencies_ms=lat,
        duration_s=max(t_last - t0, 0.0), errors=errors,
        tenant_latencies_ms=by_tenant)


def replay_sync(service: SolverService, handle: GraphHandle,
                schedule: List[ReplayEvent], tol: float = 1e-5,
                maxiter: int = 2000) -> ReplayReport:
    """The pre-daemon baseline: every arrival submits and immediately
    flushes on the caller's thread (the v2 ``result()``-triggers-flush
    discipline, one request per flush).  Same open-loop latency
    convention, so saturation shows up as schedule lag."""
    n = handle.n

    def submit_one(ev: ReplayEvent):
        ticket = service.submit(
            SolveRequest(graph=handle, b=make_rhs(n, ev), tol=tol,
                         maxiter=maxiter))
        try:
            ticket.result()                  # synchronous flush, per call
        except Exception:
            pass                             # counted via ticket.error()
        return ticket

    submitted = _drive(submit_one, schedule)
    lat, by_tenant, errors, t_last = [], {}, 0, 0.0
    for scheduled, ev, ticket in submitted:
        if ticket.error() is not None:
            errors += 1
            continue
        resolved = ticket._resolved_at
        ms = (resolved - scheduled) * 1e3
        lat.append(ms)
        by_tenant.setdefault(ev.tenant, []).append(ms)
        t_last = max(t_last, resolved)
    t0 = submitted[0][0]
    return ReplayReport(
        mode="sync", rate_hz=_offered_rate(schedule),
        n_requests=len(schedule), latencies_ms=lat,
        duration_s=max(t_last - t0, 0.0), errors=errors,
        tenant_latencies_ms=by_tenant)


def _offered_rate(schedule: List[ReplayEvent]) -> float:
    if len(schedule) < 2 or schedule[-1].t <= 0:
        return 0.0
    return (len(schedule) - 1) / schedule[-1].t
