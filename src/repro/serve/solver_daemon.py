"""Async serving runtime: a background flusher daemon over SolverService.

The v2 request plane batches on the *caller's* thread: ``SolveTicket.
result()`` triggers a synchronous ``flush()``, so latency is whatever the
calling code's flush discipline happens to be, and the queue dies with the
caller.  This module adds the daemon-grade serving loop the "millions of
users" north star implies:

    svc = SolverService(disk_dir="cache")          # store persists beside it
    daemon = SolverDaemon(svc, max_batch_delay_ms=25.0,
                          tenants={"paid": TenantConfig(max_pending_columns=256,
                                                        weight=4.0),
                                   "free": TenantConfig(max_pending_columns=64)})
    h = svc.register(g)
    t = daemon.submit(SolveRequest(graph=h, b=b), tenant="paid")
    x = t.result(timeout=1.0).x                    # no flush() anywhere
    daemon.close()                                 # drains, then stops

Three mechanisms, one thread:

  * **Deadline + size batching.**  A background flusher thread sleeps until
    the oldest queued request's deadline (``submit time +
    max_batch_delay_ms`` — the SLO knob) or until ``max_batch_columns``
    RHS columns are queued, whichever comes first, then drains a batch
    through the service's (graph, config)-group scheduler.  Requests
    carrying ``SolveRequest(deadline_ms=...)`` get a queue-side TTL: an
    entry still queued that long past submit is *expired* — failed with a
    typed :class:`~repro.solver.requests.DeadlineExceededError` instead of
    solved — so a saturated daemon sheds dead work rather than burning
    solve time on answers nobody is waiting for.  pdGRASS's
    organizing move — disjoint subtasks with no cross-dependencies — is
    what makes those fingerprint groups safe to dispatch from a daemon
    loop: groups fail independently, so one tenant's poisoned request
    never loses another's tickets across the thread boundary.
  * **Multi-tenant fairness.**  ``submit(request, tenant=...)`` enforces
    per-tenant pending-column budgets (typed :class:`AdmissionError` with
    tenant context) and weighted priority lanes.  Batch selection is
    starvation-free: every tenant with queued work contributes its oldest
    entry to every cycle (tenants ordered oldest-deadline-first), then the
    remaining column budget fills by weighted deficit round-robin — a
    flood from one tenant can delay, but never exclude, another.
  * **Event-resolved tickets.**  Daemon tickets carry a per-ticket
    ``threading.Event``: ``result(timeout=...)`` blocks until the flusher
    resolves them, ``done()`` stays non-blocking, and ``close(drain=True)``
    settles every queued ticket deterministically (``drain=False`` fails
    them with :class:`DaemonShutdownError` instead — never a hang).

Observability (all in the service's metrics registry, ``serve.*``): a
``serve.flush_cycle`` span per cycle (samplable in production via
``Tracer(sample_rate=...)``), a ``serve.queue_depth`` gauge,
``serve.queue_wait_ms`` / ``serve.e2e_ms`` latency histograms, and a
``serve.slo_violations`` counter incremented when a flush group's
end-to-end latency exceeds the ``max_batch_delay_ms``-derived budget.
"""
from __future__ import annotations

import copy
import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional

from repro.obs import get_tracer
from repro.solver.requests import (AdmissionError, DeadlineExceededError,
                                   GraphHandle, SolveRequest, SolveTicket)
from repro.solver.service import SolverService


class DaemonShutdownError(RuntimeError):
    """The daemon was closed (``drain=False``) before this ticket's batch
    ran; the request was never solved and should be re-submitted elsewhere."""


@dataclasses.dataclass(frozen=True)
class TenantConfig:
    """Per-tenant admission + scheduling policy.

    ``max_pending_columns`` bounds the tenant's queued RHS columns (``None``
    = unbounded); ``weight`` scales its share of each size-limited batch
    (weight 2 drains twice the columns of weight 1 under contention —
    starvation-freedom holds at any weight, the guaranteed floor is one
    entry per cycle)."""

    max_pending_columns: Optional[int] = None
    weight: float = 1.0

    def __post_init__(self):
        if not self.weight > 0:
            raise ValueError(f"tenant weight must be > 0, got {self.weight}")


@dataclasses.dataclass
class _Lane:
    """Mutable runtime state of one tenant."""

    config: TenantConfig
    pending_columns: int = 0
    credit: float = 0.0          # weighted deficit counter (see _select)
    submitted: int = 0
    rejected: int = 0
    solved: int = 0
    failed: int = 0
    expired: int = 0             # queue-side TTL expiries (deadline_ms)


@dataclasses.dataclass
class _Entry:
    """One queued request with its serving metadata."""

    ticket: SolveTicket
    handle: GraphHandle
    request: SolveRequest
    tenant: str
    cols: int
    t_submit: float              # daemon clock
    deadline: float              # t_submit + max_batch_delay
    expiry: Optional[float] = None  # t_submit + deadline_ms (queue TTL)


class SolverDaemon:
    """Background flusher with deadline/size batching and tenant fairness.

    Wraps (does not replace) a :class:`SolverService`: ``submit`` goes to
    the daemon, everything else — registration, warmup, stats, the cache
    and store planes — stays on the service.  One daemon per service; the
    synchronous ``service.submit``/``flush`` path keeps working beside it
    (separate queues), but daemon traffic never requires it.

    ``clock`` is injectable (monotonic seconds) for deterministic tests.
    """

    def __init__(self, service: SolverService,
                 max_batch_delay_ms: float = 25.0,
                 max_batch_columns: Optional[int] = None,
                 tenants: Optional[Dict[str, TenantConfig]] = None,
                 default_tenant: str = "default",
                 slo_budget_ms: Optional[float] = None,
                 autostart: bool = True,
                 clock: Callable[[], float] = time.monotonic):
        if max_batch_delay_ms <= 0:
            raise ValueError(
                f"max_batch_delay_ms must be > 0, got {max_batch_delay_ms}")
        if max_batch_columns is not None and max_batch_columns < 1:
            raise ValueError(
                f"max_batch_columns must be >= 1, got {max_batch_columns}")
        self.service = service
        self.max_batch_delay_ms = float(max_batch_delay_ms)
        self.max_batch_columns = max_batch_columns
        self.default_tenant = default_tenant
        # SLO budget: queueing is bounded by max_batch_delay_ms, so the
        # end-to-end target defaults to a small multiple of it (queue wait
        # + batched solve + readback); override for explicit latency SLOs.
        self.slo_budget_ms = (float(slo_budget_ms) if slo_budget_ms is not None
                              else 4.0 * self.max_batch_delay_ms)
        self._clock = clock
        # Canonical shared-state inventory, machine-checked by
        # repro.analysis.lock_lint: every field below may only be touched
        # inside `with self._cond` or from a *_locked method (the
        # Condition wraps an RLock, so nested acquisition is fine).
        # lock: self._cond
        #   _queue _pending_columns _lanes _closed _drain_on_close
        #   _thread _cycles _triggers _slo_violations _expired
        self._cond = threading.Condition()
        self._queue: List[_Entry] = []
        self._pending_columns = 0
        self._lanes: Dict[str, _Lane] = {}
        for name, cfg in (tenants or {}).items():
            if not isinstance(cfg, TenantConfig):
                raise TypeError(
                    f"tenants[{name!r}] wants a TenantConfig, got "
                    f"{type(cfg).__name__}")
            self._lanes[name] = _Lane(config=cfg)
        self._closed = False
        self._drain_on_close = True
        self._thread: Optional[threading.Thread] = None
        self._cycles = 0
        self._triggers = {"deadline": 0, "size": 0, "drain": 0}
        self._slo_violations = 0
        self._expired = 0
        if autostart:
            self.start()

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "SolverDaemon":
        """Start the flusher thread (idempotent)."""
        with self._cond:
            if self._closed:
                raise RuntimeError("daemon is closed")
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._run, name="solver-daemon-flusher",
                    daemon=True)
                self._thread.start()
        return self

    @property
    def running(self) -> bool:
        with self._cond:
            thread = self._thread
        return thread is not None and thread.is_alive()

    def close(self, drain: bool = True,
              timeout: Optional[float] = None) -> None:
        """Stop the daemon deterministically.  ``drain=True`` runs one
        final cycle over everything queued (every ticket resolves or
        carries its group's failure); ``drain=False`` fails queued tickets
        with :class:`DaemonShutdownError`.  Idempotent."""
        with self._cond:
            if self._closed:
                thread = self._thread
            else:
                self._closed = True
                self._drain_on_close = drain
                thread = self._thread
                self._cond.notify_all()
        if thread is not None:
            thread.join(timeout)
            if thread.is_alive():
                raise TimeoutError(
                    f"daemon flusher did not stop within {timeout}s")
        else:
            # never started (autostart=False): settle the queue inline
            self._shutdown_queue()

    def __enter__(self) -> "SolverDaemon":
        return self

    def __exit__(self, *exc) -> None:
        self.close(drain=exc[0] is None)

    # -- request plane -------------------------------------------------------

    def _lane_locked(self, tenant: str) -> _Lane:
        lane = self._lanes.get(tenant)
        if lane is None:
            lane = self._lanes[tenant] = _Lane(config=TenantConfig())
        return lane

    def submit(self, request: SolveRequest,
               tenant: Optional[str] = None) -> SolveTicket:
        """Queue a request under ``tenant``'s lane; returns a ticket whose
        ``result(timeout=...)`` blocks until the background flusher
        resolves it — no caller ever flushes.

        Raises :class:`AdmissionError` (with ``.tenant`` set) when the
        tenant's pending-column budget would be exceeded: backpressure is
        per tenant, so one tenant hitting its budget never blocks another.
        """
        tenant = tenant if tenant is not None else self.default_tenant
        # Validate + register + allocate the ticket id outside the daemon
        # lock (registration may hash a new graph's edge arrays).
        ticket, handle = self.service._new_ticket(request)
        cols = request.b.shape[1] if getattr(request.b, "ndim", 1) == 2 else 1
        metrics = self.service.metrics
        with self._cond:
            if self._closed:
                raise RuntimeError(
                    "daemon is closed — submit to a live daemon or use the "
                    "synchronous service.submit()/flush() path")
            lane = self._lane_locked(tenant)
            budget = lane.config.max_pending_columns
            if budget is not None and lane.pending_columns + cols > budget:
                lane.rejected += 1
                metrics.inc("serve.rejected")
                metrics.inc(f"serve.tenant.{tenant}.rejected")
                raise AdmissionError(lane.pending_columns, cols, budget,
                                     tenant=tenant)
            ticket._event = threading.Event()
            now = self._clock()
            self._queue.append(_Entry(
                ticket=ticket, handle=handle, request=request, tenant=tenant,
                cols=cols, t_submit=now,
                deadline=now + self.max_batch_delay_ms / 1e3,
                expiry=(now + request.deadline_ms / 1e3
                        if request.deadline_ms is not None else None)))
            lane.pending_columns += cols
            lane.submitted += 1
            self._pending_columns += cols
            metrics.set_gauge("serve.queue_depth", len(self._queue))
            self._cond.notify_all()
        metrics.inc("serve.submitted")
        return ticket

    # -- flusher loop --------------------------------------------------------

    def _size_ready_locked(self) -> bool:
        return (self.max_batch_columns is not None
                and self._pending_columns >= self.max_batch_columns)

    def _expire_locked(self, now: float) -> None:
        """Queue-side TTL sweep: fail every still-queued entry whose
        ``deadline_ms`` expiry has passed with a typed
        :class:`DeadlineExceededError`, without solving it.  Runs under the
        condition lock; ``_fail`` only sets the ticket's outcome + event,
        so waking waiters from here is safe."""
        expired = [e for e in self._queue
                   if e.expiry is not None and e.expiry <= now]
        if not expired:
            return
        dead = set(id(e) for e in expired)
        self._queue = [e for e in self._queue if id(e) not in dead]
        metrics = self.service.metrics
        for e in expired:
            self._charge_locked(e)
            lane = self._lanes[e.tenant]
            lane.expired += 1
            self._expired += 1
            metrics.inc("serve.expired")
            metrics.inc(f"serve.tenant.{e.tenant}.expired")
            e.ticket._fail(DeadlineExceededError(
                int(e.ticket), e.request.deadline_ms,
                (now - e.t_submit) * 1e3, tenant=e.tenant))
        metrics.set_gauge("serve.queue_depth", len(self._queue))

    def _run(self) -> None:
        while True:
            with self._cond:
                trigger = None
                while trigger is None:
                    if self._closed:
                        trigger = "drain"
                        break
                    if not self._queue:
                        self._cond.wait()
                        continue
                    now = self._clock()
                    self._expire_locked(now)
                    if not self._queue:
                        continue
                    if self._size_ready_locked():
                        trigger = "size"
                        break
                    # Sleep until the batch deadline OR the earliest TTL
                    # expiry, whichever is sooner — an expiry must not wait
                    # out a longer batch window to be honored.
                    wake = self._queue[0].deadline
                    for e in self._queue:
                        if e.expiry is not None and e.expiry < wake:
                            wake = e.expiry
                    wait = wake - now
                    if wait <= 0:
                        # every expiry <= now was just swept, so an overdue
                        # wake-up time can only be the batch deadline
                        trigger = "deadline"
                        break
                    self._cond.wait(wait)
                if trigger == "drain":
                    break   # settle the remaining queue below, then exit
                batch = self._select_batch_locked()
            if batch:
                self._run_cycle(batch, trigger)
        self._shutdown_queue()

    def _shutdown_queue(self) -> None:
        """Settle whatever is still queued at close time: one final drain
        cycle, or a deterministic failure of every ticket."""
        with self._cond:
            # honor TTLs one last time: entries already past deadline get
            # the precise DeadlineExceededError, not a generic shutdown one
            self._expire_locked(self._clock())
            batch, self._queue = self._queue, []
            for e in batch:
                self._charge_locked(e)
            self.service.metrics.set_gauge("serve.queue_depth", 0)
            drain = self._drain_on_close
        if not batch:
            return
        if drain:
            self._run_cycle(batch, "drain")
        else:
            err = DaemonShutdownError(
                f"daemon closed with drain=False — {len(batch)} queued "
                f"ticket(s) failed without solving")
            with self._cond:
                for e in batch:
                    self._lanes[e.tenant].failed += 1
            for e in batch:
                e.ticket._fail(err)
            self.service.metrics.inc("serve.shutdown_failed", len(batch))

    def _charge_locked(self, e: _Entry) -> None:
        """Remove ``e``'s columns from the queue accounting (called when an
        entry leaves the queue for a cycle)."""
        self._pending_columns -= e.cols
        self._lanes[e.tenant].pending_columns -= e.cols

    def _select_batch_locked(self) -> List[_Entry]:
        """Pick this cycle's entries from the queue, fairly across tenants.

        Unbounded (``max_batch_columns=None``): take everything — the
        deadline already fired, and the group scheduler splits the batch.

        Bounded: two passes.  (1) *Starvation guard* — every tenant with
        queued work contributes its oldest entry, tenants visited
        oldest-deadline-first, regardless of the column budget: no tenant
        can be excluded from a flush window by another's flood.  (2)
        *Weighted fill* — remaining budget fills by deficit round-robin:
        each cycle a lane earns credit proportional to its weight, paying
        ``cols / weight`` per selected entry (heavier lanes drain more
        columns per cycle); credit persists across cycles so short-changed
        lanes catch up.  Ties break toward the oldest deadline.
        """
        if self.max_batch_columns is None:
            batch, self._queue = self._queue, []
            for e in batch:
                self._charge_locked(e)
            self.service.metrics.set_gauge("serve.queue_depth", 0)
            return batch
        by_tenant: Dict[str, List[_Entry]] = {}
        for e in self._queue:            # queue is submit-ordered: each
            by_tenant.setdefault(e.tenant, []).append(e)   # lane list FIFO
        selected: List[_Entry] = []
        cols = 0
        for t in sorted(by_tenant, key=lambda t: by_tenant[t][0].deadline):
            e = by_tenant[t].pop(0)
            selected.append(e)
            cols += e.cols
            self._lanes[t].credit += self._lanes[t].config.weight
        while cols < self.max_batch_columns:
            live = [t for t, es in by_tenant.items() if es]
            if not live:
                break
            t = max(live, key=lambda t: (self._lanes[t].credit,
                                         -by_tenant[t][0].deadline))
            e = by_tenant[t].pop(0)
            selected.append(e)
            cols += e.cols
            self._lanes[t].credit -= e.cols / self._lanes[t].config.weight
        chosen = set(id(e) for e in selected)
        self._queue = [e for e in self._queue if id(e) not in chosen]
        for e in selected:
            self._charge_locked(e)
        self.service.metrics.set_gauge("serve.queue_depth", len(self._queue))
        return selected

    def _run_cycle(self, batch: List[_Entry], trigger: str) -> None:
        """Solve one selected batch through the service's group scheduler
        and account latencies/SLO per entry.  Runs on the flusher thread;
        per-group failure isolation comes from ``_solve_batch`` itself
        (a failed group fails only its own tickets)."""
        metrics = self.service.metrics
        tracer = get_tracer()
        t_start = self._clock()
        with self._cond:
            cycle = self._cycles
            self._cycles += 1
            self._triggers[trigger] += 1
        for e in batch:
            metrics.observe("serve.queue_wait_ms",
                            (t_start - e.t_submit) * 1e3)
        with tracer.span("serve.flush_cycle", cycle=cycle, trigger=trigger,
                         requests=len(batch),
                         columns=sum(e.cols for e in batch),
                         tenants=len({e.tenant for e in batch})) as sp:
            self.service._solve_batch(
                [(e.ticket, e.handle, e.request) for e in batch])
            sp.set(queue_wait_ms=round((t_start - batch[0].t_submit) * 1e3, 3))
        t_end = self._clock()
        metrics.inc("serve.cycles")
        # Per-entry end-to-end latency; SLO violations counted per
        # (graph, config) group — the unit the scheduler dispatches — when
        # the group's slowest member blows the delay-derived budget.
        group_worst: Dict[tuple, float] = {}
        with self._cond:
            for e in batch:
                e2e_ms = (t_end - e.t_submit) * 1e3
                metrics.observe("serve.e2e_ms", e2e_ms)
                metrics.observe(f"serve.tenant.{e.tenant}.e2e_ms", e2e_ms)
                lane = self._lanes[e.tenant]
                if e.ticket.error() is not None:
                    lane.failed += 1
                else:
                    lane.solved += 1
                config = e.request.pipeline if e.request.pipeline is not None \
                    else self.service.pipeline
                gid = (e.handle.fingerprint, config.fingerprint())
                group_worst[gid] = max(group_worst.get(gid, 0.0), e2e_ms)
            for gid, worst in group_worst.items():
                if worst > self.slo_budget_ms:
                    self._slo_violations += 1
                    metrics.inc("serve.slo_violations")
        return None

    # -- introspection -------------------------------------------------------

    def stats(self) -> dict:
        """Daemon + per-tenant snapshot (deep copy, mutate freely).  The
        service's own ``stats()`` — cache, store, scheduler, metrics with
        the ``serve.*`` namespace — stays on ``daemon.service.stats()``."""
        with self._cond:
            tenants = {
                name: {
                    "pending_columns": lane.pending_columns,
                    "budget": lane.config.max_pending_columns,
                    "weight": lane.config.weight,
                    "submitted": lane.submitted,
                    "rejected": lane.rejected,
                    "solved": lane.solved,
                    "failed": lane.failed,
                    "expired": lane.expired,
                } for name, lane in self._lanes.items()}
            return copy.deepcopy({
                "daemon": {
                    "running": self.running,
                    "closed": self._closed,
                    "cycles": self._cycles,
                    "triggers": dict(self._triggers),
                    "queue_depth": len(self._queue),
                    "pending_columns": self._pending_columns,
                    "max_batch_delay_ms": self.max_batch_delay_ms,
                    "max_batch_columns": self.max_batch_columns,
                    "slo_budget_ms": self.slo_budget_ms,
                    "slo_violations": self._slo_violations,
                    "expired": self._expired,
                },
                "tenants": tenants,
            })
