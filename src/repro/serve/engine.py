"""Batched serving engine: prefill + jit'd decode with KV caches.

A deliberately small continuous-batching core: requests join a fixed-size
batch slot, prefill fills their caches, and a single jit'd ``decode_step``
advances every active slot one token per tick.  greedy/temperature
sampling; EOS or length frees the slot.

This is the serving counterpart exercised by the ``decode_*`` dry-run
shapes (one new token against a seq_len-deep cache).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as model_mod
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # [S] int32
    max_new: int = 16
    out: Optional[np.ndarray] = None


class Engine:
    def __init__(self, cfg: ModelConfig, params, batch: int, cache_len: int,
                 eos: int = -1):
        self.cfg, self.params = cfg, params
        self.B, self.C, self.eos = batch, cache_len, eos
        self._decode = jax.jit(
            lambda p, c, t, pos: model_mod.decode_step(p, cfg, c, t, pos))

    def generate(self, requests: List[Request], greedy: bool = True,
                 seed: int = 0) -> List[np.ndarray]:
        """Serve a batch of requests (padded to engine batch)."""
        cfg = self.cfg
        assert len(requests) <= self.B
        S = max(len(r.prompt) for r in requests)
        toks = np.zeros((self.B, S), np.int32)
        for i, r in enumerate(requests):
            toks[i, S - len(r.prompt):] = r.prompt  # left-pad
        logits, caches = model_mod.prefill(
            self.params, cfg, jnp.asarray(toks), self.C)
        max_new = max(r.max_new for r in requests)
        outs = [[] for _ in requests]
        rng = np.random.default_rng(seed)
        cur = np.asarray(jnp.argmax(logits, -1)).astype(np.int32)
        for i in range(len(requests)):
            outs[i].append(int(cur[i]))
        pos = S
        for t in range(max_new - 1):
            tok = jnp.asarray(cur[:, None])
            logits, caches = self._decode(self.params, caches, tok,
                                          jnp.int32(pos))
            if greedy:
                cur = np.asarray(jnp.argmax(logits, -1)).astype(np.int32)
            else:
                p = np.asarray(jax.nn.softmax(logits, -1))
                cur = np.array([rng.choice(p.shape[1], p=p[i])
                                for i in range(p.shape[0])], np.int32)
            pos += 1
            for i, r in enumerate(requests):
                if len(outs[i]) < r.max_new:
                    outs[i].append(int(cur[i]))
        return [np.asarray(o, np.int32) for o in outs]
