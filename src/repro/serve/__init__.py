"""repro.serve: serving runtimes.

  * :mod:`repro.serve.engine`        — LM continuous-batching engine
    (prefill + jit'd decode over KV-cache slots).
  * :mod:`repro.serve.solver_daemon` — async Laplacian-solve runtime: a
    background flusher over :class:`~repro.solver.service.SolverService`
    with deadline/size batching, multi-tenant fairness, and event-resolved
    tickets (no caller-side ``flush()``).
  * :mod:`repro.serve.replay`        — deterministic open-loop traffic
    replay (seeded arrival schedules, p50/p99 latency reports) for
    benchmarking the daemon against the sync-flush baseline.

The LM engine is imported lazily by its users; importing this package pulls
only the solver-serving surface.
"""
from repro.serve.replay import (ReplayEvent, ReplayReport,  # noqa: F401
                                make_rhs, make_schedule, replay_daemon,
                                replay_sync)
from repro.serve.solver_daemon import (DaemonShutdownError,  # noqa: F401
                                       SolverDaemon, TenantConfig)

__all__ = [
    "SolverDaemon", "TenantConfig", "DaemonShutdownError",
    "ReplayEvent", "ReplayReport", "make_schedule", "make_rhs",
    "replay_daemon", "replay_sync",
]
