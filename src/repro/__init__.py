"""repro: pdGRASS graph spectral sparsification + multi-pod JAX framework."""
__version__ = "1.0.0"
