"""AST lock-discipline checker for the threaded serving plane.

The service/daemon threading relies on a convention: shared mutable state
hangs off ``self`` and is only touched while holding the instance lock,
either inside a ``with self._lock:`` block or from a method whose name
ends in ``_locked`` (which callers must invoke under the lock).  This
checker turns the convention into a machine-checked contract.

The contract is declared in the source itself as a ``# lock:`` inventory
block — canonical documentation and checker input in one place::

    # lock: self._lock
    #   _pending _next_ticket _sched
    #   _timing _warmed

Every field named in the inventory of the enclosing class may only be
read/written

* inside a ``with self.<lock>:`` statement,
* inside a method whose name ends with ``_locked``,
* or inside ``__init__`` (construction precedes sharing).

and every ``self.*_locked(...)`` call must itself happen under one of the
first two.  Two rules:

``lock-unguarded-field``
    inventory field accessed outside the lock.

``lock-unlocked-call``
    ``*_locked`` method called outside the lock.

Purely AST-based: no imports of the checked modules, no runtime state.
The lock attribute can be any ``self.<name>`` (the daemon guards with a
``threading.Condition`` named ``_cond`` — a Condition wraps an RLock, so
``with self._cond`` is the guard there).  Re-entrant acquisition is
assumed (both planes use RLock semantics), so nested ``with`` blocks and
``_locked`` calls from ``_locked`` methods are fine.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.findings import Finding, apply_pragmas, scan_pragmas

_BLOCK_HEAD_RE = re.compile(r"^\s*#\s*lock:\s*self\.(\w+)\s*$")
_BLOCK_FIELDS_RE = re.compile(r"^\s*#\s+((?:_\w+\s*)+)$")

_EXEMPT_METHODS = {"__init__", "__del__"}


class _Inventory:
    """One ``# lock:`` block: the guarding attribute and its fields."""

    def __init__(self, lock_attr: str, line: int):
        self.lock_attr = lock_attr
        self.line = line
        self.fields: Set[str] = set()


def parse_inventories(source: str) -> List[_Inventory]:
    """Extract ``# lock: self.X`` blocks and their indented field lists."""
    out: List[_Inventory] = []
    current: Optional[_Inventory] = None
    for i, text in enumerate(source.splitlines(), start=1):
        m = _BLOCK_HEAD_RE.match(text)
        if m:
            current = _Inventory(m.group(1), i)
            out.append(current)
            continue
        if current is not None:
            m = _BLOCK_FIELDS_RE.match(text)
            if m:
                current.fields.update(m.group(1).split())
            else:
                current = None
    return [inv for inv in out if inv.fields]


def _enclosing_class(tree: ast.Module, line: int) -> Optional[ast.ClassDef]:
    best: Optional[ast.ClassDef] = None
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            end = getattr(node, "end_lineno", node.lineno)
            if node.lineno <= line <= end:
                if best is None or node.lineno > best.lineno:
                    best = node
    return best


def _with_holds_lock(node: ast.With, lock_attr: str) -> bool:
    for item in node.items:
        expr = item.context_expr
        # with self._lock: …  — also accept self._lock: acquire-style
        # wrappers like `with self._cond:` (Condition wraps an RLock)
        if isinstance(expr, ast.Attribute) and expr.attr == lock_attr \
                and isinstance(expr.value, ast.Name) \
                and expr.value.id == "self":
            return True
    return False


class _MethodChecker(ast.NodeVisitor):
    """Walk one method body tracking whether the lock is held."""

    def __init__(self, method: ast.AST, inv: _Inventory, path: str,
                 findings: List[Finding]):
        self.inv = inv
        self.path = path
        self.findings = findings
        name = getattr(method, "name", "")
        self.held = name.endswith("_locked") or name in _EXEMPT_METHODS
        self.method_name = name

    def visit_With(self, node: ast.With):
        if _with_holds_lock(node, self.inv.lock_attr):
            prev, self.held = self.held, True
            for child in node.body:
                self.visit(child)
            self.held = prev
        else:
            self.generic_visit(node)

    def visit_FunctionDef(self, node):
        # nested defs (e.g. callbacks handed elsewhere) run who-knows-when:
        # treat them as unlocked regardless of the definition site.
        # Lambdas deliberately have NO such override — the codebase uses
        # them as sort/max keys that execute synchronously under the lock.
        prev, self.held = self.held, False
        self.generic_visit(node)
        self.held = prev

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Attribute(self, node: ast.Attribute):
        if not self.held \
                and isinstance(node.value, ast.Name) \
                and node.value.id == "self" \
                and node.attr in self.inv.fields:
            self.findings.append(Finding(
                file=self.path, line=node.lineno, rule="lock-unguarded-field",
                message=f"self.{node.attr} accessed in {self.method_name}() "
                        f"outside 'with self.{self.inv.lock_attr}' — field "
                        f"is in the lock inventory (line {self.inv.line})"))
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        fn = node.func
        if not self.held \
                and isinstance(fn, ast.Attribute) \
                and fn.attr.endswith("_locked") \
                and isinstance(fn.value, ast.Name) and fn.value.id == "self":
            self.findings.append(Finding(
                file=self.path, line=node.lineno, rule="lock-unlocked-call",
                message=f"self.{fn.attr}() called from "
                        f"{self.method_name}() without holding "
                        f"self.{self.inv.lock_attr} — the _locked suffix "
                        f"is a promise the caller already owns the lock"))
        self.generic_visit(node)


def check_source(source: str, path: str) -> List[Finding]:
    """Check one module; no-op (zero findings) if it declares no
    ``# lock:`` inventory."""
    inventories = parse_inventories(source)
    if not inventories:
        return []
    allowed, findings = scan_pragmas(source, path)
    out: List[Finding] = list(findings)
    tree = ast.parse(source, filename=path)

    for inv in inventories:
        cls = _enclosing_class(tree, inv.line)
        methods: List[ast.AST]
        if cls is not None:
            methods = [n for n in cls.body
                       if isinstance(n, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))]
        else:  # file-level inventory: every method in the module
            methods = [n for n in ast.walk(tree)
                       if isinstance(n, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))]
        for method in methods:
            checker = _MethodChecker(method, inv, path, out)
            for child in method.body:
                checker.visit(child)
    return apply_pragmas(out, allowed)


def check_tree(root: str) -> List[Finding]:
    """Check every ``.py`` under ``root`` that declares an inventory."""
    out: List[Finding] = []
    for dirpath, _, files in sorted(os.walk(root)):
        for name in sorted(files):
            if not name.endswith(".py"):
                continue
            path = os.path.join(dirpath, name)
            with open(path) as f:
                src = f.read()
            if "# lock:" not in src:
                continue
            rel = os.path.relpath(path, os.path.dirname(root))
            out.extend(check_source(src, rel))
    return out
