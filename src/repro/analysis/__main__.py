"""CLI: ``python -m repro.analysis --check all|jaxpr|trace|locks|vmem``.

Prints every finding as ``file:line: [rule-id] message``, a per-check
summary, and exits non-zero when anything fired — the CI
``static-analysis`` job is exactly this invocation.  ``--json PATH``
additionally writes the bench-v1-style findings artifact.
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import List

from repro.analysis import CHECKS, run_checks
from repro.analysis.findings import write_findings_json


def main(argv: List[str] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static invariant checks for the repro tree")
    parser.add_argument(
        "--check", action="append", default=None,
        choices=("all",) + CHECKS, metavar="|".join(("all",) + CHECKS),
        help="checker to run (repeatable; default: all)")
    parser.add_argument(
        "--json", default=None, metavar="PATH",
        help="write the bench-v1-style findings artifact here")
    parser.add_argument(
        "--root", default=None, metavar="DIR",
        help="package tree for the AST checkers "
             "(default: the imported repro package)")
    args = parser.parse_args(argv)
    checks = args.check or ["all"]

    t0 = time.time()
    per_check = run_checks(checks, root=args.root)
    elapsed = time.time() - t0

    findings = [f for fs in per_check.values() for f in fs]
    for f in sorted(findings, key=lambda f: (f.file, f.line, f.rule)):
        print(f.format())

    ran = sorted(per_check)
    counts = ", ".join(f"{c}: {len(per_check[c])}" for c in ran)
    status = "FAIL" if findings else "OK"
    print(f"[analysis] {status} — {len(findings)} finding(s) "
          f"({counts}) in {elapsed:.1f}s")

    if args.json:
        write_findings_json(args.json, findings, ran,
                            extra={"elapsed_s": elapsed})
        print(f"[analysis] wrote {args.json}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
