"""AST trace-safety lint: host syncs, numpy-on-traced, Python branches.

Static companion of the jaxpr auditor: where the auditor inspects what a
registered entry point *traced to*, this lint inspects the *source* of
every module under ``src/repro`` — it catches violations in paths the
registry does not trace (new entry points, rarely-taken branches) and
reports them at the offending source line before anything runs.

Three rules:

``trace-host-sync``
    ``float(e)`` / ``int(e)`` / ``bool(e)`` / ``e.item()`` where ``e``
    contains a ``jnp.*`` / ``jax.lax.*`` / ``jax.scipy.*`` / ``jax.ops.*``
    /``jax.nn.*`` call (directly or through a local variable assigned from
    one).  Inside a jit trace this is a ``ConcretizationError`` waiting to
    happen; *outside* jit it is a silent blocking device round-trip — the
    class of bug the solver's setup path shipped (``float(jnp.linalg.
    norm(w))`` per hierarchy level).  Applied file-wide: build-time closure
    code is exactly where these hide.  The designated sync points
    (``jax.device_get`` / ``jax.block_until_ready`` and host values built
    from them) are not flagged — routing a scalarization through
    ``device_get`` is the documented way to *mark* it deliberate.

``trace-numpy-on-traced``
    ``np.*`` call inside a jit-traced scope whose arguments involve traced
    values: numpy forces a transfer and constant-folds under trace,
    silently baking one batch's values into the compiled executable.

``trace-python-branch``
    ``if`` (statement or expression) inside a jit-traced scope whose test
    involves a traced value or a ``jnp.*`` call.  Exemptions: ``is None``
    checks, ``isinstance``, and anything reached only through
    ``.shape`` / ``.ndim`` / ``.dtype`` / ``len()`` — shape math is static
    under trace and is how the kernels legitimately branch on padding.

Traced scopes are discovered statically, best-effort by construction:
functions decorated with ``jax.jit`` (including ``partial(jax.jit, ...)``,
honoring ``static_argnums``/``static_argnames``), functions passed by name
to ``jax.jit`` / ``shard_map`` / ``shard_map_compat`` / ``lax.while_loop``
/ ``lax.fori_loop`` / ``lax.scan`` / ``lax.cond``, plus module-local
functions those call (one call-graph closure, by simple name).  Nested
defs inside a traced scope are scanned with their *own* parameters treated
as untraced (the V-cycle's ``cycle(l, r)`` recursion takes static level
indices) — traced-ness flows through closure variables and ``jnp`` calls
instead.  Pallas kernel bodies are excluded: they receive ``Ref``s and
cannot host-sync.

Known limitation: the dataflow is flow-insensitive (facts are only ever
added), so REASSIGNING a device-derived name from a host boundary
(``x = jax.device_get(x)``) does not clear its derived status — bind the
host value to a NEW name instead (``host_x = jax.device_get(x)``), which
is also clearer to human readers about which side of the boundary a value
lives on.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.findings import (Finding, apply_pragmas, scan_pragmas)

# attribute roots whose calls produce traced/device values
_JAX_CALL_ROOTS = {"jnp"}
_JAX_CALL_PREFIXES = (("jax", "lax"), ("jax", "scipy"), ("jax", "ops"),
                      ("jax", "nn"), ("jax", "numpy"))
# designated sync points: calls through these are deliberate host landings
_SYNC_OK = {("jax", "device_get"), ("jax", "block_until_ready")}
# host boundaries: the *result* of these calls is a host value — syncs on
# values that already crossed through one are free, so dataflow pruning
# stops here (np.asarray(jnp_x) is the sync; int() of it afterwards isn't)
_HOST_BOUNDARY = {("np", "asarray"), ("np", "array"),
                  ("numpy", "asarray"), ("numpy", "array"),
                  ("jax", "device_get"), ("jax", "block_until_ready")}
_NP_ROOTS = {"np", "numpy"}
_SHAPE_ATTRS = {"shape", "ndim", "dtype", "size"}

_TRACING_CALLEES = {
    # (dotted suffix) -> positions of function-valued args that get traced
    "jit": (0,),
    "while_loop": (0, 1),
    "fori_loop": (2,),
    "scan": (0,),
    "cond": (1, 2),
    "shard_map": (0,),
    "shard_map_compat": (0,),
    "_shard_map": (0,),
}


def _attr_path(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """``a.b.c`` -> ("a", "b", "c"); None for anything not a pure path."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def _is_jax_call(node: ast.Call) -> bool:
    path = _attr_path(node.func)
    if path is None:
        return False
    if path[:2] in _SYNC_OK:
        return False
    if path[0] in _JAX_CALL_ROOTS:
        return True
    return any(path[:len(p)] == p for p in _JAX_CALL_PREFIXES)


def _is_host_boundary(node: ast.Call) -> bool:
    path = _attr_path(node.func)
    return bool(path) and (path[:2] in _HOST_BOUNDARY
                           or path[-2:] in _HOST_BOUNDARY)


def _walk_pruned(node: ast.AST, prune_host: bool):
    """ast.walk, optionally skipping host-boundary call subtrees whole
    (their results live on the host regardless of what fed them)."""
    stack = [node]
    while stack:
        sub = stack.pop()
        if prune_host and isinstance(sub, ast.Call) \
                and _is_host_boundary(sub):
            continue
        yield sub
        stack.extend(ast.iter_child_nodes(sub))


def _contains_jax_call(node: ast.AST, prune_host: bool = False) -> bool:
    return any(isinstance(sub, ast.Call) and _is_jax_call(sub)
               for sub in _walk_pruned(node, prune_host))


class _NameUse(ast.NodeVisitor):
    """Names referenced in an expression, split into shape-shielded uses
    (only ever seen under ``.shape``/``.ndim``/``.dtype``/``len()``) and
    value uses."""

    def __init__(self, prune_host: bool = False):
        self.value_names: Set[str] = set()
        self.prune_host = prune_host

    def visit_Attribute(self, node: ast.Attribute):
        if node.attr in _SHAPE_ATTRS:
            return  # anything under .shape is static metadata
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        if isinstance(node.func, ast.Name) and node.func.id == "len":
            return
        if isinstance(node.func, ast.Name) and node.func.id == "isinstance":
            return
        if self.prune_host and _is_host_boundary(node):
            return
        # the callee name itself is not a *value* use (msolve(r): msolve
        # being a traced-built closure does not make the test traced)
        for arg in node.args:
            self.visit(arg)
        for kw in node.keywords:
            self.visit(kw.value)

    def visit_Name(self, node: ast.Name):
        self.value_names.add(node.id)


def _value_names(node: ast.AST, prune_host: bool = False) -> Set[str]:
    v = _NameUse(prune_host)
    v.visit(node)
    return v.value_names


def _targets(t: ast.AST) -> Iterable[str]:
    if isinstance(t, ast.Name):
        yield t.id
    elif isinstance(t, (ast.Tuple, ast.List)):
        for e in t.elts:
            yield from _targets(e)
    elif isinstance(t, ast.Starred):
        yield from _targets(t.value)


def _decorator_jit_info(fn: ast.AST) -> Optional[Tuple[Set[int], Set[str]]]:
    """(static_argnums, static_argnames) if the def is jit-decorated."""
    for dec in getattr(fn, "decorator_list", ()):
        target = dec
        static_nums: Set[int] = set()
        static_names: Set[str] = set()
        if isinstance(dec, ast.Call):
            path = _attr_path(dec.func)
            if path and path[-1] == "partial" and dec.args:
                target = dec.args[0]
                for kw in dec.keywords:
                    if kw.arg == "static_argnums":
                        static_nums = _const_int_set(kw.value)
                    elif kw.arg == "static_argnames":
                        static_names = _const_str_set(kw.value)
            else:
                target = dec.func  # jax.jit(static_argnames=...) form
                for kw in dec.keywords:
                    if kw.arg == "static_argnums":
                        static_nums = _const_int_set(kw.value)
                    elif kw.arg == "static_argnames":
                        static_names = _const_str_set(kw.value)
        path = _attr_path(target)
        if path and path[-1] == "jit":
            return static_nums, static_names
    return None


def _const_int_set(node: ast.AST) -> Set[int]:
    out: Set[int] = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, int):
            out.add(sub.value)
    return out


def _const_str_set(node: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            out.add(sub.value)
    return out


def _fn_params(fn: ast.AST) -> List[str]:
    a = fn.args
    names = [p.arg for p in getattr(a, "posonlyargs", [])]
    names += [p.arg for p in a.args]
    if a.vararg:
        names.append(a.vararg.arg)
    names += [p.arg for p in a.kwonlyargs]
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


class _Scope:
    """One function to lint: its def node and which params are traced."""

    def __init__(self, node, traced_params: Set[str], why: str):
        self.node = node
        self.traced_params = traced_params
        self.why = why


def _collect_scopes(tree: ast.Module) -> List[_Scope]:
    """Discover traced scopes: jit-decorated defs, defs passed to tracing
    callees, and the module-local call closure over both."""
    defs: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, []).append(node)

    scopes: Dict[ast.AST, _Scope] = {}

    def add(node, traced: Set[str], why: str):
        if node not in scopes:
            scopes[node] = _Scope(node, traced, why)

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            info = _decorator_jit_info(node)
            if info is not None:
                nums, names = info
                params = _fn_params(node)
                traced = {p for i, p in enumerate(params)
                          if i not in nums and p not in names}
                add(node, traced, "jit-decorated")
        if isinstance(node, ast.Call):
            path = _attr_path(node.func)
            if path is None:
                continue
            positions = _TRACING_CALLEES.get(path[-1])
            if positions is None:
                continue
            for pos in positions:
                if pos >= len(node.args):
                    continue
                arg = node.args[pos]
                if isinstance(arg, ast.Lambda):
                    add(arg, set(_fn_params(arg)), f"passed to {path[-1]}")
                elif isinstance(arg, ast.Name):
                    for d in defs.get(arg.id, []):
                        add(d, set(_fn_params(d)), f"passed to {path[-1]}")

    # one closure round: module-local functions called from traced scopes
    # are traced scopes themselves (their params conservatively untraced —
    # we cannot see the call's argument binding statically)
    frontier = list(scopes.values())
    while frontier:
        nxt: List[_Scope] = []
        for sc in frontier:
            for sub in ast.walk(sc.node):
                if isinstance(sub, ast.Call) and isinstance(sub.func,
                                                            ast.Name):
                    for d in defs.get(sub.func.id, []):
                        if d not in scopes:
                            scopes[d] = _Scope(d, set(),
                                               f"called from {sc.why}")
                            nxt.append(scopes[d])
        frontier = nxt
    return list(scopes.values())


def _traced_names_flow(fn, traced_params: Set[str]) -> Set[str]:
    """Forward-propagate traced-ness through simple assignments: a target
    is traced when its RHS uses a traced name by value (not through
    ``.shape``) or contains a ``jnp.*``-family call."""
    traced = set(traced_params)
    for _ in range(3):        # small fixpoint: assignment chains are short
        before = len(traced)
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                rhs, tgts = node.value, node.targets
            elif isinstance(node, ast.AugAssign):
                rhs, tgts = node.value, [node.target]
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                rhs, tgts = node.value, [node.target]
            else:
                continue
            if (_value_names(rhs) & traced) or _contains_jax_call(rhs):
                for name in _targets_of(tgts):
                    traced.add(name)
        if len(traced) == before:
            break
    return traced


def _targets_of(tgts) -> Iterable[str]:
    for t in tgts:
        yield from _targets(t)


def _jnp_derived_names(fn) -> Set[str]:
    """Locals assigned (transitively) from ``jnp.*``-family calls — the
    host-sync rule's dataflow, applicable outside traced scopes too."""
    derived: Set[str] = set()
    for _ in range(3):
        before = len(derived)
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                rhs, tgts = node.value, node.targets
            elif isinstance(node, ast.AugAssign):
                rhs, tgts = node.value, [node.target]
            else:
                continue
            if _contains_jax_call(rhs, prune_host=True) \
                    or (_value_names(rhs, prune_host=True) & derived):
                for name in _targets_of(tgts):
                    derived.add(name)
        if len(derived) == before:
            break
    return derived


def _own_nodes(fn) -> Iterable[ast.AST]:
    """Nodes of ``fn``'s body excluding nested function bodies (nested defs
    are linted as their own scopes with their own dataflow)."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _exempt_test(test: ast.AST) -> bool:
    """``x is None`` / ``x is not None`` / isinstance checks are static."""
    if isinstance(test, ast.Compare) and all(
            isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops):
        return True
    if isinstance(test, ast.Call):
        path = _attr_path(test.func)
        if path and path[-1] == "isinstance":
            return True
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _exempt_test(test.operand)
    return False


def check_source(source: str, path: str) -> List[Finding]:
    """Lint one module's source; returns pragma-filtered findings."""
    allowed, findings = scan_pragmas(source, path)
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(file=path, line=e.lineno or 1, rule="trace-host-sync",
                        message=f"unparseable module: {e.msg}")]

    out: List[Finding] = list(findings)

    # ---- rule: trace-host-sync (file-wide) ------------------------------
    all_fns = [n for n in ast.walk(tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    for fn in all_fns:
        derived = _jnp_derived_names(fn)

        def syncy(expr) -> bool:
            return (_contains_jax_call(expr, prune_host=True)
                    or bool(_value_names(expr, prune_host=True) & derived))

        for node in _own_nodes(fn):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Name) and \
                    node.func.id in ("float", "int", "bool") and node.args:
                if syncy(node.args[0]):
                    out.append(Finding(
                        file=path, line=node.lineno, rule="trace-host-sync",
                        message=f"{node.func.id}() scalarizes a jax value "
                                f"in {fn.name}() — a blocking device "
                                f"round-trip; keep it on device or route "
                                f"through jax.device_get at a designated "
                                f"sync point"))
            elif isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "item" and not node.args:
                if syncy(node.func.value):
                    out.append(Finding(
                        file=path, line=node.lineno, rule="trace-host-sync",
                        message=f".item() scalarizes a jax value in "
                                f"{fn.name}() — a blocking device "
                                f"round-trip"))

    # ---- traced-scope rules --------------------------------------------
    for sc in _collect_scopes(tree):
        fn = sc.node
        if isinstance(fn, ast.Lambda):
            traced = set(sc.traced_params)
            nodes = list(ast.walk(fn.body))
            tests: List[ast.AST] = [n for n in nodes
                                    if isinstance(n, ast.IfExp)]
        else:
            traced = _traced_names_flow(fn, sc.traced_params)
            nodes = list(_own_nodes(fn))
            tests = [n for n in nodes if isinstance(n, (ast.If, ast.IfExp))]

        # numpy on traced values
        for node in nodes:
            if not isinstance(node, ast.Call):
                continue
            p = _attr_path(node.func)
            if not p or p[0] not in _NP_ROOTS:
                continue
            arg_names: Set[str] = set()
            for a in list(node.args) + [kw.value for kw in node.keywords]:
                arg_names |= _value_names(a)
            if arg_names & traced:
                out.append(Finding(
                    file=path, line=node.lineno,
                    rule="trace-numpy-on-traced",
                    message=f"np.{'.'.join(p[1:])}() applied to traced "
                            f"value(s) {sorted(arg_names & traced)} inside "
                            f"jit-traced scope "
                            f"{getattr(fn, 'name', '<lambda>')} ({sc.why}) "
                            f"— use jnp, or hoist to the host boundary"))

        # python branch on traced values
        for node in tests:
            test = node.test
            if _exempt_test(test):
                continue
            names = _value_names(test)
            if (names & traced) or _contains_jax_call(test):
                out.append(Finding(
                    file=path, line=node.lineno, rule="trace-python-branch",
                    message=f"Python branch on traced value(s) "
                            f"{sorted((names & traced)) or '(jnp expr)'} "
                            f"inside jit-traced scope "
                            f"{getattr(fn, 'name', '<lambda>')} ({sc.why}) "
                            f"— use jnp.where / lax.cond"))

    return apply_pragmas(out, allowed)


def check_tree(root: str, subdir: str = "") -> List[Finding]:
    """Lint every ``.py`` under ``root`` (a package dir, e.g. src/repro)."""
    out: List[Finding] = []
    base = os.path.join(root, subdir) if subdir else root
    for dirpath, _, files in sorted(os.walk(base)):
        for name in sorted(files):
            if not name.endswith(".py"):
                continue
            path = os.path.join(dirpath, name)
            with open(path) as f:
                src = f.read()
            rel = os.path.relpath(path, os.path.dirname(root))
            out.extend(check_source(src, rel))
    return out
