"""Pallas VMEM-footprint and sharded tile/halo layout checker.

The fused V-cycle kernels (:mod:`repro.kernels.vcycle_fused`) hold a
whole hierarchy level VMEM-resident — slabs, diagonal, and every ``[n,k]``
vector stream at once — because the Chebyshev recurrence is globally
data-dependent and cannot row-tile without cross-tile synchronization.
That is a *capacity contract*: the module docstring bounds it at ~16 MB
of VMEM per level.  Nothing enforced it until now; a hierarchy config
change (bigger ``coarse_n``, a denser sparsifier raising the ELL width)
could silently push a level past the budget and fail at Mosaic lowering
time on real hardware, far from the config diff that caused it.

``vmem-budget``
    for every bench-suite graph, build the hierarchy, take
    ``roofline.hierarchy_level_triples``, and model each level's fused
    smoother / restrict+residual *residency* (not HBM traffic — the
    roofline models count stream bytes; residency additionally holds the
    recurrence temporaries).  A level above the budget must route through
    the unfused (row-tiled) path.  The batched spmv is also modeled per
    grid step (tile slabs + the full resident ``x`` block).

``vmem-tile-halo``
    layout sanity of :func:`repro.solver.sharded.shard_ell_slabs` over
    the suite: padded row count divisible by the shard count, local rows
    * shards == padded rows, halo indices in range and consistent with
    the extended local gather width.

Footprint models (float32 data, int32 indices):

* fused smoother: ``n*L*8`` slab + ``n*4`` diag + ``(3 + guess)*n*k*4``
  vector streams (r, z_out, one recurrence temporary, plus the initial
  iterate on post-smooth sweeps).
* fused restrict+residual: ``n*L*8`` slab + ``n*4`` agg +
  ``3*n*k*4`` (r, z, residual temporary) + ``n_coarse*k*4`` out.
* batched spmv per grid step: ``tile_n*L*8`` + ``nx*k*4`` resident x +
  ``tile_n*k*4`` out tile.
"""
from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

from repro.analysis.findings import Finding

#: the documented bound from the vcycle_fused module docstring
VMEM_BUDGET_BYTES = 16 * 2 ** 20

#: RHS width used for the capacity model — the widest warmup bucket the
#: service prewarms by default, i.e. the worst case production traces.
DEFAULT_K = 16

_DTYPE_B = 4
_IDX_B = 4


def fused_smoother_vmem(n: int, L: int, k: int,
                        with_guess: bool = False) -> int:
    slab = n * L * (_IDX_B + _DTYPE_B)
    vecs = (3 + (1 if with_guess else 0)) * n * k * _DTYPE_B
    return slab + n * _DTYPE_B + vecs


def fused_restrict_residual_vmem(n: int, L: int, k: int,
                                 n_coarse: int) -> int:
    slab = n * L * (_IDX_B + _DTYPE_B)
    return slab + n * _IDX_B + 3 * n * k * _DTYPE_B \
        + n_coarse * k * _DTYPE_B


def spmv_batched_step_vmem(tile_n: int, L: int, nx: int, k: int) -> int:
    return tile_n * L * (_IDX_B + _DTYPE_B) + nx * k * _DTYPE_B \
        + tile_n * k * _DTYPE_B


def check_level_triples(triples: Sequence[Tuple[int, int, int]],
                        *, k: int = DEFAULT_K,
                        budget: int = VMEM_BUDGET_BYTES,
                        file: str = "src/repro/kernels/vcycle_fused.py",
                        line: int = 1,
                        graph: str = "<synthetic>") -> List[Finding]:
    """Model every level's fused-kernel residency against ``budget``.

    Exposed with injectable ``triples``/``budget`` so the planted-fixture
    tests can drive it without building a pathological real hierarchy.
    """
    out: List[Finding] = []
    for i, (n, L, nc) in enumerate(triples):
        worst = max(fused_smoother_vmem(n, L, k, with_guess=True),
                    fused_restrict_residual_vmem(n, L, k, nc))
        if worst > budget:
            out.append(Finding(
                file=file, line=line, rule="vmem-budget",
                message=f"fused-kernel VMEM footprint "
                        f"{worst / 2**20:.1f} MiB exceeds the "
                        f"{budget / 2**20:.0f} MiB budget at level {i} "
                        f"(n={n}, ell_width={L}, n_coarse={nc}, k={k}) "
                        f"of graph '{graph}' — route this level through "
                        f"the unfused row-tiled path"))
    return out


def _fused_def_lines():
    """(file, smoother line) of the fused kernel entry point, so budget
    findings land on real source."""
    try:
        import inspect
        from repro.kernels import vcycle_fused
        file = "src/repro/kernels/vcycle_fused.py"
        line = inspect.getsourcelines(vcycle_fused.make_fused_chebyshev)[1]
        return file, line
    except Exception:
        return "src/repro/kernels/vcycle_fused.py", 1


@functools.lru_cache(maxsize=1)
def _suite():
    """The capacity-check graph suite — the solver_bench 'quick'+'full'
    shapes plus the hub topology whose star levels stress ELL width."""
    from repro.core.graph import (barabasi_albert, grid2d, mesh2d,
                                  star_hub)
    return (
        ("mesh2d-16x16", mesh2d(16, 16, seed=0)),
        ("grid2d-20x20", grid2d(20, 20, seed=0)),
        ("ba-300", barabasi_albert(300, 3, seed=1)),
        ("star-200", star_hub(200, extra=64, seed=2)),
    )


def check_suite(*, k: int = DEFAULT_K,
                budget: int = VMEM_BUDGET_BYTES) -> List[Finding]:
    """Build the suite hierarchies and run both vmem rules."""
    from repro.launch.roofline import hierarchy_level_triples
    from repro.solver.device_pcg import ell_laplacian
    from repro.solver.hierarchy import build_hierarchy

    file, line = _fused_def_lines()
    out: List[Finding] = []
    for name, g in _suite():
        hier = build_hierarchy(g, coarse_n=32)
        triples = hierarchy_level_triples(hier)
        out.extend(check_level_triples(triples, k=k, budget=budget,
                                       file=file, line=line, graph=name))
        # the top-level batched spmv (solve matvec) residency
        idx, val = ell_laplacian(g)
        n, L = int(idx.shape[0]), int(idx.shape[1])
        step = spmv_batched_step_vmem(256, L, n, k)
        if step > budget:
            out.append(Finding(
                file="src/repro/kernels/vcycle_fused.py", line=1,
                rule="vmem-budget",
                message=f"spmv_ell_batched grid-step residency "
                        f"{step / 2**20:.1f} MiB exceeds the budget on "
                        f"graph '{name}' (n={n}, L={L}, k={k}) — the "
                        f"resident x block no longer fits; shrink k or "
                        f"tile x"))
        out.extend(_check_shard_layout(idx, val, name))
    return out


def _check_shard_layout(idx, val, graph: str) -> List[Finding]:
    """Tile divisibility + halo-extent sanity of the sharded slabs."""
    import numpy as np
    from repro.solver.sharded import shard_ell_slabs

    out: List[Finding] = []
    file = "src/repro/solver/sharded.py"
    n = int(np.asarray(idx).shape[0])
    for n_sh in (2, 4):
        if n < n_sh:
            continue
        slab, meta = shard_ell_slabs(idx, val, n_sh)
        halo = np.asarray(slab.halo).reshape(n_sh, int(meta.halo))
        problems = validate_shard_layout(
            n_pad=int(meta.n_pad), n_loc=int(meta.n_loc), n_sh=n_sh,
            halo=halo, idx=np.asarray(slab.idx))
        for msg in problems:
            out.append(Finding(
                file=file, line=1, rule="vmem-tile-halo",
                message=f"{msg} (graph '{graph}', n_sh={n_sh})"))
    return out


def validate_shard_layout(*, n_pad: int, n_loc: int, n_sh: int,
                          halo, idx) -> List[str]:
    """Pure layout predicate — also the fixture-test entry point.

    ``halo``: ``[n_sh, H]`` global row ids each shard gathers;
    ``idx``: ``[n_pad, L]`` local column coordinates into the
    ``n_loc + H`` extended local vector.
    """
    problems: List[str] = []
    if n_pad % n_sh != 0:
        problems.append(
            f"padded row count {n_pad} not divisible by shard count "
            f"{n_sh}")
    if n_loc * n_sh != n_pad:
        problems.append(
            f"local rows {n_loc} * shards {n_sh} != padded rows {n_pad}")
    H = int(halo.shape[1]) if getattr(halo, "ndim", 0) == 2 else 0
    if (halo < 0).any() or (halo >= max(n_pad, 1)).any():
        problems.append(
            f"halo ids outside [0, {n_pad}) — the all-gather would "
            f"index out of range")
    ext = n_loc + H
    if (idx < 0).any() or (idx >= ext).any():
        problems.append(
            f"local ELL coordinates outside the extended width "
            f"{ext} (= n_loc {n_loc} + halo {H}) — the local gather "
            f"would read past the staged halo")
    return problems
