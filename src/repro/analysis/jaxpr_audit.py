"""Jaxpr auditor: trace registered hot paths, walk the jaxpr, flag bans.

The trace lint (:mod:`repro.analysis.trace_lint`) sees *source*; this
auditor sees what jax actually traced — so it catches violations the AST
cannot (a host callback buried three closure layers deep, an f64
intermediate introduced by dtype promotion rules, a structural difference
between two shapes of the same RHS bucket).

Four rules over each entry of :data:`repro.analysis.registry.HOT_ENTRIES`:

``jaxpr-host-callback``
    any callback-family primitive (``debug_callback`` from
    ``jax.debug.print``, ``pure_callback``, ``io_callback``,
    ``infeed``/``outfeed``) anywhere in the traced closure — each one is
    a device->host round trip per invocation.

``jaxpr-while-transfer``
    the same primitives *inside a ``while_loop`` body or cond* — a sync
    per PCG iteration, the catastrophic variant.

``jaxpr-f64-promotion``
    ``convert_element_type`` to float64, or any f64-dtyped intermediate,
    inside a declared-f32 entry.  Traced under ``jax.experimental.
    enable_x64``: with x64 disabled jax silently *downgrades* f64
    requests, which would mask exactly the promotions we hunt.

``jaxpr-recompile-hazard``
    the entry traced at two shapes in the same RHS pow2 bucket (k=5 and
    k=7 -> bucket 8) must produce an identical primitive structure —
    otherwise the service's warmup-per-bucket compile amortization breaks
    (every new k inside a bucket would recompile).

Findings are located by the primitive's user source frame when jax
records one, falling back to the registry entry's name.
"""
from __future__ import annotations

import os
from typing import Iterable, List, Optional, Sequence, Tuple

from repro.analysis.findings import Finding
from repro.analysis.registry import HOT_ENTRIES, HotEntry

_CALLBACK_PRIMS = {
    "debug_callback", "pure_callback", "io_callback", "callback",
    "host_callback_call", "outside_call", "infeed", "outfeed",
}

# primitives whose params hold sub-jaxprs we must recurse into; everything
# is discovered generically from eqn.params, these are only for while-body
# special-casing
_WHILE_PRIM = "while"


def _sub_jaxprs(params: dict):
    """Yield every (Closed)Jaxpr reachable from an eqn's params."""
    import jax.core as jcore
    closed = getattr(jcore, "ClosedJaxpr", None)
    open_ = getattr(jcore, "Jaxpr", None)

    def walk(obj):
        if closed is not None and isinstance(obj, closed):
            yield obj.jaxpr
        elif open_ is not None and isinstance(obj, open_):
            yield obj
        elif isinstance(obj, (list, tuple)):
            for item in obj:
                yield from walk(item)
        elif isinstance(obj, dict):
            for item in obj.values():
                yield from walk(item)

    for value in params.values():
        yield from walk(value)


def _source_loc(eqn, default_file: str) -> Tuple[str, int]:
    """Best-effort (file, line) of the eqn's user frame."""
    try:
        from jax._src import source_info_util
        frame = source_info_util.user_frame(eqn.source_info)
        if frame is not None:
            fname = frame.file_name
            # report repo-relative paths when the frame is ours
            for marker in ("src/repro/", "repro/"):
                k = fname.find(marker)
                if k >= 0:
                    fname = "src/repro/" + fname[k + len(marker):] \
                        if marker == "repro/" else fname[k:]
                    break
            return fname, frame.start_line
    except Exception:
        pass
    return default_file, 1


def _walk(jaxpr, in_while: bool):
    """Yield ``(eqn, in_while)`` over the jaxpr and all sub-jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn, in_while
        inner_while = in_while or eqn.primitive.name == _WHILE_PRIM
        for sub in _sub_jaxprs(eqn.params):
            yield from _walk(sub, inner_while)


def _prim_structure(jaxpr) -> Tuple[str, ...]:
    """Flattened primitive-name sequence — the recompile-hazard
    comparison key.  Shapes/consts are deliberately excluded: two shapes
    of one bucket differ in constants but must agree here."""
    out: List[str] = []
    for eqn, _ in _walk(jaxpr, False):
        out.append(eqn.primitive.name)
    return tuple(out)


def _trace(fn, args, static_argnums: Tuple[int, ...]):
    import jax
    from jax.experimental import enable_x64
    # x64 ON while tracing: with x64 off, jax silently downgrades f64 and
    # the promotion rule would never fire.  Entries are built f32, so a
    # clean path stays f32 under either flag.
    with enable_x64(True):
        return jax.make_jaxpr(fn, static_argnums=static_argnums)(*args)


def audit_entry(entry: HotEntry) -> List[Finding]:
    """Run all four jaxpr rules over one registered entry."""
    import numpy as np

    findings: List[Finding] = []
    default_file = f"<registry:{entry.name}>"
    try:
        fn, args_small, args_sibling, static = entry.build()
        closed = _trace(fn, args_small, static)
    except Exception as e:  # building/tracing failed: that IS a finding
        return [Finding(
            file=default_file, line=1, rule="jaxpr-recompile-hazard",
            message=f"entry {entry.name} failed to build/trace: "
                    f"{type(e).__name__}: {e}")]

    jaxpr = closed.jaxpr
    f64 = np.dtype("float64")
    seen_lines = set()
    for eqn, in_while in _walk(jaxpr, False):
        name = eqn.primitive.name
        if name in _CALLBACK_PRIMS:
            f, line = _source_loc(eqn, default_file)
            rule = "jaxpr-while-transfer" if in_while \
                else "jaxpr-host-callback"
            findings.append(Finding(
                file=f, line=line, rule=rule,
                message=f"primitive '{name}' in hot path "
                        f"'{entry.name}'"
                        + (" inside a while_loop body — one host sync "
                           "per PCG iteration" if in_while else
                           " — a device->host round trip per call")))
            continue
        if entry.declared_dtype == "float32":
            promo = (name == "convert_element_type"
                     and np.dtype(eqn.params.get("new_dtype")) == f64)
            wide_out = any(
                getattr(getattr(v, "aval", None), "dtype", None) == f64
                for v in eqn.outvars)
            if promo or wide_out:
                f, line = _source_loc(eqn, default_file)
                if (f, line, name) in seen_lines:
                    continue  # one finding per site, not per intermediate
                seen_lines.add((f, line, name))
                findings.append(Finding(
                    file=f, line=line, rule="jaxpr-f64-promotion",
                    message=f"'{name}' produces float64 inside "
                            f"declared-f32 hot path '{entry.name}' — "
                            f"f64 belongs only in the iterative-"
                            f"refinement wrapper outside the jit region"))

    if args_sibling is not None:
        try:
            sibling = _trace(fn, args_sibling, static)
        except Exception as e:
            findings.append(Finding(
                file=default_file, line=1, rule="jaxpr-recompile-hazard",
                message=f"entry {entry.name} failed to trace at the "
                        f"sibling bucket shape: {type(e).__name__}: {e}"))
        else:
            a = _prim_structure(jaxpr)
            b = _prim_structure(sibling.jaxpr)
            if a != b:
                k = next((i for i, (x, y) in enumerate(zip(a, b))
                          if x != y), min(len(a), len(b)))
                findings.append(Finding(
                    file=default_file, line=1,
                    rule="jaxpr-recompile-hazard",
                    message=f"jaxpr structure differs between two shapes "
                            f"of one RHS bucket for '{entry.name}' "
                            f"({len(a)} vs {len(b)} primitives, first "
                            f"divergence at #{k}: "
                            f"{a[k] if k < len(a) else '<end>'} vs "
                            f"{b[k] if k < len(b) else '<end>'}) — "
                            f"warmup-per-bucket amortization is broken"))
    return findings


def check_registry(entries: Optional[Sequence[HotEntry]] = None
                   ) -> List[Finding]:
    """Audit every registered hot entry (or an explicit subset)."""
    out: List[Finding] = []
    for entry in (HOT_ENTRIES if entries is None else entries):
        out.extend(audit_entry(entry))
    return out
