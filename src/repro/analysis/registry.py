"""Registry of jit/Pallas hot entry points for the jaxpr auditor.

Each :class:`HotEntry` names one hot path, a builder that constructs the
callable plus two argument tuples — a *small* shape and a *sibling* shape
in the same RHS pow2 bucket (the service pads ``k`` to pow2 buckets, so
``k=5`` and ``k=7`` both land in bucket 8 and must lower to structurally
identical jaxprs for the warmup-per-bucket amortization to hold).

The entries mirror what production traffic actually traces:

* ``batched_pcg`` — ``make_solver``'s jitted end-to-end solve (PCG +
  V-cycle preconditioner), the service's single-device workhorse.
* ``vcycle_ref`` / ``vcycle_fused`` — the V-cycle closure alone in the
  jnp-reference and the Pallas-fused flavor (interpret mode: the audit
  runs on CPU; the traced structure is backend-independent).
* ``sharded_solver`` — the ``shard_map`` solve on a 1-device mesh (the
  smallest mesh that exercises the sharded code path).
* ``device_contraction`` — the jitted propose/accept hierarchy
  contraction kernel (static ``n``).
* ``harmonic_pcg`` — the Dirichlet-projected ``_pcg_loop`` under
  ``make_dirichlet_core``, the spectral plane's hot path.

Builders are lazy and memoized: the shared mesh2d hierarchy is built once
per process.  Everything here is float32 — the registry's
``declared_dtype`` is what the f64-promotion rule enforces (the f64
iterative-refinement wrapper lives *outside* these closures by design,
and that is exactly what the rule pins down).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class HotEntry:
    """One registered hot path.

    ``build()`` returns ``(fn, args_small, args_sibling, static_argnums)``;
    ``args_sibling`` is ``None`` when the RHS-bucket recompile check does
    not apply (e.g. the contraction has no RHS width).
    """

    name: str
    doc: str
    build: Callable[[], Tuple[Callable, tuple, Optional[tuple],
                              Tuple[int, ...]]]
    declared_dtype: str = "float32"


@functools.lru_cache(maxsize=1)
def _shared_artifacts():
    """(graph, idx, val, hierarchy) for the registry's suite graph —
    small enough to trace in seconds, deep enough for a real multilevel
    V-cycle (mesh2d 12x12 -> 2+ levels at coarse_n=16)."""
    from repro.core.graph import mesh2d
    from repro.solver.device_pcg import ell_laplacian
    from repro.solver.hierarchy import build_hierarchy

    g = mesh2d(12, 12, seed=0)
    idx, val = ell_laplacian(g)
    hier = build_hierarchy(g, coarse_n=16)
    return g, idx, val, hier


def _rhs(n: int, k: int):
    import jax.numpy as jnp
    rng = np.random.RandomState(0)
    b = rng.randn(n, k).astype(np.float32)
    b -= b.mean(axis=0, keepdims=True)
    return jnp.asarray(b)


def _build_batched_pcg():
    from repro.solver.device_pcg import make_solver
    g, idx, val, hier = _shared_artifacts()
    solve = make_solver(idx, val, hier, precond="hierarchy",
                        matvec_impl="ref")
    return solve, (_rhs(g.n, 5),), (_rhs(g.n, 7),), ()


def _build_vcycle(impl: str):
    from repro.solver.device_pcg import make_vcycle
    g, _, _, hier = _shared_artifacts()
    interpret = True if impl == "fused" else None
    vcycle = make_vcycle(hier, matvec_impl=impl, interpret=interpret)
    return vcycle, (_rhs(g.n, 5),), (_rhs(g.n, 7),), ()


def _build_sharded_solver():
    from repro.launch.mesh import compat_make_mesh
    from repro.solver.sharded import make_sharded_solver
    g, idx, val, hier = _shared_artifacts()
    mesh = compat_make_mesh((1,), ("data",))
    solve = make_sharded_solver(idx, val, hier, precond="hierarchy",
                                mesh=mesh, matvec_impl="ref")
    return solve, (_rhs(g.n, 5),), (_rhs(g.n, 7),), ()


def _build_device_contraction():
    import jax.numpy as jnp
    from repro.solver.hierarchy import _device_contract_arrays
    g, _, _, _ = _shared_artifacts()
    args = (g.n, jnp.asarray(g.src), jnp.asarray(g.dst),
            jnp.asarray(g.weight))
    return _device_contract_arrays, args, None, (0,)


def _build_harmonic_pcg():
    import jax.numpy as jnp
    from repro.core.device_graph import DeviceGraph
    from repro.spectral.harmonic import make_dirichlet_core
    g, _, _, _ = _shared_artifacts()
    dg = DeviceGraph.from_graph(g)
    solve = make_dirichlet_core(dg)
    interior = jnp.asarray(
        (np.arange(g.n) >= g.n // 4).astype(np.float32))
    tol = jnp.float32(1e-5)
    maxiter = jnp.int32(50)
    return (solve, (interior, _rhs(g.n, 5), tol, maxiter),
            (interior, _rhs(g.n, 7), tol, maxiter), ())


HOT_ENTRIES: Tuple[HotEntry, ...] = (
    HotEntry("batched_pcg",
             "make_solver jit'd batched PCG + V-cycle (single device)",
             _build_batched_pcg),
    HotEntry("vcycle_ref",
             "make_vcycle closure, jnp reference matvec",
             lambda: _build_vcycle("ref")),
    HotEntry("vcycle_fused",
             "make_vcycle closure, Pallas-fused kernels (interpret)",
             lambda: _build_vcycle("fused")),
    HotEntry("sharded_solver",
             "make_sharded_solver shard_map solve on a 1-device mesh",
             _build_sharded_solver),
    HotEntry("device_contraction",
             "jit'd propose/accept hierarchy contraction (static n)",
             _build_device_contraction),
    HotEntry("harmonic_pcg",
             "make_dirichlet_core projected _pcg_loop (spectral plane)",
             _build_harmonic_pcg),
)
