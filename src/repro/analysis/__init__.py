"""Static invariant checkers for the jit/Pallas hot paths, the
mixed-precision discipline, and the threaded serving plane.

Four checkers, one :class:`~repro.analysis.findings.Finding` shape:

* ``jaxpr`` — :mod:`repro.analysis.jaxpr_audit`: trace the registered
  hot entry points and walk the jaxprs for banned primitives, f64
  promotions, while-body host transfers, and RHS-bucket recompile
  hazards.
* ``trace`` — :mod:`repro.analysis.trace_lint`: AST lint of
  ``src/repro`` for host syncs, numpy-on-traced, and Python branches on
  traced values.
* ``locks`` — :mod:`repro.analysis.lock_lint`: ``# lock:`` inventory
  discipline of the service/daemon threading.
* ``vmem`` — :mod:`repro.analysis.vmem_check`: fused-kernel VMEM
  capacity and sharded tile/halo layout over the bench suite.

CLI: ``python -m repro.analysis --check all [--json PATH]``.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Sequence

from repro.analysis.findings import (  # noqa: F401  (public API)
    RULES, RULES_BY_ID, RULE_IDS, Finding, write_findings_json)

CHECKS = ("jaxpr", "trace", "locks", "vmem")


def _default_root() -> str:
    """The ``src/repro`` package directory this module was imported from."""
    return os.path.dirname(os.path.abspath(__file__)).rsplit(
        os.sep + "analysis", 1)[0]


def run_checks(checks: Sequence[str] = ("all",),
               root: Optional[str] = None) -> Dict[str, List[Finding]]:
    """Run the selected checkers; returns ``{check: findings}``.

    ``root`` overrides the tree the AST checkers walk (default: the
    installed ``repro`` package directory); the jaxpr/vmem checkers
    always run against the imported code.
    """
    selected = list(CHECKS) if "all" in checks else list(checks)
    unknown = sorted(set(selected) - set(CHECKS))
    if unknown:
        raise ValueError(
            f"unknown check(s) {unknown}; valid: all, {', '.join(CHECKS)}")
    root = root or _default_root()
    out: Dict[str, List[Finding]] = {}
    for check in selected:
        if check == "jaxpr":
            from repro.analysis.jaxpr_audit import check_registry
            out[check] = check_registry()
        elif check == "trace":
            from repro.analysis.trace_lint import check_tree
            out[check] = check_tree(root)
        elif check == "locks":
            from repro.analysis.lock_lint import check_tree
            out[check] = check_tree(root)
        elif check == "vmem":
            from repro.analysis.vmem_check import check_suite
            out[check] = check_suite()
    return out
