"""Finding records, the rule registry, and suppression pragmas.

Every checker in :mod:`repro.analysis` reports :class:`Finding` records —
``(file, line, rule id, severity, message)`` — so the CLI, the CI gate and
the tests consume one shape regardless of which analysis produced it.

Suppressions are *inline and reasoned*: a line carrying

    # analysis: allow(<rule-id>): <reason>

silences exactly that rule on that line (or, for block constructs like a
``with`` statement, on the line that opens it).  The reason is mandatory —
a suppression without one is itself reported as ``meta-bare-allow`` — so
every exception to an invariant documents *why* it is safe, reviewable in
the diff that introduced it.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import subprocess
import time
from typing import Dict, Iterable, List, Optional, Tuple

SEV_ERROR = "error"
SEV_WARNING = "warning"


@dataclasses.dataclass(frozen=True)
class Rule:
    """One invariant the analyzers enforce."""

    id: str
    checker: str          # "jaxpr" | "trace" | "locks" | "vmem" | "meta"
    severity: str
    summary: str


# The canonical ruleset.  Rule ids are stable API: tests, suppressions and
# the CI artifact all key on them — add, never repurpose.
RULES: Tuple[Rule, ...] = (
    Rule("jaxpr-host-callback", "jaxpr", SEV_ERROR,
         "host callback / debug print primitive inside a registered jit "
         "hot path (forces a device->host round trip per call)"),
    Rule("jaxpr-f64-promotion", "jaxpr", SEV_ERROR,
         "convert_element_type to float64 (or an f64 intermediate) inside "
         "a declared-f32 hot path; the f64 iterative-refinement wrapper is "
         "the only allowed f64 region"),
    Rule("jaxpr-while-transfer", "jaxpr", SEV_ERROR,
         "host transfer (callback / infeed / outfeed) inside a "
         "lax.while_loop body — a sync per PCG iteration"),
    Rule("jaxpr-recompile-hazard", "jaxpr", SEV_ERROR,
         "jaxpr structure differs between two shapes of the same RHS "
         "bucket — the warmup-per-bucket compile amortization breaks"),
    Rule("trace-host-sync", "trace", SEV_ERROR,
         "float()/int()/bool()/.item() scalarization of a jax value on a "
         "hot path (blocking device round trip)"),
    Rule("trace-numpy-on-traced", "trace", SEV_ERROR,
         "np.* applied to a traced value inside a jit-traced scope "
         "(silent host transfer + constant folding under trace)"),
    Rule("trace-python-branch", "trace", SEV_ERROR,
         "Python if on an array-valued expression inside a jit-traced "
         "scope (TracerBoolConversionError at best, silent "
         "per-value recompilation at worst)"),
    Rule("lock-unguarded-field", "locks", SEV_ERROR,
         "field listed in a '# lock:' inventory read/written outside "
         "'with <lock>' and outside *_locked methods"),
    Rule("lock-unlocked-call", "locks", SEV_ERROR,
         "*_locked method called without holding the lock"),
    Rule("vmem-budget", "vmem", SEV_ERROR,
         "fused-kernel VMEM footprint above the documented budget — the "
         "level must route through the unfused (tiled) path"),
    Rule("vmem-tile-halo", "vmem", SEV_ERROR,
         "tile divisibility / halo extent violation in the sharded "
         "contraction layout"),
    Rule("meta-bare-allow", "meta", SEV_ERROR,
         "suppression pragma without a reason — every allow() must say why"),
)

RULE_IDS = frozenset(r.id for r in RULES)
RULES_BY_ID: Dict[str, Rule] = {r.id: r for r in RULES}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic: where, which rule, and what happened."""

    file: str
    line: int
    rule: str
    message: str
    severity: str = SEV_ERROR

    def format(self) -> str:
        return f"{self.file}:{self.line}: [{self.rule}] {self.message}"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


_ALLOW_RE = re.compile(
    r"#\s*analysis:\s*allow\(\s*([\w.\-]+)\s*\)\s*(?::\s*(\S.*))?")


def scan_pragmas(source: str, path: str
                 ) -> Tuple[Dict[int, set], List[Finding]]:
    """Collect ``# analysis: allow(<rule>)`` pragmas per line.

    Returns ``(allowed, findings)`` where ``allowed[line]`` is the set of
    rule ids suppressed on that line, and ``findings`` reports bare
    (reason-less) or unknown-rule pragmas — a suppression of nothing is a
    typo that would otherwise silently not suppress."""
    allowed: Dict[int, set] = {}
    findings: List[Finding] = []
    for i, text in enumerate(source.splitlines(), start=1):
        m = _ALLOW_RE.search(text)
        if not m:
            continue
        rule, reason = m.group(1), m.group(2)
        if rule not in RULE_IDS:
            findings.append(Finding(
                file=path, line=i, rule="meta-bare-allow",
                message=f"allow({rule}) names no known rule — valid ids: "
                        f"{', '.join(sorted(RULE_IDS))}"))
            continue
        if not reason:
            findings.append(Finding(
                file=path, line=i, rule="meta-bare-allow",
                message=f"allow({rule}) carries no reason — write "
                        f"'# analysis: allow({rule}): <why this is safe>'"))
            continue
        allowed.setdefault(i, set()).add(rule)
    return allowed, findings


def apply_pragmas(findings: Iterable[Finding],
                  allowed: Dict[int, set]) -> List[Finding]:
    """Drop findings whose (line, rule) is suppressed by a pragma on the
    same line."""
    return [f for f in findings
            if f.rule not in allowed.get(f.line, ())]


def _git_sha(cwd: str) -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True, cwd=cwd,
                             timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def write_findings_json(path: str, findings: List[Finding],
                        checks_run: List[str],
                        extra: Optional[dict] = None) -> dict:
    """bench-v1-style machine-readable artifact — the same envelope the
    benchmark harness emits (``schema``/``bench``/``git_sha``/``records``)
    so the CI validator and any downstream tooling parse one format."""
    doc = {
        "schema": "bench-v1",
        "bench": "analysis",
        # resolve the SHA from the checked tree (this package lives in
        # it), not from wherever the artifact is being written
        "git_sha": _git_sha(os.path.dirname(os.path.abspath(__file__))),
        "created_unix": time.time(),
        "records": {
            "checks_run": sorted(checks_run),
            "ruleset": [dataclasses.asdict(r) for r in RULES],
            "findings": [f.as_dict() for f in findings],
            "finding_count": len(findings),
        },
    }
    if extra:
        doc.update(extra)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    return doc
