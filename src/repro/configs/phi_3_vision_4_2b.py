"""phi-3-vision-4.2b [vlm] — 32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064 — phi3-mini + CLIP [hf:microsoft/Phi-3-vision-128k-instruct; hf].

The CLIP frontend is a STUB per the assignment: ``input_specs`` provides
precomputed patch embeddings [B, 256, 1024] that a learned projector maps
into the backbone width, prefixed to the text sequence.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    vocab=32064,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    frontend="vision",
    frontend_dim=1024,
    frontend_len=256,
).validate()
