"""The paper's own workload config: distributed pdGRASS edge recovery.

Not an LM architecture — this describes the graph-sparsification
production job: a power-grid-scale graph whose off-tree edges are
sharded across the full mesh and recovered with the inner (cross-device)
round engine.  Lowered/compiled by ``repro.launch.dryrun_pdgrass``.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PdGrassConfig:
    name: str = "pdgrass-graph"
    n_vertices: int = 16_000_000          # |V| ~ 1.6e7 (power-grid scale)
    m_offtree: int = 2 ** 25              # 33.5M off-tree edges
    c: int = 8                            # BFS cap (beta <= c)
    block_size: int = 64                  # candidates per round per shard
    chunk: int = 4096                     # marking-pass tile rows


CONFIG = PdGrassConfig()
