"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attn+mamba heads
[arXiv:2411.13676; hf].

Blocks run attention and mamba in parallel on the same normed input and
average the branch outputs.  3 layers (first/middle/last) use global
attention, the rest sliding-window — the 'hymba' layer pattern.  Meta
tokens are not modeled (DESIGN.md §Arch-applicability).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    vocab=32001,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    layer_pattern="hymba",
    window=1024,
    ssm_state=16,
    ssm_expand=2,
    ssm_chunk=16,   # §Perf I3 (same scan-residual scaling as falcon-mamba)
    tie_embeddings=True,
).validate()
