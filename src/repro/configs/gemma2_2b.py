"""gemma2-2b [dense] — 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000,
local+global alternating, logit softcap [arXiv:2408.00118; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    vocab=256000,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    mlp_type="geglu",
    layer_pattern="local_global",
    window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    sandwich_norm=True,
    embed_scale=True,
    tie_embeddings=True,
).validate()
