"""falcon-mamba-7b [ssm] — 64L d_model=4096 attn-free, vocab=65024, state=16.

Mamba1 architecture [arXiv:2410.05355; unverified].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    vocab=65024,
    d_ff=0,
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    ssm_dt_rank=256,
    ssm_chunk=16,   # §Perf I3: 6.8x lower memory-roofline term vs 256
    tie_embeddings=True,
).validate()
