"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual [hf:Snowflake/snowflake-arctic-base; hf].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    vocab=32000,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    n_experts=128,
    top_k=2,
    moe_d_ff=4864,
    dense_residual=True,     # arctic: dense FFN in parallel with the MoE
    expert_shard="ep",       # 128 experts / 16-way model axis = 8 per device
).validate()
