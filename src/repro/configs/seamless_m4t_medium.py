"""seamless-m4t-medium [audio] — 12L d_model=1024 16H d_ff=4096
vocab=256206 — enc-dec, multimodal [arXiv:2308.11596; hf].

Backbone only: the speech frontend is a STUB; ``input_specs`` provides
precomputed frame embeddings [B, S_src, 1024] consumed by the encoder.
12 encoder + 12 decoder layers with cross-attention.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,
    enc_layers=12,
    d_model=1024,
    vocab=256206,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    mlp_type="gelu",
    frontend="audio",
    frontend_dim=1024,
    frontend_len=0,   # src length comes from the shape spec, not fixed
).validate()
