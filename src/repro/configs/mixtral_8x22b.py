"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8e top-2, SWA [arXiv:2401.04088; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    vocab=32768,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    n_experts=8,
    top_k=2,
    layer_pattern="swa",
    window=4096,
    expert_shard="tp",       # 8 experts < 16-way model axis: TP inside experts
).validate()
