"""Architecture registry: ``--arch <id>`` resolves through here.

``get_config(name)`` accepts dashed or underscored ids.  ``reduced(cfg)``
shrinks any config to a CPU-smokeable size of the same family (small
layers/width, few experts, tiny vocab) — used by the per-arch smoke tests.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct).
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCHS = [
    "falcon-mamba-7b",
    "arctic-480b",
    "mixtral-8x22b",
    "qwen3-4b",
    "phi3-medium-14b",
    "gemma2-2b",
    "starcoder2-15b",
    "phi-3-vision-4.2b",
    "hymba-1.5b",
    "seamless-m4t-medium",
]


def _modname(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(name: str) -> ModelConfig:
    key = name.replace("_", "-")
    if key not in [a for a in ARCHS]:
        # allow exact underscore ids too
        matches = [a for a in ARCHS if _modname(a) == _modname(name)]
        if not matches:
            raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
        key = matches[0]
    mod = importlib.import_module(f"repro.configs.{_modname(key)}")
    return mod.CONFIG


def reduced(cfg: ModelConfig) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=2,
        d_model=64,
        vocab=512,
        d_ff=128 if cfg.d_ff else 0,
        ssm_chunk=16,
        moe_group=64,
    )
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv_heads=2, head_dim=16)
    if cfg.n_experts:
        kw.update(n_experts=4, top_k=min(cfg.top_k, 2), moe_d_ff=128)
    if cfg.ssm_state:
        kw.update(ssm_state=4, ssm_dt_rank=8)
    if cfg.window:
        kw.update(window=8)
    if cfg.enc_layers:
        kw.update(enc_layers=2)
    if cfg.frontend:
        kw.update(frontend_dim=32,
                  frontend_len=4 if cfg.frontend_len else 0)
    return dataclasses.replace(cfg, **kw).validate()


__all__ = ["ARCHS", "get_config", "reduced"]
