"""Figure 1 analog: relative recovery time and PCG-iteration ratios
(feGRASS / pdGRASS) per graph x alpha.  >1 on either axis means pdGRASS
improves on that metric."""
from __future__ import annotations

import argparse

from benchmarks import table2_quality


def run(quick: bool = False):
    rows = table2_quality.run(scale="tiny" if quick else "small",
                              alphas=(0.05,) if quick else (0.02, 0.05, 0.10),
                              quality=True)
    out = []
    for r in rows:
        out.append({
            "graph": r["graph"], "alpha": r["alpha"],
            "time_ratio": round(r["T_fe_ms"] / max(r["T_pd_ms"], 1e-3), 2),
            "iter_ratio": r.get("iter_ratio", float("nan")),
        })
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    rows = run(quick=args.quick)
    print("graph,alpha,time_ratio_fe_over_pd,iter_ratio_fe_over_pd")
    for r in rows:
        print(f"{r['graph']},{r['alpha']},{r['time_ratio']},{r['iter_ratio']}")


if __name__ == "__main__":
    main()
