"""Table III analog: Judge-Before-Parallel statistics on a skewed graph.

The paper's JBP optimization selects only *unmarked* edges as parallel-
block candidates, eliminating idle "continue-branch" lanes.  Our round
engine implements JBP structurally (candidates are the first-B *open*
rows per subtask); this benchmark quantifies it by comparing against a
naive variant that blocks over the next B rows regardless of status —
reporting candidates examined, in-block kills (redundant parallel work,
the paper's "false positives") and round counts.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import barabasi_albert, star_hub, prepare
from repro.core.recovery import recover_rounds


def run(quick: bool = False):
    if quick:
        graphs = [("ba_skewed", barabasi_albert(300, 4, seed=1)),
                  ("star_hub", star_hub(200, extra=150, seed=2))]
        blocks = [(16, 128)]
    else:
        graphs = [("ba_skewed", barabasi_albert(3000, 4, seed=1)),
                  ("star_hub", star_hub(2000, extra=1500, seed=2))]
        blocks = [(16, 128), (32, 256)]
    rows = []
    for name, g in graphs:
        prep = prepare(g)
        for B, K in blocks:
            status, stats = recover_rounds(
                prep.problem, block_size=B, max_candidates=K,
                stop_at_target=False)
            n_rec = int((np.asarray(status) == 1).sum())
            cand = int(stats.candidates)
            killed = int(stats.killed_in_block)
            rows.append({
                "graph": name, "block": B, "cap": K,
                "rounds": int(stats.rounds),
                "candidates": cand,
                "recovered": n_rec,
                "killed_in_block_pct": round(100 * killed / max(cand, 1), 2),
                "useful_pct": round(100 * n_rec / max(cand, 1), 2),
            })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    rows = run(quick=args.quick)
    keys = list(rows[0].keys())
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r[k]) for k in keys))


if __name__ == "__main__":
    main()
