"""Solver-service benchmark: per-call host PCG vs cached batched device PCG.

Four ways to serve ``L_G x = b`` traffic on the same graph:

  * ``host``        — the pre-solver-service path: rebuild the pdGRASS
    sparsifier, factor it (sparse LU), and run scipy PCG — per call.
  * ``dev``         — device batched PCG (jit'd lax.while_loop, ELL matvec),
    unpreconditioned, artifacts cached across calls.
  * ``dev+hier:pd`` — device batched PCG preconditioned by the multilevel
    hierarchy built from the **pdGRASS** pipeline config.
  * ``dev+hier:fe`` — the same service, same code path, with the **feGRASS**
    pipeline config as a *per-request override* — the v2 serving API: one
    ``SolverService``, two stage mixes, two cached hierarchies.

The graph is registered once (``svc.register -> GraphHandle``), so the
O(m) content hash is paid once per graph per process — not twice per row
as in the v1 bench.  A final **mixed-config flush** row submits pdGRASS-
and feGRASS-preconditioned requests for the same mesh in one flush; the
scheduler splits them into two (graph, config) groups, each cache-hitting
its own hierarchy.

A **hierarchy-build row** times the multilevel build under both
contraction modes (``host`` sequential greedy matching vs the default
``device`` jit'd propose/accept matching) and asserts they produce the
same chain shape (depth, per-level sizes) — the parity check runs in CI
through ``--quick``.

``--sharded`` adds a **mesh-sharded solve row**: a ``SolverService(mesh=)``
over every visible device (row-sharded PCG + V-cycle + sharded hierarchy
contraction) timed against the same traffic, with solution parity asserted
against the single-device path (re-based solutions within atol, iteration
counts within +-2).  CI runs it under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

    PYTHONPATH=src python benchmarks/solver_bench.py [--scale small] [--k 8]
    PYTHONPATH=src python benchmarks/solver_bench.py --quick
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python benchmarks/solver_bench.py --quick --sharded
"""
import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.common import timeit, write_bench_json  # noqa: E402

from repro.core import barabasi_albert, mesh2d, pdgrass  # noqa: E402
from repro.core.pcg import pcg_host  # noqa: E402
from repro.pipeline import fegrass_config, pdgrass_config  # noqa: E402
from repro.solver import (SolveRequest, SolverService,  # noqa: E402
                          build_hierarchy)


def host_solve_per_call(g, b):
    """The old path: steps 1-4 + LU factor + PCG, all rebuilt per call."""
    sp = pdgrass(g, alpha=0.05)
    return pcg_host(g.laplacian(), b.astype(np.float64), sp.laplacian(),
                    tol=1e-5, maxiter=5000)


def mixed_config_flush(svc, handle, B, pd_cfg, fe_cfg):
    """One flush, two PipelineConfigs, same graph: the scheduler must split
    the batch into per-config groups that each hit their cached hierarchy."""
    k = B.shape[1]
    half = max(k // 2, 1)
    t_pd = svc.submit(SolveRequest(graph=handle, b=B[:, :half]))
    t_fe = svc.submit(SolveRequest(graph=handle, b=B[:, half:] if k > 1
                                   else B, pipeline=fe_cfg))
    groups_before = svc.stats()["scheduler"]["groups"]
    t0 = time.perf_counter()
    out = svc.flush()
    t_flush = time.perf_counter() - t0
    groups = svc.stats()["scheduler"]["groups"] - groups_before
    r_pd, r_fe = out[t_pd], out[t_fe]
    assert groups == 2, f"expected 2 (graph, config) groups, got {groups}"
    assert r_pd.config != r_fe.config, "configs collapsed into one group"
    assert r_pd.cache == "mem" and r_fe.cache == "mem", (
        "mixed-config flush missed the artifact cache: "
        f"pd={r_pd.cache} fe={r_fe.cache}")
    assert r_pd.converged and r_fe.converged
    return t_flush, groups


def hierarchy_build_row(name, g, cfg):
    """Time the multilevel hierarchy build under both contraction modes.

    The device path must agree with the host oracle on the chain shape
    (depth + per-level sizes — the strict total order makes the clustering
    identical), so any drift in the propose/accept matching fails the bench
    before it shows up as solver-quality noise.  Device cold includes the
    per-level jit compiles; warm is the serving-relevant rebuild time.
    """
    t0 = time.perf_counter()
    h_host = build_hierarchy(g, config=cfg, contraction="host")
    t_host = time.perf_counter() - t0
    t0 = time.perf_counter()
    h_dev = build_hierarchy(g, config=cfg, contraction="device")
    t_dev_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    h_dev = build_hierarchy(g, config=cfg, contraction="device")
    t_dev = time.perf_counter() - t0
    assert h_dev.depth == h_host.depth, (
        f"{name}: device depth {h_dev.depth} != host {h_host.depth}")
    assert h_dev.level_sizes == h_host.level_sizes, (
        f"{name}: device levels {h_dev.level_sizes} != host "
        f"{h_host.level_sizes}")
    print(f"  hier build:   host={t_host*1e3:8.1f} ms  "
          f"device={t_dev*1e3:8.1f} ms (cold {t_dev_cold*1e3:.1f} ms)  "
          f"depth={h_dev.depth} levels={h_dev.level_sizes}")
    return {"host_ms": t_host * 1e3, "device_ms": t_dev * 1e3,
            "device_cold_ms": t_dev_cold * 1e3, "depth": h_dev.depth,
            "level_sizes": list(h_dev.level_sizes)}


def sharded_solve_row(name, g, B, pd_cfg, ref, repeat=1):
    """Time the mesh-sharded solve plane over every visible device and
    assert solution parity against the single-device path.

    Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to
    exercise real collectives; on one device the mesh is (1,) and the row
    degenerates to a layout check.  Parity contract (same as the tier-1
    suite): re-based solutions within atol, per-column iteration counts
    within +-2.
    """
    import jax
    from repro.launch.mesh import compat_make_mesh

    mesh = compat_make_mesh((jax.device_count(),), ("data",))
    svc = SolverService(pipeline=pd_cfg, mesh=mesh)
    handle = svc.register(g)
    t0 = time.perf_counter()
    cold = svc.solve(handle, B)
    t_cold = time.perf_counter() - t0
    t_warm, warm = timeit(svc.solve, handle, B, repeat=repeat)
    assert warm.cache == "mem" and warm.converged, (name, "sharded")

    def rebase(x):
        x = np.asarray(x, np.float64)
        return x - x[0]

    np.testing.assert_allclose(rebase(warm.x), rebase(ref.x), atol=1e-4,
                               err_msg=f"{name}: sharded solve drifted "
                                       f"from the single-device path")
    d_it = np.abs(np.asarray(warm.iters, np.int64)
                  - np.asarray(ref.iters, np.int64)).max()
    assert d_it <= 2, (
        f"{name}: sharded iteration counts drifted by {d_it} (> 2) "
        f"from the single-device path")
    k = B.shape[1]
    print(f"  sharded ({jax.device_count()} dev) cold={t_cold:6.1f}s  warm="
          f"{t_warm * 1e3 / k:8.2f} ms/rhs   iters={int(warm.iters.max()):<5d}"
          f" relres={float(warm.relres.max()):.1e}  parity_vs_1dev=OK "
          f"(d_iters<={int(d_it)})")
    return {"devices": jax.device_count(), "cold_s": t_cold,
            "warm_ms_per_rhs": t_warm * 1e3 / k,
            "iters": int(warm.iters.max()),
            "relres": float(warm.relres.max()), "d_iters": int(d_it)}


def bench_graph(name, g, k=8, repeat=3, sharded=False):
    rng = np.random.default_rng(0)
    B = rng.standard_normal((g.n, k)).astype(np.float32)
    B -= B.mean(axis=0)

    # host path: one RHS per call (it has no batching), time per call
    t_host, res_host = timeit(host_solve_per_call, g, B[:, 0], repeat=repeat)

    pd_cfg = pdgrass_config(alpha=0.05, chunk=512)
    fe_cfg = fegrass_config(alpha=0.05, chunk=512)
    svc_none = SolverService(pipeline=pd_cfg, precond="none")
    svc_hier = SolverService(pipeline=pd_cfg, precond="hierarchy")
    handle = svc_hier.register(g)   # content hash paid once, reused below
    svc_none.register(handle)
    rows = []
    warm_by_tag = {}
    for tag, svc, pipeline in [
            ("dev", svc_none, None),
            ("dev+hier:pd", svc_hier, None),
            ("dev+hier:fe", svc_hier, fe_cfg)]:
        t0 = time.perf_counter()
        cold = svc.solve(handle, B, pipeline=pipeline)  # build + jit + solve
        t_cold = time.perf_counter() - t0
        t_warm, warm = timeit(svc.solve, handle, B, pipeline=pipeline,
                              repeat=repeat)
        assert warm.cache == "mem" and warm.converged, (name, tag)
        warm_by_tag[tag] = warm
        rows.append({
            "tag": tag,
            "cold_s": t_cold,
            "warm_ms_per_rhs": t_warm * 1e3 / k,
            "iters": int(warm.iters.max()),
            "relres": float(warm.relres.max()),
        })

    host_ms = t_host * 1e3
    print(f"\n{name}: |V|={g.n} |E|={g.m}  batch k={k}")
    hier_rec = hierarchy_build_row(name, g, pd_cfg)
    print(f"  host per-call:        {host_ms:10.1f} ms/rhs   "
          f"iters={res_host.iters}")
    for r in rows:
        speedup = host_ms / r["warm_ms_per_rhs"]
        print(f"  {r['tag']:<12} cold={r['cold_s']:6.1f}s  warm="
              f"{r['warm_ms_per_rhs']:8.2f} ms/rhs   iters={r['iters']:<5d} "
              f"relres={r['relres']:.1e}  speedup_vs_host={speedup:8.1f}x")
    by_tag = {r["tag"]: r for r in rows}
    pd_r, fe_r = by_tag["dev+hier:pd"], by_tag["dev+hier:fe"]
    print(f"  pd-vs-fe (one service, per-request configs): iters "
          f"{pd_r['iters']} vs {fe_r['iters']}, warm "
          f"{pd_r['warm_ms_per_rhs']:.2f} vs "
          f"{fe_r['warm_ms_per_rhs']:.2f} ms/rhs")
    sharded_rec = None
    if sharded:
        sharded_rec = sharded_solve_row(name, g, B, pd_cfg,
                                        warm_by_tag["dev+hier:pd"],
                                        repeat=repeat)
    t_mixed, groups = mixed_config_flush(svc_hier, handle, B, pd_cfg, fe_cfg)
    stats = svc_hier.stats()
    print(f"  mixed flush (pd+fe):  {t_mixed*1e3:8.1f} ms for k={k} RHS in "
          f"{groups} groups  hash_events={stats['store']['hash_events']} "
          f"cache_hits={stats['cache']['hits']}")
    warm_best = min(r["warm_ms_per_rhs"] for r in rows)
    assert warm_best < host_ms, (
        f"{name}: cached device path ({warm_best:.1f} ms/rhs) did not beat "
        f"the per-call host path ({host_ms:.1f} ms/rhs)")
    return {
        "graph": name, "n": g.n, "m": g.m, "k": k,
        "host_ms_per_rhs": host_ms,
        "host_iters": int(res_host.iters),
        "hierarchy_build": hier_rec,
        "rows": rows,
        "sharded": sharded_rec,
        "mixed_flush_ms": t_mixed * 1e3,
        "mixed_flush_groups": groups,
        "convergence": stats["convergence"],
        "speedup_best": host_ms / warm_best,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small",
                    choices=["small", "medium"])
    ap.add_argument("--k", type=int, default=8, help="RHS batch width")
    ap.add_argument("--quick", action="store_true",
                    help="tiny graphs, k=2 — smoke-test the code path")
    ap.add_argument("--sharded", action="store_true",
                    help="add a mesh-sharded solve row over every visible "
                         "device (set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8 for "
                         "real collectives) asserting parity vs the "
                         "single-device path")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write machine-readable results (schema bench-v1: "
                         "rows, timings, iteration counts, git SHA)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="enable span tracing for the whole run and export "
                         "a Chrome trace-event JSON (open in "
                         "ui.perfetto.dev)")
    args = ap.parse_args(argv)

    if args.trace:
        from repro.obs import enable_tracing
        enable_tracing()

    if args.quick:
        graphs = {
            "mesh2d-16x16": mesh2d(16, 16, seed=0),
            "ba-300": barabasi_albert(300, 3, seed=1),
        }
        k, repeat = 2, 1
    elif args.scale == "small":
        graphs = {
            "mesh2d-40x40": mesh2d(40, 40, seed=0),
            "mesh2d-60x60": mesh2d(60, 60, seed=0),
            "ba-2000": barabasi_albert(2000, 3, seed=1),
        }
        k, repeat = args.k, 3
    else:
        graphs = {
            "mesh2d-100x100": mesh2d(100, 100, seed=0),
            "mesh2d-160x160": mesh2d(160, 160, seed=0),
            "ba-20000": barabasi_albert(20_000, 3, seed=1),
        }
        k, repeat = args.k, 3

    records = [bench_graph(name, g, k=k, repeat=repeat,
                           sharded=args.sharded)
               for name, g in graphs.items()]
    speedups = [r["speedup_best"] for r in records]
    print(f"\ncached+jit'd device PCG beats the per-call host path on every "
          f"graph (best-path speedups: "
          f"{', '.join(f'{s:.0f}x' for s in speedups)})")
    if args.json:
        write_bench_json(args.json, "solver_bench", records,
                         extra={"quick": args.quick, "scale": args.scale,
                                "k": k, "sharded": args.sharded})
    if args.trace:
        from repro.obs import get_tracer
        get_tracer().export_chrome(args.trace)
        print(f"wrote {args.trace} "
              f"({len(get_tracer().events())} span events)")


if __name__ == "__main__":
    main()
