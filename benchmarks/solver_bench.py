"""Solver-service benchmark: per-call host PCG vs cached batched device PCG.

Four ways to serve ``L_G x = b`` traffic on the same graph:

  * ``host``        — the pre-solver-service path: rebuild the pdGRASS
    sparsifier, factor it (sparse LU), and run scipy PCG — per call.
  * ``dev``         — device batched PCG (jit'd lax.while_loop, ELL matvec),
    unpreconditioned, artifacts cached across calls.
  * ``dev+hier:pd`` — device batched PCG preconditioned by the multilevel
    hierarchy built from the **pdGRASS** pipeline config.
  * ``dev+hier:fe`` — same service, same code path, with the **feGRASS**
    pipeline config (the paper's Table II baseline) — the two rows differ
    only by a ``PipelineConfig`` recovery-stage diff.

The device rows pay a one-time cold cost (pipeline steps 1-4 + jit) and
then amortize it over every subsequent solve on the same graph — the
serving regime the cache exists for.

    PYTHONPATH=src python benchmarks/solver_bench.py [--scale small] [--k 8]
    PYTHONPATH=src python benchmarks/solver_bench.py --quick
"""
import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.common import timeit  # noqa: E402

from repro.core import barabasi_albert, mesh2d, pdgrass  # noqa: E402
from repro.core.pcg import pcg_host  # noqa: E402
from repro.pipeline import fegrass_config, pdgrass_config  # noqa: E402
from repro.solver import SolverService  # noqa: E402


def host_solve_per_call(g, b):
    """The old path: steps 1-4 + LU factor + PCG, all rebuilt per call."""
    sp = pdgrass(g, alpha=0.05)
    return pcg_host(g.laplacian(), b.astype(np.float64), sp.laplacian(),
                    tol=1e-5, maxiter=5000)


def bench_graph(name, g, k=8, repeat=3):
    rng = np.random.default_rng(0)
    B = rng.standard_normal((g.n, k)).astype(np.float32)
    B -= B.mean(axis=0)

    # host path: one RHS per call (it has no batching), time per call
    t_host, res_host = timeit(host_solve_per_call, g, B[:, 0], repeat=repeat)

    pd_cfg = pdgrass_config(alpha=0.05, chunk=512)
    fe_cfg = fegrass_config(alpha=0.05, chunk=512)
    services = [
        ("dev", SolverService(pipeline=pd_cfg, precond="none")),
        ("dev+hier:pd", SolverService(pipeline=pd_cfg, precond="hierarchy")),
        ("dev+hier:fe", SolverService(pipeline=fe_cfg, precond="hierarchy")),
    ]
    rows = []
    for tag, svc in services:
        t0 = time.perf_counter()
        cold = svc.solve(g, B)           # build + jit + first solve
        t_cold = time.perf_counter() - t0
        t_warm, warm = timeit(svc.solve, g, B, repeat=repeat)
        assert warm.cache == "mem" and warm.converged, (name, tag)
        rows.append({
            "tag": tag,
            "cold_s": t_cold,
            "warm_ms_per_rhs": t_warm * 1e3 / k,
            "iters": int(warm.iters.max()),
            "relres": float(warm.relres.max()),
        })

    host_ms = t_host * 1e3
    print(f"\n{name}: |V|={g.n} |E|={g.m}  batch k={k}")
    print(f"  host per-call:        {host_ms:10.1f} ms/rhs   "
          f"iters={res_host.iters}")
    for r in rows:
        speedup = host_ms / r["warm_ms_per_rhs"]
        print(f"  {r['tag']:<12} cold={r['cold_s']:6.1f}s  warm="
              f"{r['warm_ms_per_rhs']:8.2f} ms/rhs   iters={r['iters']:<5d} "
              f"relres={r['relres']:.1e}  speedup_vs_host={speedup:8.1f}x")
    by_tag = {r["tag"]: r for r in rows}
    pd_r, fe_r = by_tag["dev+hier:pd"], by_tag["dev+hier:fe"]
    print(f"  pd-vs-fe (one Pipeline code path): iters {pd_r['iters']} vs "
          f"{fe_r['iters']}, warm {pd_r['warm_ms_per_rhs']:.2f} vs "
          f"{fe_r['warm_ms_per_rhs']:.2f} ms/rhs")
    warm_best = min(r["warm_ms_per_rhs"] for r in rows)
    assert warm_best < host_ms, (
        f"{name}: cached device path ({warm_best:.1f} ms/rhs) did not beat "
        f"the per-call host path ({host_ms:.1f} ms/rhs)")
    return host_ms / warm_best


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small",
                    choices=["small", "medium"])
    ap.add_argument("--k", type=int, default=8, help="RHS batch width")
    ap.add_argument("--quick", action="store_true",
                    help="tiny graphs, k=2 — smoke-test the code path")
    args = ap.parse_args(argv)

    if args.quick:
        graphs = {
            "mesh2d-16x16": mesh2d(16, 16, seed=0),
            "ba-300": barabasi_albert(300, 3, seed=1),
        }
        k, repeat = 2, 1
    elif args.scale == "small":
        graphs = {
            "mesh2d-40x40": mesh2d(40, 40, seed=0),
            "mesh2d-60x60": mesh2d(60, 60, seed=0),
            "ba-2000": barabasi_albert(2000, 3, seed=1),
        }
        k, repeat = args.k, 3
    else:
        graphs = {
            "mesh2d-100x100": mesh2d(100, 100, seed=0),
            "mesh2d-160x160": mesh2d(160, 160, seed=0),
            "ba-20000": barabasi_albert(20_000, 3, seed=1),
        }
        k, repeat = args.k, 3

    speedups = [bench_graph(name, g, k=k, repeat=repeat)
                for name, g in graphs.items()]
    print(f"\ncached+jit'd device PCG beats the per-call host path on every "
          f"graph (best-path speedups: "
          f"{', '.join(f'{s:.0f}x' for s in speedups)})")


if __name__ == "__main__":
    main()
