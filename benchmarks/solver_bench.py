"""Solver-service benchmark: per-call host PCG vs cached batched device PCG.

Three ways to serve ``L_G x = b`` traffic on the same graph:

  * ``host``      — the pre-solver-service path: rebuild the pdGRASS
    sparsifier, factor it (sparse LU), and run scipy PCG — per call.
  * ``dev``       — device batched PCG (jit'd lax.while_loop, ELL matvec),
    unpreconditioned, artifacts cached across calls.
  * ``dev+hier``  — device batched PCG preconditioned by the multilevel
    hierarchy V-cycle, artifacts cached across calls.

The device rows pay a one-time cold cost (pipeline steps 1-4 + jit) and
then amortize it over every subsequent solve on the same graph — the
serving regime the cache exists for.

    PYTHONPATH=src python benchmarks/solver_bench.py [--scale small] [--k 8]
"""
import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.common import timeit  # noqa: E402

from repro.core import barabasi_albert, mesh2d, pdgrass  # noqa: E402
from repro.core.pcg import pcg_host  # noqa: E402
from repro.solver import SolverService  # noqa: E402


def host_solve_per_call(g, b):
    """The old path: steps 1-4 + LU factor + PCG, all rebuilt per call."""
    sp = pdgrass(g, alpha=0.05)
    return pcg_host(g.laplacian(), b.astype(np.float64), sp.laplacian(),
                    tol=1e-5, maxiter=5000)


def bench_graph(name, g, k=8, repeat=3):
    rng = np.random.default_rng(0)
    B = rng.standard_normal((g.n, k)).astype(np.float32)
    B -= B.mean(axis=0)

    # host path: one RHS per call (it has no batching), time per call
    t_host, res_host = timeit(host_solve_per_call, g, B[:, 0], repeat=repeat)

    rows = []
    for precond in ("none", "hierarchy"):
        svc = SolverService(alpha=0.05, precond=precond)
        t0 = time.perf_counter()
        cold = svc.solve(g, B)           # build + jit + first solve
        t_cold = time.perf_counter() - t0
        t_warm, warm = timeit(svc.solve, g, B, repeat=repeat)
        assert warm.cache == "mem" and warm.converged, (name, precond)
        rows.append({
            "precond": precond,
            "cold_s": t_cold,
            "warm_ms_per_rhs": t_warm * 1e3 / k,
            "iters": int(warm.iters.max()),
            "relres": float(warm.relres.max()),
        })

    host_ms = t_host * 1e3
    print(f"\n{name}: |V|={g.n} |E|={g.m}  batch k={k}")
    print(f"  host per-call:        {host_ms:10.1f} ms/rhs   "
          f"iters={res_host.iters}")
    for r in rows:
        tag = "dev" if r["precond"] == "none" else "dev+hier"
        speedup = host_ms / r["warm_ms_per_rhs"]
        print(f"  {tag:<10} cold={r['cold_s']:6.1f}s  warm="
              f"{r['warm_ms_per_rhs']:8.2f} ms/rhs   iters={r['iters']:<5d} "
              f"relres={r['relres']:.1e}  speedup_vs_host={speedup:8.1f}x")
    warm_best = min(r["warm_ms_per_rhs"] for r in rows)
    assert warm_best < host_ms, (
        f"{name}: cached device path ({warm_best:.1f} ms/rhs) did not beat "
        f"the per-call host path ({host_ms:.1f} ms/rhs)")
    return host_ms / warm_best


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small", choices=["small", "medium"])
    ap.add_argument("--k", type=int, default=8, help="RHS batch width")
    args = ap.parse_args()

    if args.scale == "small":
        graphs = {
            "mesh2d-40x40": mesh2d(40, 40, seed=0),
            "mesh2d-60x60": mesh2d(60, 60, seed=0),
            "ba-2000": barabasi_albert(2000, 3, seed=1),
        }
    else:
        graphs = {
            "mesh2d-100x100": mesh2d(100, 100, seed=0),
            "mesh2d-160x160": mesh2d(160, 160, seed=0),
            "ba-20000": barabasi_albert(20_000, 3, seed=1),
        }

    speedups = [bench_graph(name, g, k=args.k) for name, g in graphs.items()]
    print(f"\ncached+jit'd device PCG beats the per-call host path on every "
          f"graph (best-path speedups: "
          f"{', '.join(f'{s:.0f}x' for s in speedups)})")


if __name__ == "__main__":
    main()
