"""Kernel microbenchmarks: Pallas (interpret) vs pure-jnp reference.

On this CPU container the Pallas kernels execute in interpret mode, so
the numbers measure correctness-path overhead, not TPU performance; the
jnp reference path is what the CPU actually runs in production here.
Shapes sweep the regimes the recovery engine uses.
"""
from __future__ import annotations

import argparse

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import timeit
from repro.kernels import ops


def run(quick: bool = False):
    shapes = [(16, 4096)] if quick else [(16, 4096), (64, 16384),
                                         (128, 65536)]
    spmv_shapes = [(4096, 8)] if quick else [(4096, 8), (65536, 8)]
    rows = []
    rng = np.random.default_rng(0)
    for K, m in shapes:
        c1 = 9
        mk = lambda r: jnp.asarray(   # noqa: E731
            rng.integers(0, 1000, (r, c1)).astype(np.int32))
        csu, csv, esu, esv = mk(K), mk(K), mk(m), mk(m)
        cbeta = jnp.asarray(rng.integers(0, c1, K).astype(np.int32))
        cseg = jnp.asarray(rng.integers(0, 8, K).astype(np.int32))
        eseg = jnp.asarray(rng.integers(0, 8, m).astype(np.int32))

        t_ref, _ = timeit(lambda: np.asarray(ops.similarity_mark_ref(
            csu, csv, cbeta, cseg, esu, esv, eseg)), repeat=3)
        rows.append((f"similarity_ref_K{K}_m{m}", t_ref * 1e6,
                     f"pairs={K*m}"))
        t_int, _ = timeit(lambda: np.asarray(ops.similarity_mark(
            csu, csv, cbeta, cseg, esu, esv, eseg, tile_m=2048)), repeat=1)
        rows.append((f"similarity_pallas_interp_K{K}_m{m}", t_int * 1e6,
                     "interpret=True"))

    for n, L in spmv_shapes:
        idx = jnp.asarray(rng.integers(0, n, (n, L)).astype(np.int32))
        val = jnp.asarray(rng.standard_normal((n, L)).astype(np.float32))
        x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        t_ref, _ = timeit(lambda: np.asarray(ops.spmv_ref(idx, val, x)),
                          repeat=3)
        rows.append((f"spmv_ref_n{n}", t_ref * 1e6, f"nnz={n*L}"))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    for name, us, derived in run(quick=args.quick):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
