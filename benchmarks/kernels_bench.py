"""Kernel microbenchmarks: Pallas (interpret) vs pure-jnp reference.

On this CPU container the Pallas kernels execute in interpret mode, so
the numbers measure correctness-path overhead, not TPU performance; the
jnp reference path is what the CPU actually runs in production here.
Shapes sweep the regimes the recovery engine uses.

The ``vcycle_*`` rows time a full preconditioner application through the
fused kernel suite vs the unfused composition (checked allclose on the
way), and the run asserts the fused HBM-byte model below the unfused one
— the same acceptance gate ``roofline_table`` carries, here on the
microbench path.  ``--json`` writes a bench-v1 artifact with the rows
plus the byte models.
"""
from __future__ import annotations

import argparse

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import timeit, write_bench_json
from repro.kernels import ops


def run(quick: bool = False):
    shapes = [(16, 4096)] if quick else [(16, 4096), (64, 16384),
                                         (128, 65536)]
    spmv_shapes = [(4096, 8)] if quick else [(4096, 8), (65536, 8)]
    rows = []
    rng = np.random.default_rng(0)
    for K, m in shapes:
        c1 = 9
        mk = lambda r: jnp.asarray(   # noqa: E731
            rng.integers(0, 1000, (r, c1)).astype(np.int32))
        csu, csv, esu, esv = mk(K), mk(K), mk(m), mk(m)
        cbeta = jnp.asarray(rng.integers(0, c1, K).astype(np.int32))
        cseg = jnp.asarray(rng.integers(0, 8, K).astype(np.int32))
        eseg = jnp.asarray(rng.integers(0, 8, m).astype(np.int32))

        t_ref, _ = timeit(lambda: np.asarray(ops.similarity_mark_ref(
            csu, csv, cbeta, cseg, esu, esv, eseg)), repeat=3)
        rows.append((f"similarity_ref_K{K}_m{m}", t_ref * 1e6,
                     f"pairs={K*m}"))
        t_int, _ = timeit(lambda: np.asarray(ops.similarity_mark(
            csu, csv, cbeta, cseg, esu, esv, eseg, tile_m=2048)), repeat=1)
        rows.append((f"similarity_pallas_interp_K{K}_m{m}", t_int * 1e6,
                     "interpret=True"))

    for n, L in spmv_shapes:
        idx = jnp.asarray(rng.integers(0, n, (n, L)).astype(np.int32))
        val = jnp.asarray(rng.standard_normal((n, L)).astype(np.float32))
        x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        t_ref, _ = timeit(lambda: np.asarray(ops.spmv_ref(idx, val, x)),
                          repeat=3)
        rows.append((f"spmv_ref_n{n}", t_ref * 1e6, f"nnz={n*L}"))
        xb = jnp.asarray(rng.standard_normal((n, 4)).astype(np.float32))
        t_b, _ = timeit(lambda: np.asarray(ops.spmv_batched(
            idx, val, xb)), repeat=1)
        rows.append((f"spmv_batched_interp_n{n}_k4", t_b * 1e6,
                     "interpret=True"))
    return rows


def run_vcycle(quick: bool = False):
    """Fused vs unfused V-cycle application on a mesh2d hierarchy:
    timing rows + an allclose parity check + the byte-model assert."""
    from repro.core import mesh2d
    from repro.launch.roofline import (hierarchy_level_shapes,
                                       hierarchy_level_triples,
                                       vcycle_bytes, vcycle_bytes_fused)
    from repro.pipeline import pdgrass_config
    from repro.solver.device_pcg import make_vcycle
    from repro.solver.hierarchy import build_hierarchy

    side, k = (16, 4) if quick else (40, 8)
    g = mesh2d(side, side, seed=0)
    hier = build_hierarchy(g, config=pdgrass_config(alpha=0.05, chunk=512))
    rng = np.random.default_rng(1)
    r = jnp.asarray(rng.standard_normal((g.n, k)).astype(np.float32))
    r = r - jnp.mean(r, axis=0, keepdims=True)

    degree = 2
    vc_ref = jax.jit(make_vcycle(hier, degree=degree, matvec_impl="ref"))
    vc_fused = jax.jit(make_vcycle(hier, degree=degree,
                                   matvec_impl="fused"))
    z_ref = np.asarray(vc_ref(r))
    z_fused = np.asarray(vc_fused(r))
    assert np.allclose(z_ref, z_fused, atol=1e-5), (
        "fused V-cycle diverged from the unfused composition")

    rows = []
    t_ref, _ = timeit(lambda: np.asarray(vc_ref(r)), repeat=3)
    rows.append((f"vcycle_unfused_n{g.n}_k{k}", t_ref * 1e6,
                 f"degree={degree}"))
    t_fused, _ = timeit(lambda: np.asarray(vc_fused(r)), repeat=3)
    rows.append((f"vcycle_fused_interp_n{g.n}_k{k}", t_fused * 1e6,
                 f"degree={degree}"))

    vc_b = vcycle_bytes(hierarchy_level_shapes(hier), k,
                        cheby_degree=degree)
    vc_fused_b = vcycle_bytes_fused(hierarchy_level_triples(hier), k,
                                    cheby_degree=degree)
    assert vc_fused_b < vc_b, (
        f"fused V-cycle byte model ({vc_fused_b}) not below unfused "
        f"({vc_b})")
    rows.append((f"vcycle_bytes_model_n{g.n}_k{k}", 0.0,
                 f"unfused={vc_b};fused={vc_fused_b};"
                 f"ratio={vc_b / vc_fused_b:.2f}x"))
    models = {"n": g.n, "k": k, "degree": degree,
              "vcycle_bytes": vc_b, "vcycle_bytes_fused": vc_fused_b}
    return rows, models


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write bench-v1 JSON (rows + V-cycle byte models)")
    args = ap.parse_args(argv)
    rows = run(quick=args.quick)
    vc_rows, models = run_vcycle(quick=args.quick)
    rows += vc_rows
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        write_bench_json(
            args.json, "kernels_bench",
            [{"name": n, "us_per_call": us, "derived": d}
             for n, us, d in rows],
            extra={"vcycle_model": models})


if __name__ == "__main__":
    main()
