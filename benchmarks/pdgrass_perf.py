"""§Perf (paper side, measured on CPU): recovery-engine hillclimbing.

Baseline = the paper-faithful sequential greedy (serial oracle).  Each
variant keeps bit-identical output (asserted) while restructuring the
schedule — the table records the hypothesis -> measure loop summarized
in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import timeit
from repro.core import barabasi_albert, mesh2d, prepare
from repro.core.recovery import recover_rounds, recover_serial


def run(quick: bool = False):
    if quick:
        graphs = [("mesh_uniform", mesh2d(14, 14, seed=1)),
                  ("ba_skewed", barabasi_albert(300, 3, seed=2))]
        variants = [(16, 128, "B16_K128_default"),
                    (16, 128, "B16_K128_stop_at_target")]
    else:
        graphs = [("mesh_uniform", mesh2d(60, 60, seed=1)),
                  ("ba_skewed", barabasi_albert(4000, 3, seed=2))]
        variants = [(1, 8, "B1_K8_minimal"),
                    (16, 128, "B16_K128_default"),
                    (64, 512, "B64_K512_wide"),
                    (16, 128, "B16_K128_stop_at_target")]
    rows = []
    for name, g in graphs:
        prep = prepare(g)
        t_serial, ref = timeit(recover_serial, prep.problem, repeat=1)
        rows.append((f"{name}/serial_paper_faithful", t_serial * 1e6, "baseline"))
        for B, K, tag in variants:
            stop = tag.endswith("stop_at_target")

            def go():
                st, stats = recover_rounds(
                    prep.problem, np.int32(int(0.1 * g.n)),
                    block_size=B, max_candidates=K,
                    stop_at_target=stop)
                return np.asarray(st), stats

            t, (st, stats) = timeit(go, repeat=3)
            if not stop:
                assert np.array_equal(st, ref), (name, tag)
            rows.append((f"{name}/rounds_{tag}", t * 1e6,
                         f"rounds={int(stats.rounds)};"
                         f"speedup={t_serial/max(t,1e-9):.1f}x"))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    for name, us, derived in run(quick=args.quick):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
