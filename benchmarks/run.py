"""Benchmark harness entry point — one module per paper table/figure.

  table2_quality  -> Table II  (recovery runtime, passes, PCG iters)
  table3_jbp      -> Table III (Judge-Before-Parallel statistics)
  table4_scaling  -> Table IV / Figs 6-8 (strong scaling, work-span)
  fig1_summary    -> Figure 1  (relative time/quality ratios)
  kernels_bench   -> Pallas kernel shape sweep (interpret mode on CPU)

Prints ``name,us_per_call,derived`` CSV per section; roofline terms for
the (arch x shape) cells come from ``repro.launch.dryrun`` artifacts and
are summarized in EXPERIMENTS.md.
"""
from __future__ import annotations

import time


def main() -> None:
    from benchmarks import (fig1_summary, kernels_bench, table2_quality,
                            table3_jbp, table4_scaling)

    sections = [
        ("table2_quality", table2_quality.main),
        ("table3_jbp", table3_jbp.main),
        ("table4_scaling", table4_scaling.main),
        ("fig1_summary", fig1_summary.main),
        ("kernels_bench", kernels_bench.main),
    ]
    for name, fn in sections:
        print(f"\n=== {name} ===")
        t0 = time.perf_counter()
        fn()
        print(f"# section_runtime,{(time.perf_counter()-t0)*1e6:.0f},{name}")


if __name__ == "__main__":
    main()
