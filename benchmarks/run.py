"""Benchmark harness entry point — one module per paper table/figure.

  table2_quality  -> Table II  (recovery runtime, passes, PCG iters;
                     pdGRASS vs feGRASS through one Pipeline code path)
  table3_jbp      -> Table III (Judge-Before-Parallel statistics)
  table4_scaling  -> Table IV / Figs 6-8 (strong scaling, work-span)
  fig1_summary    -> Figure 1  (relative time/quality ratios)
  pdgrass_perf    -> §Perf     (recovery-engine hillclimbing)
  kernels_bench   -> Pallas kernel shape sweep (interpret mode on CPU)
  solver_bench    -> solver service vs per-call host path
  spectral_bench  -> batched resistance queries + embedding workloads
  analysis       -> static invariant checkers (zero findings asserted)

Prints ``name,us_per_call,derived`` CSV per section; roofline terms for
the (arch x shape) cells come from ``repro.launch.dryrun`` artifacts and
are summarized in EXPERIMENTS.md.

``--smoke`` forwards ``--quick`` to every section: tiny graphs, seconds
per section — CI runs this to catch API drift in code paths the tier-1
tests never import.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# allow `python benchmarks/run.py` without the repo root on PYTHONPATH
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="run every section with --quick on tiny graphs")
    ap.add_argument("--skip", action="append", default=[],
                    help="section name to skip (repeatable) — e.g. CI runs "
                         "solver_bench as its own fail-fast step")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write machine-readable harness results "
                         "(per-section runtimes + embedded solver_bench "
                         "detail, schema bench-v1 with git SHA)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="enable span tracing for the whole run and export "
                         "a Chrome trace-event JSON")
    args = ap.parse_args(argv)

    from benchmarks import (analysis_bench, fig1_summary, kernels_bench,
                            pdgrass_perf, replay_bench, solver_bench,
                            spectral_bench, table2_quality, table3_jbp,
                            table4_scaling)
    from benchmarks.common import write_bench_json

    if args.trace:
        from repro.obs import enable_tracing
        enable_tracing()

    sections = [
        ("table2_quality", table2_quality.main),
        ("table3_jbp", table3_jbp.main),
        ("table4_scaling", table4_scaling.main),
        ("fig1_summary", fig1_summary.main),
        ("pdgrass_perf", pdgrass_perf.main),
        ("kernels_bench", kernels_bench.main),
        ("solver_bench", solver_bench.main),
        ("replay_bench", replay_bench.main),
        ("spectral_bench", spectral_bench.main),
        ("analysis", analysis_bench.main),
    ]
    section_argv = ["--quick"] if args.smoke else []
    solver_json = kernels_json = analysis_json = None
    if args.json:
        # solver_bench / kernels_bench / analysis write their own detail
        # records; embed them in ours
        solver_json = args.json + ".solver_bench.tmp"
        kernels_json = args.json + ".kernels_bench.tmp"
        analysis_json = args.json + ".analysis.tmp"
    section_runtimes = {}
    for name, fn in sections:
        if name in args.skip:
            print(f"\n=== {name} === (skipped)")
            continue
        print(f"\n=== {name} ===")
        extra_argv = []
        if solver_json and name == "solver_bench":
            extra_argv = ["--json", solver_json]
        elif kernels_json and name == "kernels_bench":
            extra_argv = ["--json", kernels_json]
        elif analysis_json and name == "analysis":
            extra_argv = ["--json", analysis_json]
        t0 = time.perf_counter()
        fn(section_argv + extra_argv)
        dt = time.perf_counter() - t0
        section_runtimes[name] = dt
        print(f"# section_runtime,{dt*1e6:.0f},{name}")

    if args.json:
        import json as json_mod

        def _take(tmp_path):
            if tmp_path and os.path.exists(tmp_path):
                with open(tmp_path) as f:
                    detail = json_mod.load(f)
                os.remove(tmp_path)
                return detail
            return None

        write_bench_json(
            args.json, "run",
            {"section_runtimes_s": section_runtimes,
             "skipped": args.skip, "solver_bench": _take(solver_json),
             "kernels_bench": _take(kernels_json),
             "analysis": _take(analysis_json)},
            extra={"smoke": args.smoke})
    if args.trace:
        from repro.obs import get_tracer
        get_tracer().export_chrome(args.trace)
        print(f"wrote {args.trace} "
              f"({len(get_tracer().events())} span events)")


if __name__ == "__main__":
    main()
