"""Benchmark harness entry point — one module per paper table/figure.

  table2_quality  -> Table II  (recovery runtime, passes, PCG iters;
                     pdGRASS vs feGRASS through one Pipeline code path)
  table3_jbp      -> Table III (Judge-Before-Parallel statistics)
  table4_scaling  -> Table IV / Figs 6-8 (strong scaling, work-span)
  fig1_summary    -> Figure 1  (relative time/quality ratios)
  pdgrass_perf    -> §Perf     (recovery-engine hillclimbing)
  kernels_bench   -> Pallas kernel shape sweep (interpret mode on CPU)
  solver_bench    -> solver service vs per-call host path

Prints ``name,us_per_call,derived`` CSV per section; roofline terms for
the (arch x shape) cells come from ``repro.launch.dryrun`` artifacts and
are summarized in EXPERIMENTS.md.

``--smoke`` forwards ``--quick`` to every section: tiny graphs, seconds
per section — CI runs this to catch API drift in code paths the tier-1
tests never import.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# allow `python benchmarks/run.py` without the repo root on PYTHONPATH
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="run every section with --quick on tiny graphs")
    ap.add_argument("--skip", action="append", default=[],
                    help="section name to skip (repeatable) — e.g. CI runs "
                         "solver_bench as its own fail-fast step")
    args = ap.parse_args(argv)

    from benchmarks import (fig1_summary, kernels_bench, pdgrass_perf,
                            solver_bench, table2_quality, table3_jbp,
                            table4_scaling)

    sections = [
        ("table2_quality", table2_quality.main),
        ("table3_jbp", table3_jbp.main),
        ("table4_scaling", table4_scaling.main),
        ("fig1_summary", fig1_summary.main),
        ("pdgrass_perf", pdgrass_perf.main),
        ("kernels_bench", kernels_bench.main),
        ("solver_bench", solver_bench.main),
    ]
    section_argv = ["--quick"] if args.smoke else []
    for name, fn in sections:
        if name in args.skip:
            print(f"\n=== {name} === (skipped)")
            continue
        print(f"\n=== {name} ===")
        t0 = time.perf_counter()
        fn(section_argv)
        print(f"# section_runtime,{(time.perf_counter()-t0)*1e6:.0f},{name}")


if __name__ == "__main__":
    main()
