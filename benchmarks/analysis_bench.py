"""Static-analysis section for the bench harness.

Runs the :mod:`repro.analysis` checkers and reports per-checker runtime
and finding counts as ordinary bench rows, so analyzer cost and tree
cleanliness ride in the same bench-v1 artifact as every other section
(``--json`` embeds the findings + ruleset exactly like ``kernels_bench``
embeds its byte models).  A non-empty finding set is a FAILURE — the
harness is a second enforcement point beside the CI ``static-analysis``
job.

``--quick`` (the harness ``--smoke``) runs only the AST checkers; the
jaxpr/vmem checkers trace real entry points and build suite hierarchies
(~a minute on CPU interpret mode), which the dedicated CI job already
covers.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

from benchmarks.common import write_bench_json


def run(quick: bool = False):
    from repro.analysis import run_checks

    checks = ["trace", "locks"] if quick else ["all"]
    rows = []
    per_check = {}
    for check in (checks if checks != ["all"]
                  else ["jaxpr", "trace", "locks", "vmem"]):
        t0 = time.perf_counter()
        findings = run_checks([check])[check]
        dt = time.perf_counter() - t0
        per_check[check] = findings
        rows.append((f"analysis_{check}", dt * 1e6,
                     f"findings={len(findings)}"))
    return rows, per_check


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="AST checkers only (trace + locks)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write bench-v1 JSON (rows + findings + ruleset)")
    args = ap.parse_args(argv)

    rows, per_check = run(quick=args.quick)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    flat = [f for fs in per_check.values() for f in fs]
    for f in flat:
        print(f.format())

    if args.json:
        from repro.analysis.findings import RULES
        write_bench_json(
            args.json, "analysis_bench",
            [{"name": n, "us_per_call": us, "derived": d}
             for n, us, d in rows],
            extra={"analysis": {
                "checks_run": sorted(per_check),
                "ruleset": [dataclasses.asdict(r) for r in RULES],
                "findings": [f.as_dict() for f in flat],
                "finding_count": len(flat),
            }})

    assert not flat, (
        f"{len(flat)} static-analysis finding(s) on the tree — "
        f"see rows above; fix or add a reasoned "
        f"'# analysis: allow(<rule>)' pragma")


if __name__ == "__main__":
    main()
