"""Shared benchmark utilities."""
from __future__ import annotations

import json
import os
import subprocess
import time

import numpy as np

BENCH_SCHEMA = "bench-v1"


def timeit(fn, *args, repeat: int = 3, **kw):
    """Paper protocol: minimum runtime over repeats (Table II uses min of 5)."""
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best, out


def csv_row(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")


def git_sha() -> str:
    """HEAD commit of the repo this file lives in; "unknown" outside git."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)), timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def _jsonable(v):
    """numpy scalars/arrays -> plain python; last-resort repr for the rest."""
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, np.generic):
        return v.item()
    return repr(v)


def write_bench_json(path: str, bench: str, records, extra: dict = None):
    """Machine-readable bench results: ``{schema, bench, git_sha,
    created_unix, records}``.  ``records`` is whatever row structure the
    bench produced (lists/dicts of numbers); numpy values serialize as
    plain JSON numbers/lists."""
    doc = {
        "schema": BENCH_SCHEMA,
        "bench": bench,
        "git_sha": git_sha(),
        "created_unix": time.time(),
        "records": records,
    }
    if extra:
        doc.update(extra)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, default=_jsonable)
    print(f"wrote {path}")
