"""Shared benchmark utilities."""
from __future__ import annotations

import time

import numpy as np


def timeit(fn, *args, repeat: int = 3, **kw):
    """Paper protocol: minimum runtime over repeats (Table II uses min of 5)."""
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best, out


def csv_row(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
