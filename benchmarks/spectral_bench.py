"""Spectral-services benchmark: batched resistance queries + embeddings.

Two questions, each with a hard assertion CI can trip on:

  * **Batching wins.**  ``q`` effective-resistance queries submitted
    one-by-one pay ``q`` flushes of one ±e_uv column each; the batched
    endpoint stacks them into chunked ``[n, chunk]`` RHS blocks that land
    in a **single flush group** per (graph, config) — asserted via the
    scheduler's group counter, not inferred from timings.  A third row
    replays the batch against the result cache (zero solves).
  * **The embedding workload ranks sparsifiers.**  Fiedler/k=2 embeddings
    run the same block inverse iteration under the pdGRASS and feGRASS
    preconditioner configs through one service — iteration counts (outer
    and summed PCG) become a downstream-task quality comparison, the
    SF-GRASS framing.

    PYTHONPATH=src python benchmarks/spectral_bench.py [--quick]
        [--json out.json] [--trace trace.json]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.common import write_bench_json  # noqa: E402

from repro.core import barabasi_albert, grid2d, mesh2d  # noqa: E402
from repro.pipeline import fegrass_config, pdgrass_config  # noqa: E402
from repro.solver import SolverService  # noqa: E402
from repro.spectral import (ResistanceCache,  # noqa: E402
                            effective_resistance, spectral_embedding)


def sample_pairs(n: int, q: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, 2 * q)
    v = rng.integers(0, n, 2 * q)
    keep = u != v
    pairs = np.unique(np.stack([np.minimum(u[keep], v[keep]),
                                np.maximum(u[keep], v[keep])], axis=1),
                      axis=0)
    rng.shuffle(pairs)
    return pairs[:q]


def bench_resistance(name, g, q=256, chunk=128, tol=1e-6):
    """One-by-one vs batched vs cache-replay resistance queries."""
    svc = SolverService(pipeline=pdgrass_config(alpha=0.05, chunk=512))
    handle = svc.register(g)
    pairs = sample_pairs(g.n, q)
    q = pairs.shape[0]

    # Warm the artifact cache AND the jit closures for both RHS widths
    # (k=1 for the serial mode, the chunked widths for the batched mode)
    # so every mode times steady-state serving, not compilation.
    effective_resistance(svc, handle, pairs, tol=tol, chunk=chunk,
                         cache=ResistanceCache())
    effective_resistance(svc, handle, pairs[:1], tol=tol,
                         cache=ResistanceCache())

    t0 = time.perf_counter()
    serial_cache = ResistanceCache()
    r_serial = np.concatenate([
        effective_resistance(svc, handle, p.reshape(1, 2), tol=tol,
                             cache=serial_cache)
        for p in pairs])
    t_serial = time.perf_counter() - t0

    groups_before = svc.stats()["scheduler"]["groups"]
    batch_cache = ResistanceCache()
    t0 = time.perf_counter()
    r_batch = effective_resistance(svc, handle, pairs, tol=tol, chunk=chunk,
                                   cache=batch_cache)
    t_batch = time.perf_counter() - t0
    groups = svc.stats()["scheduler"]["groups"] - groups_before
    assert groups == 1, (
        f"{name}: batched queries split into {groups} flush groups — the "
        f"endpoint must submit every chunk before resolving the first so "
        f"one (graph, config) flush group serves the whole call")
    np.testing.assert_allclose(r_batch, r_serial, rtol=1e-4, atol=1e-9,
                               err_msg=f"{name}: batched resistances drifted "
                                       f"from the one-by-one path")

    t0 = time.perf_counter()
    r_replay = effective_resistance(svc, handle, pairs, tol=tol, chunk=chunk,
                                    cache=batch_cache)
    t_replay = time.perf_counter() - t0
    assert batch_cache.hits >= q and np.array_equal(r_batch, r_replay)

    speedup = t_serial / max(t_batch, 1e-9)
    assert speedup > 1, (
        f"{name}: batched queries ({t_batch*1e3:.1f} ms) did not beat "
        f"one-by-one submission ({t_serial*1e3:.1f} ms) with warm caches")
    print(f"  resistance q={q}: serial={t_serial*1e3:8.1f} ms  "
          f"batched={t_batch*1e3:8.1f} ms ({speedup:6.1f}x, "
          f"{groups} flush group)  cache_replay={t_replay*1e3:6.2f} ms")
    return {"q": q, "chunk": chunk, "serial_ms": t_serial * 1e3,
            "batched_ms": t_batch * 1e3, "speedup": speedup,
            "flush_groups": groups, "replay_ms": t_replay * 1e3,
            "cache": batch_cache.stats}


def bench_embedding(name, g, k=2, tol=1e-3):
    """Embedding iteration counts under pd vs fe preconditioner configs."""
    svc = SolverService(pipeline=pdgrass_config(alpha=0.05, chunk=512))
    handle = svc.register(g)
    out = {}
    for tag, cfg in [("pd", None),
                     ("fe", fegrass_config(alpha=0.05, chunk=512))]:
        t0 = time.perf_counter()
        emb = spectral_embedding(svc, handle, k=k, tol=tol, pipeline=cfg)
        dt = time.perf_counter() - t0
        assert emb.converged, (
            f"{name}/{tag}: embedding did not reach tol={tol} "
            f"(residuals {emb.residuals})")
        out[tag] = {"outer_iters": emb.iterations,
                    "solve_iters": emb.solve_iters,
                    "lambda2": float(emb.values[0]),
                    "max_residual": float(emb.residuals.max()),
                    "wall_ms": dt * 1e3}
        print(f"  embedding[{tag}] k={k}: outer={emb.iterations:<3d} "
              f"pcg_iters={emb.solve_iters:<6d} lam2={emb.values[0]:.4f} "
              f"resid={emb.residuals.max():.1e}  ({dt*1e3:.0f} ms)")
    # same operator, same start block — lambda2 must agree across configs
    d_lam = abs(out["pd"]["lambda2"] - out["fe"]["lambda2"])
    assert d_lam <= max(1e-6, 1e-3 * abs(out["pd"]["lambda2"])), (
        f"{name}: lambda2 drifted between preconditioner configs "
        f"({out['pd']['lambda2']} vs {out['fe']['lambda2']})")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny graphs, few queries — smoke-test the path")
    ap.add_argument("--q", type=int, default=None,
                    help="resistance query count per graph")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write machine-readable results (schema bench-v1)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="enable span tracing and export a Chrome trace")
    args = ap.parse_args(argv)

    if args.trace:
        from repro.obs import enable_tracing
        enable_tracing()

    if args.quick:
        graphs = {"grid-16x16": grid2d(16, 16, seed=0)}
        q, chunk, k = args.q or 48, 32, 2
    else:
        graphs = {
            "mesh2d-40x40": mesh2d(40, 40, seed=0),
            "ba-2000": barabasi_albert(2000, 3, seed=1),
        }
        q, chunk, k = args.q or 256, 128, 2

    records = []
    for name, g in graphs.items():
        print(f"\n{name}: |V|={g.n} |E|={g.m}")
        rec = {"graph": name, "n": g.n, "m": g.m,
               "resistance": bench_resistance(name, g, q=q, chunk=chunk),
               "embedding": bench_embedding(name, g, k=k)}
        records.append(rec)

    speedups = [r["resistance"]["speedup"] for r in records]
    print(f"\nbatched resistance queries beat one-by-one submission on "
          f"every graph ({', '.join(f'{s:.1f}x' for s in speedups)}), "
          f"each through a single flush group")
    if args.json:
        write_bench_json(args.json, "spectral_bench", records,
                         extra={"quick": args.quick, "q": q})
    if args.trace:
        from repro.obs import get_tracer
        get_tracer().export_chrome(args.trace)
        print(f"wrote {args.trace} "
              f"({len(get_tracer().events())} span events)")


if __name__ == "__main__":
    main()
