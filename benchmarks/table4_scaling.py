"""Table IV / Figs 6-8 analog: parallel scaling of pdGRASS recovery.

This container exposes ONE physical core, so OpenMP-style thread scaling
cannot be measured directly.  We report what the work-span framework
gives us (the paper's own analysis model, Section II.D):

  * measured work: serial-engine wall time (numpy oracle),
  * measured vectorized time: the JAX round engine (the "infinite-width
    SIMD" point of the design),
  * per-subtask work distribution -> predicted strong scaling
    T_p = max(outer LPT makespan over p workers, largest inner task / p)
    for the paper's thread counts (1/8/32), on both a uniform input
    (mesh ~ M6) and a skewed one (star/BA ~ com-Youtube).
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import timeit
from repro.core import barabasi_albert, mesh2d, prepare, star_hub
from repro.core.distributed import partition_subtasks
from repro.core.recovery import recover_rounds, recover_serial


def predicted_speedup(sizes: np.ndarray, p: int, cutoff=None) -> float:
    """LPT outer + inner-parallel giants (work ~ |S|^2 pessimistic bound)."""
    work = (sizes.astype(np.float64) ** 2)
    total = work.sum()
    if total == 0:
        return 1.0
    shard_of, giants, _ = partition_subtasks(sizes, p, cutoff=cutoff)
    load = np.zeros(p)
    for sid, sh in enumerate(shard_of):
        if sh >= 0:
            load[sh] += work[sid]
    inner = sum(work[g] / p for g in giants)  # giants split across workers
    t_p = load.max() + inner
    return float(total / max(t_p, 1e-9))


def run(quick: bool = False):
    if quick:
        graphs = [("uniform_mesh", mesh2d(14, 14, seed=1)),
                  ("skewed_ba", barabasi_albert(300, 4, seed=2))]
    else:
        graphs = [("uniform_mesh", mesh2d(70, 70, seed=1)),
                  ("skewed_ba", barabasi_albert(5000, 4, seed=2)),
                  ("skewed_star", star_hub(3000, extra=2500, seed=3))]
    rows = []
    for name, g in graphs:
        prep = prepare(g)
        t_serial, _ = timeit(recover_serial, prep.problem, repeat=1)
        t_vec, _ = timeit(
            lambda: recover_rounds(prep.problem, block_size=16,
                                   max_candidates=128,
                                   stop_at_target=False)[0].block_until_ready(),
            repeat=3)
        sizes = prep.subtask_sizes
        rows.append({
            "graph": name, "n_subtasks": len(sizes),
            "max_subtask_pct": round(100 * sizes.max() / sizes.sum(), 1),
            "T_serial_ms": round(t_serial * 1e3, 1),
            "T_vectorized_ms": round(t_vec * 1e3, 1),
            "vec_speedup": round(t_serial / max(t_vec, 1e-9), 1),
            "pred_speedup_p8": round(predicted_speedup(sizes, 8), 1),
            "pred_speedup_p32": round(predicted_speedup(sizes, 32), 1),
        })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    rows = run(quick=args.quick)
    keys = list(rows[0].keys())
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r[k]) for k in keys))


if __name__ == "__main__":
    main()
