"""Table II analog: recovery runtime + passes + PCG iteration counts.

feGRASS (loose similarity, multi-pass) vs pdGRASS (strict similarity,
single pass, JAX round engine) across the synthetic suite at alpha in
{0.02, 0.05, 0.10} — both run through the unified ``repro.pipeline``
harness, so the entire comparison is a recovery-stage config diff (printed
in the header).  SuiteSparse graphs are not available offline; the suite
spans the same structural families (grids/meshes ~ census+FEM rows,
BA/star ~ com-* hub rows, WS/regular ~ collaboration rows).

Beyond the paper's PCG-iteration metric, ``--quality`` rows judge each
sparsifier on the *downstream tasks* the sparsifier exists for (host f64
oracles, deterministic):

  * ``er_fe``/``er_pd``     — effective-resistance distortion: median
    relative error of ``R_P(u, v)`` on the sparsifier vs the exact
    ``R_G(u, v)`` (grounded sparse-LU solves — the dense-pinv oracle
    without the dense cost).
  * ``fied_fe``/``fied_pd`` — Fiedler fidelity: the sparsifier's Fiedler
    vector scored by its Rayleigh quotient on ``L_G``, as relative excess
    over the true lambda2 (0 = perfect spectral agreement).
  * ``itp_fe``/``itp_pd``   — harmonic interpolation error: label scores
    propagated on the sparsifier vs on ``G`` (mean abs deviation on the
    held-out vertices).

Score-stage calibration columns (the ``er_exact`` ground truth closes the
PR 2 ER-sampling item): ``iter_erx`` is the PCG iteration count with the
exact-leverage-score ranking, and ``ers_mean``/``ers_std`` are the seed
variance band of the stochastic ``er_sample`` ranking beside the
deterministic ``w_times_r`` column.

    PYTHONPATH=src python benchmarks/table2_quality.py [--quick]
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import timeit
from repro.core import quality_iters, suite
from repro.core.pcg import pcg_host
from repro.pipeline import Pipeline, config_diff, fegrass_config, pdgrass_config


# ---------------------------------------------------------------------------
# Host f64 downstream-task oracles (grounded sparse LU; no dense pinv)
# ---------------------------------------------------------------------------

def _grounded_lu(L):
    """Sparse LU of ``L`` with vertex 0 grounded — solving the grounded
    system and re-centering applies ``L^+`` exactly on ``range(L)``."""
    from scipy.sparse.linalg import splu

    A = L.tocsc()[1:, :][:, 1:]
    return splu(A)


def _lsolve(lu, b):
    """``L^+ b`` for mean-zero ``b`` ([n] or [n, q]) via the grounded LU."""
    x = np.zeros_like(b)
    x[1:] = lu.solve(b[1:])
    return x - x.mean(axis=0)


def _resistances(lu, n, pairs):
    """Exact ``R(u, v) = x_u - x_v`` with ``L x = e_u - e_v``, batched."""
    q = len(pairs)
    B = np.zeros((n, q))
    B[pairs[:, 0], np.arange(q)] = 1.0
    B[pairs[:, 1], np.arange(q)] -= 1.0
    X = _lsolve(lu, B)
    return X[pairs[:, 0], np.arange(q)] - X[pairs[:, 1], np.arange(q)]


def _fiedler_pair(L, n, iters=60, seed=0):
    """(lambda2, v2) of Laplacian ``L`` by deflated inverse iteration."""
    lu = _grounded_lu(L)
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n)
    x -= x.mean()
    x /= np.linalg.norm(x)
    for _ in range(iters):
        x = _lsolve(lu, x)
        x /= np.linalg.norm(x)
    lam = float(x @ (L @ x))
    return lam, x


def _harmonic(L, bmask, xb):
    """Dirichlet solve ``L_II x_I = -L_IB x_B`` via sparse LU (f64)."""
    from scipy.sparse.linalg import splu

    Lc = L.tocsc()
    I = np.flatnonzero(~bmask)
    B = np.flatnonzero(bmask)
    x = np.zeros(L.shape[0])
    x[B] = xb
    LII = Lc[I, :][:, I]
    LIB = Lc[I, :][:, B]
    x[I] = splu(LII).solve(-LIB @ xb)
    return x


def downstream_quality(g, spars, pairs, lam_g, v_g, lab_idx):
    """The three task-level quality numbers of one sparsifier vs ``G``."""
    L_g = g.laplacian()
    L_p = spars.laplacian()

    r_g = _resistances(_grounded_lu(L_g), g.n, pairs)
    r_p = _resistances(_grounded_lu(L_p), g.n, pairs)
    er = float(np.median(np.abs(r_p - r_g) / np.maximum(r_g, 1e-30)))

    _, v_p = _fiedler_pair(L_p, g.n)
    rayleigh = float(v_p @ (L_g @ v_p))
    fied = max(rayleigh - lam_g, 0.0) / lam_g

    bmask = np.zeros(g.n, dtype=bool)
    bmask[lab_idx] = True
    xb = np.sign(v_g[lab_idx])
    x_g = _harmonic(L_g, bmask, xb)
    x_p = _harmonic(L_p, bmask, xb)
    itp = float(np.mean(np.abs(x_p - x_g)[~bmask]))
    return er, fied, itp


def run(scale: str = "small", alphas=(0.02, 0.05, 0.10), quality: bool = True,
        er_seeds=(0, 1, 2), n_pairs: int = 16):
    rows = []
    for gname, g in suite(scale).items():
        # Shared steps 1-3: same tree + score stages for both configs (the
        # paper's apples-to-apples protocol), prepared once per graph.
        prep = Pipeline(pdgrass_config()).prepare(g)
        base_iters = lam_g = v_g = pairs = lab_idx = None
        if quality:
            rng = np.random.default_rng(0)
            b = rng.standard_normal(g.n)
            b -= b.mean()
            base_iters = pcg_host(g.laplacian(), b).iters
            u = rng.integers(0, g.n, 4 * n_pairs)
            v = rng.integers(0, g.n, 4 * n_pairs)
            keep = u != v
            pairs = np.stack([u[keep], v[keep]], axis=1)[:n_pairs]
            lam_g, v_g = _fiedler_pair(g.laplacian(), g.n)
            lab_idx = rng.choice(g.n, size=max(g.n // 10, 2), replace=False)
        for alpha in alphas:
            fe_pipe = Pipeline(fegrass_config(alpha=alpha))
            pd_pipe = Pipeline(pdgrass_config(alpha=alpha))
            t_fe, fe = timeit(fe_pipe.run, g, prepared=prep, repeat=1)
            t_pd, pd = timeit(pd_pipe.run, g, prepared=prep, repeat=3)
            row = {
                "graph": gname, "n": g.n, "m": g.m, "alpha": alpha,
                "T_fe_ms": round(t_fe * 1e3, 2),
                "passes_fe": fe.stats["passes"],
                "T_pd_ms": round(t_pd * 1e3, 2),
                "rounds_pd": pd.stats["rounds"],
                "rec_fe": fe.stats["n_recovered"],
                "rec_pd": pd.stats["n_recovered"],
            }
            if quality:
                row["iter_none"] = base_iters
                row["iter_fe"] = quality_iters(g, fe)
                row["iter_pd"] = quality_iters(g, pd)
                row["iter_ratio"] = round(row["iter_fe"] /
                                          max(row["iter_pd"], 1), 2)
                # Score-stage calibration: exact leverage scores (ground
                # truth) and the er_sample seed variance band around them.
                erx = Pipeline(pdgrass_config(
                    alpha=alpha, score_mode="er_exact")).run(g)
                row["iter_erx"] = quality_iters(g, erx)
                ers = [quality_iters(g, Pipeline(pdgrass_config(
                    alpha=alpha, score_mode="er_sample", seed=s)).run(g))
                    for s in er_seeds]
                row["ers_mean"] = round(float(np.mean(ers)), 1)
                row["ers_std"] = round(float(np.std(ers)), 1)
                # Downstream-task quality: the sparsifier judged on the
                # tasks (resistance, Fiedler, interpolation), not PCG alone.
                for tag, sp in (("fe", fe), ("pd", pd)):
                    er, fied, itp = downstream_quality(
                        g, sp, pairs, lam_g, v_g, lab_idx)
                    row[f"er_{tag}"] = round(er, 4)
                    row[f"fied_{tag}"] = round(fied, 4)
                    row[f"itp_{tag}"] = round(itp, 4)
            rows.append(row)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny graphs, one alpha — smoke-test the code path")
    ap.add_argument("--scale", default=None, choices=["tiny", "small"])
    ap.add_argument("--seeds", type=int, default=None,
                    help="er_sample variance-band seed count")
    args = ap.parse_args(argv)
    scale = args.scale or ("tiny" if args.quick else "small")
    alphas = (0.05,) if args.quick else (0.02, 0.05, 0.10)
    n_seeds = args.seeds or (3 if args.quick else 5)

    diff = config_diff(pdgrass_config(), fegrass_config())
    print(f"# pdGRASS vs feGRASS config diff: {diff}")
    rows = run(scale=scale, alphas=alphas, er_seeds=tuple(range(n_seeds)))
    keys = list(rows[0].keys())
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r[k]) for k in keys))


if __name__ == "__main__":
    main()
