"""Table II analog: recovery runtime + passes + PCG iteration counts.

feGRASS (loose similarity, multi-pass, serial reference) vs pdGRASS
(strict similarity, single pass, JAX round engine) across the synthetic
suite at alpha in {0.02, 0.05, 0.10}.  SuiteSparse graphs are not
available offline; the suite spans the same structural families
(grids/meshes ~ census+FEM rows, BA/star ~ com-* hub rows, WS/regular ~
collaboration rows).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import timeit
from repro.core import fegrass, pdgrass, prepare, quality_iters, suite
from repro.core.pcg import pcg_host


def run(scale: str = "small", alphas=(0.02, 0.05, 0.10), quality: bool = True):
    rows = []
    for gname, g in suite(scale).items():
        prep = prepare(g)   # shared step 1-3 (same tree for both, like paper)
        base_iters = None
        if quality:
            rng = np.random.default_rng(0)
            b = rng.standard_normal(g.n)
            b -= b.mean()
            base_iters = pcg_host(g.laplacian(), b).iters
        for alpha in alphas:
            t_fe, fe = timeit(fegrass, g, alpha, prepared=prep, repeat=1)
            t_pd, pd = timeit(
                pdgrass, g, alpha, prepared=prep, engine="rounds", repeat=3)
            row = {
                "graph": gname, "n": g.n, "m": g.m, "alpha": alpha,
                "T_fe_ms": round(t_fe * 1e3, 2),
                "passes_fe": fe.stats["passes"],
                "T_pd_ms": round(t_pd * 1e3, 2),
                "rounds_pd": pd.stats["rounds"],
                "rec_fe": fe.stats["n_recovered"],
                "rec_pd": pd.stats["n_recovered"],
            }
            if quality:
                row["iter_none"] = base_iters
                row["iter_fe"] = quality_iters(g, fe)
                row["iter_pd"] = quality_iters(g, pd)
                row["iter_ratio"] = round(row["iter_fe"] /
                                          max(row["iter_pd"], 1), 2)
            rows.append(row)
    return rows


def main():
    rows = run()
    keys = list(rows[0].keys())
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r[k]) for k in keys))


if __name__ == "__main__":
    main()
