"""Table II analog: recovery runtime + passes + PCG iteration counts.

feGRASS (loose similarity, multi-pass) vs pdGRASS (strict similarity,
single pass, JAX round engine) across the synthetic suite at alpha in
{0.02, 0.05, 0.10} — both run through the unified ``repro.pipeline``
harness, so the entire comparison is a recovery-stage config diff (printed
in the header).  SuiteSparse graphs are not available offline; the suite
spans the same structural families (grids/meshes ~ census+FEM rows,
BA/star ~ com-* hub rows, WS/regular ~ collaboration rows).

    PYTHONPATH=src python benchmarks/table2_quality.py [--quick]
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import timeit
from repro.core import quality_iters, suite
from repro.core.pcg import pcg_host
from repro.pipeline import Pipeline, config_diff, fegrass_config, pdgrass_config


def run(scale: str = "small", alphas=(0.02, 0.05, 0.10), quality: bool = True):
    rows = []
    for gname, g in suite(scale).items():
        # Shared steps 1-3: same tree + score stages for both configs (the
        # paper's apples-to-apples protocol), prepared once per graph.
        prep = Pipeline(pdgrass_config()).prepare(g)
        base_iters = None
        if quality:
            rng = np.random.default_rng(0)
            b = rng.standard_normal(g.n)
            b -= b.mean()
            base_iters = pcg_host(g.laplacian(), b).iters
        for alpha in alphas:
            fe_pipe = Pipeline(fegrass_config(alpha=alpha))
            pd_pipe = Pipeline(pdgrass_config(alpha=alpha))
            t_fe, fe = timeit(fe_pipe.run, g, prepared=prep, repeat=1)
            t_pd, pd = timeit(pd_pipe.run, g, prepared=prep, repeat=3)
            row = {
                "graph": gname, "n": g.n, "m": g.m, "alpha": alpha,
                "T_fe_ms": round(t_fe * 1e3, 2),
                "passes_fe": fe.stats["passes"],
                "T_pd_ms": round(t_pd * 1e3, 2),
                "rounds_pd": pd.stats["rounds"],
                "rec_fe": fe.stats["n_recovered"],
                "rec_pd": pd.stats["n_recovered"],
            }
            if quality:
                row["iter_none"] = base_iters
                row["iter_fe"] = quality_iters(g, fe)
                row["iter_pd"] = quality_iters(g, pd)
                row["iter_ratio"] = round(row["iter_fe"] /
                                          max(row["iter_pd"], 1), 2)
            rows.append(row)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny graphs, one alpha — smoke-test the code path")
    ap.add_argument("--scale", default=None, choices=["tiny", "small"])
    args = ap.parse_args(argv)
    scale = args.scale or ("tiny" if args.quick else "small")
    alphas = (0.05,) if args.quick else (0.02, 0.05, 0.10)

    diff = config_diff(pdgrass_config(), fegrass_config())
    print(f"# pdGRASS vs feGRASS config diff: {diff}")
    rows = run(scale=scale, alphas=alphas)
    keys = list(rows[0].keys())
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r[k]) for k in keys))


if __name__ == "__main__":
    main()
