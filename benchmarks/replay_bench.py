"""Traffic-replay benchmark: async daemon vs sync-flush under offered load.

Open-loop arrivals (seeded deterministic schedule — no wall-clock
randomness in the workload) are replayed against the same
``SolverService`` two ways at each load point:

  * ``sync``   — the pre-daemon discipline: every arrival submits and
    immediately flushes on the caller's thread (one request per flush).
  * ``daemon`` — :class:`~repro.serve.solver_daemon.SolverDaemon` with
    deadline batching (``max_batch_delay_ms``): arrivals queue, the
    background flusher drains them in batches, tickets resolve via their
    per-ticket events — no ``flush()`` anywhere.

Reported per (mode, load point): p50/p90/p99 end-to-end latency (scheduled
arrival -> resolution, the open-loop convention) and throughput.  At
saturation the daemon must match or beat the sync baseline's throughput —
batching k columns into one device solve is the whole point — and the
bench asserts exactly that.

    PYTHONPATH=src python benchmarks/replay_bench.py [--rates 50 400]
    PYTHONPATH=src python benchmarks/replay_bench.py --quick \\
        --json bench_replay.json --trace trace_replay.json
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.common import write_bench_json  # noqa: E402

from repro.core.graph import mesh2d  # noqa: E402
from repro.serve import (SolverDaemon, TenantConfig,  # noqa: E402
                         make_schedule, replay_daemon, replay_sync)
from repro.solver import SolverService  # noqa: E402

TENANTS = (("paid", 3.0), ("free", 1.0))


def run_load_point(svc, handle, rate_hz, n_requests, delay_ms, seed):
    """One offered-load point: sync baseline, then the daemon, over the
    *same* deterministic schedule."""
    schedule = make_schedule(n_requests, rate_hz, seed=seed, tenants=TENANTS)
    sync_rep = replay_sync(svc, handle, schedule)
    daemon = SolverDaemon(
        svc, max_batch_delay_ms=delay_ms,
        tenants={"paid": TenantConfig(weight=3.0),
                 "free": TenantConfig(weight=1.0)})
    try:
        daemon_rep = replay_daemon(daemon, handle, schedule)
        dstats = daemon.stats()
    finally:
        daemon.close()
    for rep in (sync_rep, daemon_rep):
        assert rep.errors == 0, f"{rep.mode}: {rep.errors} failed requests"
        assert rep.latencies_ms, f"{rep.mode}: no latency samples"
        assert rep.p99_ms >= rep.p50_ms > 0, (
            f"{rep.mode}: degenerate percentiles "
            f"p50={rep.p50_ms} p99={rep.p99_ms}")
    rec = {
        "rate_hz": rate_hz,
        "n_requests": n_requests,
        "max_batch_delay_ms": delay_ms,
        "sync": sync_rep.to_record(),
        "daemon": daemon_rep.to_record(),
        "daemon_cycles": dstats["daemon"]["cycles"],
        "daemon_triggers": dstats["daemon"]["triggers"],
        "slo_violations": dstats["daemon"]["slo_violations"],
    }
    print(f"  rate={rate_hz:7.1f} rps  "
          f"sync:   p50={sync_rep.p50_ms:8.2f} ms  "
          f"p99={sync_rep.p99_ms:8.2f} ms  "
          f"tput={sync_rep.throughput_rps:7.1f} rps")
    print(f"  {'':>18s}daemon: p50={daemon_rep.p50_ms:8.2f} ms  "
          f"p99={daemon_rep.p99_ms:8.2f} ms  "
          f"tput={daemon_rep.throughput_rps:7.1f} rps  "
          f"cycles={dstats['daemon']['cycles']}  "
          f"slo_viol={dstats['daemon']['slo_violations']}")
    return rec, sync_rep, daemon_rep


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=96,
                    help="requests per load point")
    ap.add_argument("--rates", type=float, nargs="+", default=[50.0, 800.0],
                    help="offered loads (requests/s); the last one must "
                         "genuinely saturate the sync baseline (offered >> "
                         "1/solve-latency), or the throughput comparison "
                         "degenerates to timer noise")
    ap.add_argument("--delay-ms", type=float, default=20.0,
                    help="daemon max_batch_delay_ms (the SLO knob)")
    ap.add_argument("--mesh", type=int, default=24,
                    help="mesh2d side length (n = side^2 vertices)")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--quick", action="store_true",
                    help="tiny graph, short schedules — CI smoke")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write machine-readable results (schema bench-v1)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="trace the whole run and export Chrome trace JSON")
    args = ap.parse_args(argv)

    if args.trace:
        from repro.obs import enable_tracing
        enable_tracing()

    if args.quick:
        args.n, args.mesh = 32, 12
        # 2000 rps offered vs a sync path that needs one device round-trip
        # per request (~2 ms warm => <500 rps capacity): saturation holds
        # even on fast machines, so daemon-vs-sync throughput is a real
        # batching comparison, not a tie at the offered rate.
        args.rates = args.rates if args.rates != [50.0, 800.0] \
            else [40.0, 2000.0]

    if len(args.rates) < 2:
        ap.error("--rates wants at least two load points (low, saturation)")

    g = mesh2d(args.mesh, args.mesh, seed=0)
    svc = SolverService(alpha=0.1)
    handle = svc.register(g)
    # Prepay artifact build + jit compiles for every pow2 RHS bucket the
    # replay can produce (sync = 1 column; daemon batches up to n), so the
    # comparison measures serving, not first-flush compilation.
    widths, w = [], 1
    while w <= max(args.n, 1):
        widths.append(w)
        w *= 2
    svc.warmup(handle, widths=widths)

    print(f"replay: mesh2d-{args.mesh}x{args.mesh} |V|={g.n} |E|={g.m}  "
          f"n={args.n}/point  delay={args.delay_ms} ms  "
          f"tenants={[t for t, _ in TENANTS]}")
    records = []
    last = None
    for i, rate in enumerate(args.rates):
        rec, sync_rep, daemon_rep = run_load_point(
            svc, handle, rate, args.n, args.delay_ms,
            seed=args.seed + i)
        records.append(rec)
        last = (sync_rep, daemon_rep)

    sync_rep, daemon_rep = last    # the highest offered load = saturation
    assert daemon_rep.throughput_rps >= sync_rep.throughput_rps, (
        f"daemon throughput {daemon_rep.throughput_rps:.1f} rps fell below "
        f"the sync-flush baseline {sync_rep.throughput_rps:.1f} rps at "
        f"saturation — batching should never lose to one-flush-per-request")
    print(f"saturation check: daemon {daemon_rep.throughput_rps:.1f} rps "
          f">= sync {sync_rep.throughput_rps:.1f} rps")

    if args.json:
        write_bench_json(args.json, "replay_bench", records, extra={
            "graph": f"mesh2d-{args.mesh}x{args.mesh}",
            "n_vertices": g.n, "n_edges": g.m,
            "tenants": dict(TENANTS),
            "max_batch_delay_ms": args.delay_ms,
        })
    if args.trace:
        from repro.obs import get_tracer
        get_tracer().export_chrome(args.trace)
        print(f"wrote {args.trace}")


if __name__ == "__main__":
    main()
