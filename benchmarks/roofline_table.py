"""Render the dry-run JSON artifacts into the EXPERIMENTS.md roofline table,
plus a measured roofline for the solver hot loop (ELL spmv + V-cycle).

The dry-run tables come from compiled-HLO cost analysis (see
``repro.launch.dryrun``); the solver table instead crosses the analytic
byte/flop models in :mod:`repro.launch.roofline` with *measured* span
timings from the telemetry plane (``solver.solve`` spans), reporting
achieved bytes/s as a fraction of the HBM roof.

    PYTHONPATH=src python benchmarks/roofline_table.py [--quick]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))


def load(out_dir="experiments"):
    rows = {}
    for f in glob.glob(os.path.join(out_dir, "dryrun_*.json")):
        for r in json.load(open(f)):
            rows[(r["arch"], r["shape"], r["mesh"])] = r
    return sorted(rows.values(), key=lambda r: (r["mesh"], r["arch"],
                                                r["shape"]))


def fmt(x, p=3):
    if x == 0:
        return "0"
    return f"{x:.{p}e}"


def table(rows, mesh):
    lines = [
        "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) "
        "| bottleneck | args+temp GB/dev | 6ND/2ND / HLO | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                         f"| — | skipped: sub-quadratic required |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED | | | | | "
                         f"| {r.get('error','')[:60]} |")
            continue
        mem = r["arg_gb"] + r["temp_gb"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt(r['t_compute'])} "
            f"| {fmt(r['t_memory'])} | {fmt(r['t_collective'])} "
            f"| {r['bottleneck']} | {mem:.1f} | {r['useful_ratio']:.3f} | |")
    return "\n".join(lines)


def solver_table(quick: bool = True):
    """Measured roofline of the solver hot loop.

    One PCG iteration streams: the top-level ELL spmv, one V-cycle over the
    hierarchy's per-level ELL slabs, and ~10 [n, k] vector passes (p/r/z/x
    updates and dot products).  The model bytes cross with the measured
    ``solver.solve`` span (warm, jit-cached) to give achieved bytes/s
    against the HBM roof — the iteration count comes from the response's
    convergence telemetry, so nothing here re-runs the solve to count."""
    import numpy as np

    from repro.core import mesh2d
    from repro.launch.roofline import (HBM_BW, achieved_bandwidth,
                                       ell_spmv_bytes, ell_spmv_flops,
                                       hierarchy_level_shapes,
                                       hierarchy_level_triples, vcycle_bytes,
                                       vcycle_bytes_fused)
    from repro.obs import get_tracer
    from repro.solver import SolverService

    side, k = (24, 4) if quick else (80, 8)
    g = mesh2d(side, side, seed=0)
    svc = SolverService(alpha=0.05)
    handle = svc.register(g)
    rng = np.random.default_rng(0)
    B = rng.standard_normal((g.n, k)).astype(np.float32)
    B -= B.mean(axis=0)
    svc.solve(handle, B)                    # cold: build artifacts + jit

    tracer = get_tracer()
    was_enabled = tracer.enabled
    tracer.enable()
    tracer.clear()
    warm = svc.solve(handle, B)             # measured, cache + jit warm
    if not was_enabled:
        tracer.disable()

    solve_ms = tracer.durations_ms("solver.solve")
    assert solve_ms, "no solver.solve span recorded — tracer wiring broken"
    _, (idx, val, hier), _ = svc.artifacts(handle)
    l_top = int(idx.shape[1])
    shapes = hierarchy_level_shapes(hier)
    triples = hierarchy_level_triples(hier)
    iters = int(np.asarray(warm.iters).max())

    degree = 2                              # make_vcycle's default smoother
    spmv_b = ell_spmv_bytes(g.n, l_top, k)
    spmv_f = ell_spmv_flops(g.n, l_top, k)
    vc_b = vcycle_bytes(shapes, k, cheby_degree=degree)
    vc_fused_b = vcycle_bytes_fused(triples, k, cheby_degree=degree)
    # acceptance gate: the fused V-cycle must model strictly fewer HBM
    # bytes than the unfused composition on every hierarchy this builds
    assert vc_fused_b < vc_b, (
        f"fused V-cycle byte model ({vc_fused_b}) not below unfused "
        f"({vc_b}) — fusion model regressed")
    vec_b = 10 * g.n * k * 4
    iter_b = spmv_b + vc_b + vec_b
    iter_fused_b = spmv_b + vc_fused_b + vec_b
    total_b = iter_b * max(iters, 1)
    ach = achieved_bandwidth(total_b, solve_ms[0] / 1e3)
    ach_fused = achieved_bandwidth(iter_fused_b * max(iters, 1),
                                   solve_ms[0] / 1e3)

    gib = 1024.0 ** 3
    lines = [
        f"solver hot loop: mesh2d-{side}x{side} |V|={g.n} ELL width "
        f"L={l_top} k={k}  hierarchy levels={[s[0] for s in shapes]}",
        "",
        "| component        | bytes/iter (model) | flops/iter (model) |",
        "|---|---|---|",
        f"| ell_spmv (top)   | {spmv_b:>12,} | {spmv_f:>12,} |",
        f"| vcycle (unfused) | {vc_b:>12,} | — |",
        f"| vcycle (fused)   | {vc_fused_b:>12,} | — |",
        f"| vector ops       | {vec_b:>12,} | — |",
        f"| **total/iter**   | {iter_b:>12,} | — |",
        "",
        f"fused V-cycle models {vc_b / vc_fused_b:.2f}x fewer HBM bytes "
        f"than unfused (degree={degree})",
        f"measured: solver.solve span = {solve_ms[0]:.2f} ms, "
        f"iters = {iters}",
        f"achieved (unfused model) = {ach['bytes_per_s'] / gib:.2f} GiB/s "
        f"({100 * ach['frac_of_hbm']:.2f}% of the {HBM_BW / 1e9:.0f} GB/s "
        f"HBM roof)",
        f"achieved (fused model)   = "
        f"{ach_fused['bytes_per_s'] / gib:.2f} GiB/s "
        f"({100 * ach_fused['frac_of_hbm']:.2f}% of the HBM roof)",
    ]
    print("\n".join(lines))
    return {"n": g.n, "k": k, "ell_width": l_top, "iters": iters,
            "bytes_per_iter": iter_b, "bytes_per_iter_fused": iter_fused_b,
            "vcycle_bytes": vc_b, "vcycle_bytes_fused": vc_fused_b,
            "solve_ms": solve_ms[0],
            "achieved_bytes_per_s": ach["bytes_per_s"],
            "frac_of_hbm": ach["frac_of_hbm"],
            "frac_of_hbm_fused": ach_fused["frac_of_hbm"]}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny graph for the solver hot-loop row")
    ap.add_argument("--out-dir", default="experiments",
                    help="directory holding dryrun_*.json artifacts")
    args = ap.parse_args(argv)

    rows = load(args.out_dir)
    for mesh in sorted({r["mesh"] for r in rows}):
        print(f"\n### Mesh {mesh}\n")
        print(table(rows, mesh))
    if not rows:
        print("(no dryrun_*.json artifacts — skipping HLO roofline tables)")

    print("\n### Solver hot loop (measured spans vs analytic model)\n")
    solver_table(quick=args.quick)


if __name__ == "__main__":
    main()
