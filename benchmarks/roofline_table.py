"""Render the dry-run JSON artifacts into the EXPERIMENTS.md roofline table."""
from __future__ import annotations

import glob
import json
import os


def load(out_dir="experiments"):
    rows = {}
    for f in glob.glob(os.path.join(out_dir, "dryrun_*.json")):
        for r in json.load(open(f)):
            rows[(r["arch"], r["shape"], r["mesh"])] = r
    return sorted(rows.values(), key=lambda r: (r["mesh"], r["arch"],
                                                r["shape"]))


def fmt(x, p=3):
    if x == 0:
        return "0"
    return f"{x:.{p}e}"


def table(rows, mesh):
    lines = [
        "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) "
        "| bottleneck | args+temp GB/dev | 6ND/2ND / HLO | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                         f"| — | skipped: sub-quadratic required |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED | | | | | "
                         f"| {r.get('error','')[:60]} |")
            continue
        mem = r["arg_gb"] + r["temp_gb"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt(r['t_compute'])} "
            f"| {fmt(r['t_memory'])} | {fmt(r['t_collective'])} "
            f"| {r['bottleneck']} | {mem:.1f} | {r['useful_ratio']:.3f} | |")
    return "\n".join(lines)


def main():
    rows = load()
    for mesh in sorted({r["mesh"] for r in rows}):
        print(f"\n### Mesh {mesh}\n")
        print(table(rows, mesh))


if __name__ == "__main__":
    main()
