"""Quickstart: the staged Pipeline API — pdGRASS vs feGRASS as a config diff.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import mesh2d, pdgrass
from repro.core.pcg import pcg_host
from repro.pipeline import (Pipeline, config_diff, fegrass_config,
                            pdgrass_config)


def main():
    g = mesh2d(40, 40, seed=0)
    print(f"graph: |V|={g.n} |E|={g.m}")

    # One harness, two configs: the whole pdGRASS-vs-feGRASS story is the
    # recovery-stage diff.
    pd_cfg = pdgrass_config(alpha=0.05)
    fe_cfg = fegrass_config(alpha=0.05)
    print(f"config diff: {config_diff(pd_cfg, fe_cfg)}")

    pipe = Pipeline(pd_cfg)
    prep = pipe.prepare(g)              # shared steps 1-3, reused below
    sp = pipe.run(g, prepared=prep)
    print(f"pdGRASS: tree edges={int(sp.tree_mask.sum())}, "
          f"recovered={sp.stats['n_recovered']} "
          f"(target {sp.stats['target']}), "
          f"subtasks={sp.stats['n_subtasks']}, "
          f"rounds={sp.stats['rounds']}, passes={sp.stats['passes']}")

    fe = Pipeline(fe_cfg).run(g, prepared=prep)
    print(f"feGRASS baseline: recovered={fe.stats['n_recovered']} "
          f"in {fe.stats['passes']} passes")

    # configs serialize canonically (cache keys, service requests, disk)
    rt = type(pd_cfg).from_dict(pd_cfg.to_dict())
    assert rt == pd_cfg

    # the legacy entry point is a thin wrapper over the same pipeline
    legacy = pdgrass(g, alpha=0.05)
    assert np.array_equal(legacy.edge_mask, sp.edge_mask)

    rng = np.random.default_rng(0)
    b = rng.standard_normal(g.n)
    b -= b.mean()
    L = g.laplacian()
    it_none = pcg_host(L, b).iters
    it_pd = pcg_host(L, b, sp.laplacian()).iters
    it_fe = pcg_host(L, b, fe.laplacian()).iters
    print(f"PCG iters: unpreconditioned={it_none}  "
          f"pdGRASS={it_pd}  feGRASS={it_fe}")
    assert it_pd < it_none

    # device-resident views: jit-safe matvec, ELL slabs for the solver
    x = rng.standard_normal(g.n).astype(np.float32)
    y = np.asarray(sp.laplacian_matvec(x))
    err = np.abs(y - sp.laplacian() @ x).max()
    idx, val = sp.to_ell()
    print(f"device views: to_ell slabs {tuple(idx.shape)}, "
          f"matvec vs scipy max err {err:.1e}")
    print("OK")


if __name__ == "__main__":
    main()
