"""Quickstart: sparsify a graph with pdGRASS and precondition PCG with it.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import mesh2d, pdgrass, fegrass
from repro.core.pcg import pcg_host


def main():
    g = mesh2d(40, 40, seed=0)
    print(f"graph: |V|={g.n} |E|={g.m}")

    sp = pdgrass(g, alpha=0.05)
    print(f"pdGRASS: tree edges={int(sp.tree_mask.sum())}, "
          f"recovered={sp.stats['n_recovered']} "
          f"(target {sp.stats['target']}), "
          f"subtasks={sp.stats['n_subtasks']}, "
          f"rounds={sp.stats['rounds']}, passes={sp.stats['passes']}")

    fe = fegrass(g, alpha=0.05)
    print(f"feGRASS baseline: recovered={fe.stats['n_recovered']} "
          f"in {fe.stats['passes']} passes")

    rng = np.random.default_rng(0)
    b = rng.standard_normal(g.n)
    b -= b.mean()
    L = g.laplacian()
    it_none = pcg_host(L, b).iters
    it_pd = pcg_host(L, b, sp.laplacian()).iters
    it_fe = pcg_host(L, b, fe.laplacian()).iters
    print(f"PCG iters: unpreconditioned={it_none}  "
          f"pdGRASS={it_pd}  feGRASS={it_fe}")
    assert it_pd < it_none
    print("OK")


if __name__ == "__main__":
    main()
