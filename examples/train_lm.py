"""Train a small LM end-to-end with the production training stack:
AdamW, remat, checkpointing, fault-tolerant trainer, synthetic pipeline.

Any of the 10 assigned architectures can be selected (reduced to a CPU-
trainable width with --width-scale); the full configs are exercised by
the dry-run instead.

    PYTHONPATH=src python examples/train_lm.py --arch qwen3-4b --steps 200
"""
import argparse
import dataclasses

import numpy as np

from repro.configs import get_config, reduced
from repro.train.data import batches
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import ResilientTrainer, TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--compress", action="store_true")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    cfg = dataclasses.replace(cfg, n_layers=args.layers,
                              d_model=args.d_model,
                              d_ff=args.d_model * 3 if cfg.d_ff else 0)
    print(f"arch={cfg.name} family={cfg.family} layers={cfg.n_layers} "
          f"d_model={cfg.d_model}")

    tr = ResilientTrainer(
        cfg,
        TrainConfig(opt=AdamWConfig(lr=3e-3, warmup_steps=20,
                                    total_steps=args.steps),
                    compress_grads=args.compress),
        ckpt_dir=args.ckpt, ckpt_every=50)
    data_fn = lambda s: batches(cfg, args.batch, args.seq,  # noqa: E731
                                seed=0, start_step=s)
    _, _, losses = tr.run(data_fn, steps=args.steps, resume=True,
                          log_every=20)
    print(f"first-10 loss {np.mean(losses[:10]):.3f} -> "
          f"last-10 loss {np.mean(losses[-10:]):.3f}")
    if tr.stragglers:
        print(f"straggler steps detected: {len(tr.stragglers)}")


if __name__ == "__main__":
    main()
