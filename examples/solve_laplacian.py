"""End-to-end driver (the paper's application): sparsifier-preconditioned
Laplacian solve at the largest size this container handles comfortably.

Pipeline: graph ingest -> effective-weight spanning tree (Boruvka, JAX)
-> binary lifting -> strict-similarity recovery (round engine) -> PCG
with the sparsifier Laplacian as preconditioner (sparse LU solve).

    PYTHONPATH=src python examples/solve_laplacian.py [--scale medium]
"""
import argparse
import time

import numpy as np

from repro.core import barabasi_albert, mesh2d, pdgrass, prepare
from repro.core.pcg import pcg_host


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small",
                    choices=["small", "medium"])
    ap.add_argument("--alpha", type=float, default=0.05)
    args = ap.parse_args()

    if args.scale == "small":
        g = mesh2d(120, 120, seed=0)
    else:
        g = mesh2d(300, 300, seed=0)
    print(f"graph: |V|={g.n} |E|={g.m}")

    t0 = time.perf_counter()
    prep = prepare(g)
    t_prep = time.perf_counter() - t0
    print(f"steps 1-3 (tree+lifting+subtasks): {t_prep*1e3:.0f} ms, "
          f"{prep.n_subtasks} subtasks, largest={prep.subtask_sizes.max()}")

    t0 = time.perf_counter()
    sp = pdgrass(g, alpha=args.alpha, prepared=prep)
    t_rec = time.perf_counter() - t0
    print(f"step 4 (recovery): {t_rec*1e3:.0f} ms, "
          f"recovered {sp.stats['n_recovered']} edges "
          f"in {sp.stats['rounds']} rounds")

    rng = np.random.default_rng(1)
    b = rng.standard_normal(g.n)
    b -= b.mean()
    L = g.laplacian()
    t0 = time.perf_counter()
    res_raw = pcg_host(L, b)
    t_raw = time.perf_counter() - t0
    t0 = time.perf_counter()
    res_pre = pcg_host(L, b, sp.laplacian())
    t_pre = time.perf_counter() - t0
    print(f"PCG unpreconditioned: {res_raw.iters} iters, {t_raw*1e3:.0f} ms")
    print(f"PCG + pdGRASS:        {res_pre.iters} iters, {t_pre*1e3:.0f} ms "
          f"(relres {res_pre.relres:.2e})")


if __name__ == "__main__":
    main()
