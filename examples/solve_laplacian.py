"""End-to-end driver (the paper's application): sparsifier-preconditioned
Laplacian solve, served through the ``repro.solver`` subsystem.

Pipeline per graph (paid once, then cached by content hash): effective-weight
spanning tree (Boruvka, JAX) -> binary lifting -> strict-similarity recovery
(round engine) -> SF-GRASS-style multilevel hierarchy -> jit'd batched
device PCG with the hierarchy V-cycle as preconditioner.  Repeated solves on
the same graph skip all of it and run the cached jit'd solver.

    PYTHONPATH=src python examples/solve_laplacian.py [--scale medium]
"""
import argparse
import time

import numpy as np

from repro.core import mesh2d, pdgrass
from repro.core.pcg import pcg_host
from repro.pipeline import pdgrass_config
from repro.solver import SolverService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small",
                    choices=["small", "medium"])
    ap.add_argument("--alpha", type=float, default=0.05)
    ap.add_argument("--batch", type=int, default=8,
                    help="number of right-hand sides per request")
    args = ap.parse_args()

    if args.scale == "small":
        g = mesh2d(60, 60, seed=0)
    else:
        g = mesh2d(160, 160, seed=0)
    print(f"graph: |V|={g.n} |E|={g.m}")

    rng = np.random.default_rng(1)
    B = rng.standard_normal((g.n, args.batch)).astype(np.float32)
    B -= B.mean(axis=0)

    # the service takes the full staged pipeline config — any family member
    # (swap in fegrass_config for the baseline-preconditioned service)
    svc = SolverService(pipeline=pdgrass_config(alpha=args.alpha, chunk=512),
                        precond="hierarchy")
    t0 = time.perf_counter()
    cold = svc.solve(g, B)
    t_cold = time.perf_counter() - t0
    print(f"cold solve (steps 1-4 + hierarchy + jit + solve): "
          f"{t_cold:.1f} s  cache={cold.cache}  "
          f"iters={int(cold.iters.max())}  relres={cold.relres.max():.2e}")

    t0 = time.perf_counter()
    warm = svc.solve(g, B)
    t_warm = time.perf_counter() - t0
    print(f"warm solve (cache hit, jit'd batched PCG): "
          f"{t_warm*1e3:.0f} ms for k={args.batch} RHS "
          f"({t_warm*1e3/args.batch:.1f} ms/rhs)  cache={warm.cache}")

    # reference: the pre-service path — rebuild the sparsifier and factor it
    # per call, then host PCG (this is what every solve used to cost)
    b0 = B[:, 0].astype(np.float64)
    L = g.laplacian()
    t0 = time.perf_counter()
    sp = pdgrass(g, alpha=args.alpha)
    res_pre = pcg_host(L, b0, sp.laplacian(), tol=1e-5, maxiter=20_000)
    t_host = time.perf_counter() - t0
    print(f"host per-call (pdGRASS rebuild + LU + PCG): {res_pre.iters} "
          f"iters, {t_host*1e3:.0f} ms/rhs")
    xd = warm.x[:, 0] - warm.x[0, 0]
    xh = res_pre.x - res_pre.x[0]
    err = np.abs(xd - xh).max() / max(np.abs(xh).max(), 1.0)
    print(f"device vs host solution: max rel diff {err:.2e} — cached warm "
          f"path speedup {t_host / (t_warm/args.batch):.1f}x per RHS")


if __name__ == "__main__":
    main()
