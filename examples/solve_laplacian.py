"""End-to-end driver (the paper's application): sparsifier-preconditioned
Laplacian solve, served through the ``repro.solver`` subsystem's v2 request
plane.

Pipeline per (graph, config), paid once then cached by content hash:
effective-weight spanning tree (Boruvka, JAX) -> binary lifting ->
strict-similarity recovery (round engine) -> SF-GRASS-style multilevel
hierarchy -> jit'd batched device PCG with the hierarchy V-cycle as
preconditioner.  The serving flow is: register the graph once (one O(m)
content hash -> GraphHandle), warm the artifact cache, submit ticket
futures — optionally with per-request PipelineConfig overrides — and flush;
the scheduler batches each (graph, config) group into one device solve.

Single-device here; ``SolverService(mesh=...)`` moves the same request
plane onto a device mesh (row-sharded PCG + V-cycle, mesh-contracted
hierarchy) — see ``examples/distributed_sparsify.py`` for the one-mesh
end-to-end flow.

    PYTHONPATH=src python examples/solve_laplacian.py [--scale medium]
"""
import argparse
import time

import numpy as np

from repro.core import mesh2d, pdgrass
from repro.core.pcg import pcg_host
from repro.pipeline import fegrass_config, pdgrass_config
from repro.solver import SolveRequest, SolverService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small",
                    choices=["small", "medium"])
    ap.add_argument("--alpha", type=float, default=0.05)
    ap.add_argument("--batch", type=int, default=8,
                    help="number of right-hand sides per request")
    args = ap.parse_args()

    if args.scale == "small":
        g = mesh2d(60, 60, seed=0)
    else:
        g = mesh2d(160, 160, seed=0)
    print(f"graph: |V|={g.n} |E|={g.m}")

    rng = np.random.default_rng(1)
    B = rng.standard_normal((g.n, args.batch)).astype(np.float32)
    B -= B.mean(axis=0)

    # the service takes the full staged pipeline config — any family member
    pd_cfg = pdgrass_config(alpha=args.alpha, chunk=512)
    fe_cfg = fegrass_config(alpha=args.alpha, chunk=512)
    svc = SolverService(pipeline=pd_cfg, precond="hierarchy")

    # register once: the O(m) content hash lives on the handle from here on.
    # warmup builds the hierarchy per config (device propose/accept
    # contraction) AND jit-compiles the solve for the RHS-width bucket, so
    # the first real flush pays neither build nor XLA compile time.
    handle = svc.register(g)
    t0 = time.perf_counter()
    sources = svc.warmup(handle, configs=[pd_cfg, fe_cfg],
                         widths=[args.batch])
    t_warmup = time.perf_counter() - t0
    timing = svc.stats()["timing"]
    print(f"warmup (steps 1-4 + hierarchy + jit per config): "
          f"{t_warmup:.1f} s  artifact sources={sources}  "
          f"compile={timing['warmup_compile_ms']/1e3:.1f} s")

    # one flush, two pipeline configs, one graph: the scheduler splits the
    # pending tickets into per-(graph, config) groups, each a single
    # batched jit'd device PCG against its own cached hierarchy
    t_pd = svc.submit(SolveRequest(graph=handle, b=B))
    t_fe = svc.submit(SolveRequest(graph=handle, b=B, pipeline=fe_cfg))
    t0 = time.perf_counter()
    svc.flush()
    t_flush = time.perf_counter() - t0
    r_pd, r_fe = t_pd.result(), t_fe.result()   # futures, any order
    print(f"mixed flush (compile prepaid by warmup): {t_flush:.1f} s  "
          f"pd: iters={int(r_pd.iters.max())} cache={r_pd.cache}  "
          f"fe: iters={int(r_fe.iters.max())} cache={r_fe.cache}")

    t0 = time.perf_counter()
    warm = svc.solve(handle, B)
    t_warm = time.perf_counter() - t0
    print(f"warm solve (cache hit, jit'd batched PCG): "
          f"{t_warm*1e3:.0f} ms for k={args.batch} RHS "
          f"({t_warm*1e3/args.batch:.1f} ms/rhs)  cache={warm.cache}")
    stats = svc.stats()
    print(f"stats: groups={stats['scheduler']['groups']} "
          f"hash_events={stats['store']['hash_events']} "
          f"solves_by_config={stats['solves_by_config']} "
          f"compile/solve split="
          f"{stats['timing']['warmup_compile_ms']:.0f}/"
          f"{stats['timing']['solve_ms']:.0f} ms")

    # reference: the pre-service path — rebuild the sparsifier and factor it
    # per call, then host PCG (this is what every solve used to cost)
    b0 = B[:, 0].astype(np.float64)
    L = g.laplacian()
    t0 = time.perf_counter()
    sp = pdgrass(g, alpha=args.alpha)
    res_pre = pcg_host(L, b0, sp.laplacian(), tol=1e-5, maxiter=20_000)
    t_host = time.perf_counter() - t0
    print(f"host per-call (pdGRASS rebuild + LU + PCG): {res_pre.iters} "
          f"iters, {t_host*1e3:.0f} ms/rhs")
    xd = warm.x[:, 0] - warm.x[0, 0]
    xh = res_pre.x - res_pre.x[0]
    err = np.abs(xd - xh).max() / max(np.abs(xh).max(), 1.0)
    print(f"device vs host solution: max rel diff {err:.2e} — cached warm "
          f"path speedup {t_host / (t_warm/args.batch):.1f}x per RHS")


if __name__ == "__main__":
    main()
