"""Batched serving demo: prefill + jit'd decode against KV caches.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma2-2b
"""
import argparse

import numpy as np

import jax

from repro.configs import get_config, reduced
from repro.models import init_params
from repro.serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    params = init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, batch=args.batch, cache_len=64)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=8
                                        ).astype(np.int32),
                    max_new=args.max_new)
            for _ in range(args.batch)]
    outs = eng.generate(reqs)
    for i, o in enumerate(outs):
        print(f"req{i}: prompt={reqs[i].prompt.tolist()} -> {o.tolist()}")
    print("OK")


if __name__ == "__main__":
    main()
