"""Multi-device pdGRASS + solver service: the paper's mixed parallel strategy
on a JAX mesh, feeding the sparsifier-preconditioned solve.

Runs with 8 emulated host devices (set before jax import) — subtasks are
LPT-packed onto devices (outer parallelism); subtasks above the cutoff go
through the cross-device inner engine (one all_gather of candidates per
round).  Verifies bit-identical output vs the serial oracle, then routes a
batch of right-hand sides through a ``SolverService(mesh=...)`` on the SAME
mesh — the sharded solve plane: mesh-contracted hierarchy, row-sharded
batched PCG + V-cycle — and spot-checks parity against the single-device
solver.  One mesh, end to end.

    PYTHONPATH=src python examples/distributed_sparsify.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402

import jax  # noqa: E402

from repro.core import barabasi_albert  # noqa: E402
from repro.core.distributed import partition_subtasks  # noqa: E402
from repro.launch.mesh import compat_make_mesh  # noqa: E402
from repro.pipeline import Pipeline, pdgrass_config  # noqa: E402
from repro.solver import SolverService  # noqa: E402


def main():
    g = barabasi_albert(3000, 4, seed=0)
    print(f"graph: |V|={g.n} |E|={g.m}, devices={jax.device_count()}")

    # the distributed engine is just another recovery stage; the mesh is
    # runtime context (not config), passed through Pipeline.run
    dist_pipe = Pipeline(pdgrass_config(alpha=0.05, chunk=512,
                                        engine="distributed",
                                        stop_at_target=False))
    serial_pipe = Pipeline(pdgrass_config(alpha=0.05, chunk=512,
                                          engine="serial"))
    prep = dist_pipe.prepare(g)   # shared steps 1-3 for both engines
    mesh = compat_make_mesh((jax.device_count(),), ("data",))
    shard_of, giants, load = partition_subtasks(
        prep.subtask_sizes, jax.device_count())
    print(f"subtasks={prep.n_subtasks} giants={len(giants)} "
          f"outer load per device={load.tolist()}")
    sp = dist_pipe.run(g, prepared=prep, mesh=mesh)
    ref = serial_pipe.run(g, prepared=prep)
    assert np.array_equal(sp.recovered_mask, ref.recovered_mask), \
        "distributed != serial!"
    print(f"recovered={sp.stats['n_recovered']} on "
          f"{sp.stats['n_shards']} shards — "
          f"bit-identical to the serial oracle. OK")

    # downstream: serve solves on the SAME mesh — the sharded solve plane
    # (row-sharded PCG + V-cycle, mesh-sharded hierarchy contraction), so
    # sparsify + precondition + solve all run on one set of devices
    svc = SolverService(alpha=0.05, mesh=mesh)
    rng = np.random.default_rng(1)
    B = rng.standard_normal((g.n, 4)).astype(np.float32)
    B -= B.mean(axis=0)
    cold = svc.solve(g, B)
    warm = svc.solve(g, B)
    print(f"sharded solver service ({jax.device_count()} devices, "
          f"contraction={svc.contraction}): cold cache={cold.cache} "
          f"iters={int(cold.iters.max())} relres={cold.relres.max():.2e}; "
          f"warm cache={warm.cache} ({warm.solve_ms:.0f} ms for 4 RHS)")

    # parity spot-check against a single-device service
    ref = SolverService(alpha=0.05).solve(g, B)
    drift = np.abs((warm.x - warm.x[0]) - (ref.x - ref.x[0])).max()
    d_it = int(np.abs(np.asarray(warm.iters, np.int64)
                      - np.asarray(ref.iters, np.int64)).max())
    print(f"parity vs single-device: max rebased drift={drift:.1e}, "
          f"iteration-count delta={d_it}")
    # f32 reduction order differs across shard counts; on this 3000-vertex
    # graph the counts land within a few iterations of each other
    assert d_it <= 4
    assert drift <= 1e-4


if __name__ == "__main__":
    main()
