"""Telemetry plane: span tracer, metrics registry, solver instrumentation.

Covers the observability contracts end to end:

  * disabled tracing is a true no-op (allocation spy + shared singleton),
  * concurrent span recording is thread-safe and lossless under the cap,
  * histogram percentiles agree with the numpy oracle within one bucket
    ratio,
  * Chrome trace export round-trips through ``json.loads`` and preserves
    nesting by interval containment,
  * a single flush with tracing enabled produces the full nested span set
    (pipeline stages, hierarchy levels, cache lookups, batched solve),
  * ``stats()`` reports per-config PCG convergence histograms, is a deep
    copy (mutating the return must not corrupt live counters), and
    per-service metrics are isolated between services.
"""
import json
import threading

import numpy as np
import pytest

from repro.core import mesh2d
from repro.obs import (Counter, Gauge, Histogram, Metrics, get_metrics,
                       get_tracer)
from repro.obs import trace as trace_mod
from repro.obs.trace import NOOP_SPAN, Tracer
from repro.solver import SolveRequest, SolverService
from repro.solver.cache import content_fingerprint


def _rhs(g, k=1, seed=0):
    rng = np.random.default_rng(seed)
    b = rng.standard_normal((g.n, k)).astype(np.float32)
    return b - b.mean(axis=0)


@pytest.fixture
def traced():
    """Enable the process-wide tracer for one test, restoring prior state."""
    tr = get_tracer()
    was = tr.enabled
    tr.enable()
    tr.clear()
    yield tr
    tr.clear()
    tr.enabled = was


# -- tracer ------------------------------------------------------------------


def test_disabled_span_is_true_noop(monkeypatch):
    """A disabled tracer must not allocate, lock, or record — the warm-solve
    path is instrumented unconditionally, so this is the <2% contract."""
    calls = {"n": 0}
    real_span = trace_mod._Span

    class Spy(real_span):
        def __init__(self, *a, **kw):
            calls["n"] += 1
            real_span.__init__(self, *a, **kw)

    monkeypatch.setattr(trace_mod, "_Span", Spy)
    tr = Tracer(enabled=False)
    spans = [tr.span(f"s{i}", i=i) for i in range(50)]
    assert calls["n"] == 0, "disabled span() constructed a live span"
    assert all(s is NOOP_SPAN for s in spans), (
        "disabled span() must return the shared singleton")
    with tr.span("x") as sp:
        sp.set(result=1)        # must be accepted and discarded
    tr.instant("marker")
    assert tr.events() == []
    tr.enable()
    with tr.span("y"):
        pass
    assert calls["n"] == 1 and tr.span_names() == ["y"]


def test_nested_spans_record_depth_and_containment(traced):
    with traced.span("outer", who="test") as outer:
        with traced.span("inner"):
            pass
        outer.set(children=1)
    evs = {ev["name"]: ev for ev in traced.events()}
    assert evs["inner"]["depth"] == 1 and evs["outer"]["depth"] == 0
    assert evs["outer"]["args"] == {"who": "test", "children": 1}
    # the child exits first but its interval nests inside the parent's
    o, i = evs["outer"], evs["inner"]
    assert o["ts_ns"] <= i["ts_ns"]
    assert i["ts_ns"] + i["dur_ns"] <= o["ts_ns"] + o["dur_ns"]


def test_concurrent_span_recording_is_thread_safe():
    tr = Tracer(enabled=True)
    n_threads, n_spans = 8, 200
    barrier = threading.Barrier(n_threads)

    def worker(i):
        barrier.wait()
        for j in range(n_spans):
            with tr.span(f"t{i}", j=j):
                with tr.span(f"t{i}.child"):
                    pass

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    evs = tr.events()
    assert len(evs) == n_threads * n_spans * 2 and tr.dropped == 0
    # per-thread nesting depths never bled across threads
    for ev in evs:
        assert ev["depth"] == (1 if ev["name"].endswith(".child") else 0)
    assert len({ev["tid"] for ev in evs}) == n_threads


def test_event_buffer_is_bounded():
    tr = Tracer(enabled=True, max_events=10)
    for i in range(25):
        with tr.span("s"):
            pass
    assert len(tr.events()) == 10 and tr.dropped == 15
    assert tr.to_chrome()["otherData"]["dropped_events"] == 15
    tr.clear()
    assert tr.events() == [] and tr.dropped == 0


def test_chrome_export_roundtrips_through_json(tmp_path, traced):
    with traced.span("parent", n=np.int64(3), f=np.float32(0.5),
                     arr=np.arange(2)):
        with traced.span("child"):
            pass
    traced.instant("mark", note="hi")
    path = tmp_path / "trace.json"
    traced.export_chrome(str(path))
    doc = json.loads(path.read_text())      # strict round-trip
    evs = {ev["name"]: ev for ev in doc["traceEvents"]}
    assert evs["parent"]["ph"] == "X" and evs["child"]["ph"] == "X"
    assert evs["mark"]["ph"] == "i"
    # numpy attrs degraded to plain JSON scalars/strings
    assert evs["parent"]["args"]["n"] == 3
    assert evs["parent"]["args"]["f"] == pytest.approx(0.5)
    assert isinstance(evs["parent"]["args"]["arr"], str)
    # microsecond containment survives the export
    p, c = evs["parent"], evs["child"]
    assert p["ts"] <= c["ts"] and c["ts"] + c["dur"] <= p["ts"] + p["dur"]
    assert all(ev["pid"] == p["pid"] for ev in doc["traceEvents"])


def test_jsonl_export_one_object_per_line(tmp_path, traced):
    for i in range(3):
        with traced.span("s", i=i):
            pass
    path = tmp_path / "trace.jsonl"
    traced.export_jsonl(str(path))
    lines = path.read_text().strip().splitlines()
    assert len(lines) == 3
    assert [json.loads(ln)["args"]["i"] for ln in lines] == [0, 1, 2]


# -- metrics -----------------------------------------------------------------


def test_counter_gauge_basics():
    m = Metrics()
    m.inc("a.count")
    m.inc("a.count", 4)
    m.set_gauge("a.level", 7.5)
    assert m.counter("a.count").value == 5
    assert m.gauge("a.level").value == 7.5
    with pytest.raises(TypeError):
        m.gauge("a.count")          # type conflict must be loud
    snap = m.snapshot()
    assert snap == {"a.count": 5, "a.level": 7.5}
    snap["a.count"] = 999           # snapshot is detached
    assert m.counter("a.count").value == 5


def test_histogram_percentiles_match_numpy_oracle():
    rng = np.random.default_rng(42)
    data = rng.lognormal(mean=1.0, sigma=1.5, size=5000)
    h = Histogram()
    h.observe_many(data)
    snap = h.snapshot()
    assert snap["count"] == 5000
    assert snap["min"] == pytest.approx(float(data.min()))
    assert snap["max"] == pytest.approx(float(data.max()))
    assert snap["sum"] == pytest.approx(float(data.sum()), rel=1e-9)
    # bounded buckets guarantee at most one bucket ratio (~26%) of error
    for p in (50, 90, 99):
        assert h.percentile(p) == pytest.approx(
            float(np.percentile(data, p)), rel=0.26)
    # endpoints are exact
    assert h.percentile(0) == pytest.approx(float(data.min()))
    assert h.percentile(100) == pytest.approx(float(data.max()))


def test_histogram_concurrent_observe():
    h = Histogram()

    def worker(seed):
        rng = np.random.default_rng(seed)
        h.observe_many(rng.uniform(0.1, 100.0, size=500))

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert h.count == 2000


def test_content_hash_mirrors_into_global_metrics():
    before = get_metrics().counter("store.hash_events").value
    g = mesh2d(4, 4, seed=3)
    content_fingerprint(g)
    content_fingerprint(g)          # memoized: no second hash event
    assert get_metrics().counter("store.hash_events").value == before + 1


# -- solver-depth instrumentation -------------------------------------------


@pytest.fixture(scope="module")
def traffic():
    """One traced flush through a service whose hierarchy has real levels."""
    tr = get_tracer()
    was = tr.enabled
    tr.enable()
    tr.clear()
    g = mesh2d(14, 14, seed=0)      # n=196 > coarse_n: multilevel chain
    svc = SolverService(alpha=0.1)
    h = svc.register(g)
    ticket = svc.submit(SolveRequest(graph=h, b=_rhs(g, k=3)))
    svc.flush()
    resp = ticket.result()
    events = tr.events()
    tr.clear()
    tr.enabled = was
    return g, svc, resp, events


def test_flush_produces_nested_solver_spans(traffic, tmp_path):
    g, svc, resp, events = traffic
    assert resp.converged
    names = {ev["name"] for ev in events}
    for required in ("pipeline.prepare", "pipeline.tree", "pipeline.scores",
                     "pipeline.recovery", "hierarchy.build",
                     "hierarchy.level", "hierarchy.sparsify",
                     "hierarchy.contract", "cache.get", "cache.build",
                     "solver.flush", "solver.group", "solver.artifacts",
                     "solver.solve"):
        assert required in names, f"missing span {required}"
    # the whole stack nests under the flush: Chrome containment check
    tr = Tracer(enabled=True)
    tr._events = list(events)       # re-export the captured buffer
    doc = json.loads(json.dumps(tr.to_chrome()))
    evs = {ev["name"]: ev for ev in doc["traceEvents"]}
    flush = evs["solver.flush"]
    for inner in ("solver.group", "solver.solve", "hierarchy.build",
                  "cache.get"):
        ev = evs[inner]
        assert flush["ts"] <= ev["ts"]
        assert ev["ts"] + ev["dur"] <= flush["ts"] + flush["dur"]
    # hierarchy levels carry sizes; one span per fine level
    levels = [ev for ev in events if ev["name"] == "hierarchy.level"]
    assert len(levels) >= 1
    assert levels[0]["args"]["n"] == g.n


def test_stats_reports_convergence_telemetry(traffic):
    _, svc, resp, _ = traffic
    st = svc.stats()
    assert st["convergence"], "no convergence telemetry recorded"
    conv = st["convergence"][resp.config]
    assert conv["iters"]["count"] == 3          # one sample per RHS column
    assert conv["iters"]["max"] >= resp.iters.max()
    assert conv["relres"]["count"] == 3
    assert conv["relres"]["p99"] <= 2e-5        # converged to tol
    assert conv["solve_ms"]["count"] == 1       # one flush group
    assert conv["solve_ms"]["p50"] > 0
    m = st["metrics"]
    assert m["solver.flushes"] == 1
    assert m["solver.requests_solved"] == 1
    assert m["cache.misses"] == 1
    assert m[f"solver.pcg.iters.{resp.config}"]["count"] == 3


def test_stats_returns_a_deep_copy(traffic):
    """Satellite regression: mutating the returned dict must never corrupt
    the service's live counters."""
    _, svc, resp, _ = traffic
    st = svc.stats()
    st["scheduler"]["flushes"] = 10_000
    st["timing"]["solve_ms"] = -1.0
    st["metrics"].clear()
    st["convergence"][resp.config]["iters"]["count"] = 0
    st["solves_by_config"].clear()
    st2 = svc.stats()
    assert st2["scheduler"]["flushes"] == 1
    assert st2["timing"]["solve_ms"] > 0
    assert st2["metrics"]["solver.flushes"] == 1
    assert st2["convergence"][resp.config]["iters"]["count"] == 3
    assert st2["solves_by_config"] == {resp.config: 1}


def test_service_metrics_are_isolated(traffic):
    """Two services must not share solver/cache instruments."""
    _, busy, _, _ = traffic
    fresh = SolverService(alpha=0.1)
    st = fresh.stats()
    assert st["metrics"].get("solver.flushes", 0) == 0
    assert st["metrics"].get("cache.misses", 0) == 0
    assert st["convergence"] == {}
    assert busy.stats()["metrics"]["solver.flushes"] == 1
    # explicit sharing is still possible by injecting one registry
    shared = Metrics()
    a = SolverService(alpha=0.1, metrics=shared)
    b = SolverService(alpha=0.1, metrics=shared)
    assert a.metrics is b.metrics is shared


def test_warm_solve_records_no_spans_when_disabled(traffic):
    """Instrumented hot path stays silent with the tracer off."""
    g, svc, _, _ = traffic
    tr = get_tracer()
    assert not tr.enabled
    tr.clear()
    resp = svc.solve(svc.register(g), _rhs(g, k=2, seed=1))
    assert resp.converged and resp.cache == "mem"
    assert tr.events() == []


def test_counter_and_gauge_types_exported():
    assert isinstance(Metrics().counter("x"), Counter)
    assert isinstance(Metrics().gauge("y"), Gauge)


# -- sampled always-on tracing ----------------------------------------------


def test_sampled_tracer_records_every_nth_root_span():
    tr = Tracer(enabled=True, sample_rate=0.5)     # period 2
    for i in range(10):
        with tr.span(f"root{i}"):
            pass
    assert tr.span_names() == [f"root{i}" for i in range(0, 10, 2)]
    assert tr.sampled_out == 5


def test_sampling_decision_covers_the_whole_root_tree():
    """A dropped root suppresses everything beneath it — nested spans and
    instants never sample independently, so recorded trees stay complete."""
    tr = Tracer(enabled=True, sample_rate=0.5)
    for i in range(4):
        with tr.span(f"root{i}") as root:
            root.set(i=i)
            with tr.span("child") as c:
                c.set(deep=True)
                with tr.span("grandchild"):
                    pass
            tr.instant(f"marker{i}")
    names = tr.span_names()
    # roots 0 and 2 recorded with their full subtrees; 1 and 3 vanish whole
    assert names.count("child") == 2 == names.count("grandchild")
    assert [n for n in names if n.startswith("root")] == ["root0", "root2"]
    assert [n for n in names if n.startswith("marker")] == \
        ["marker0", "marker2"]
    # nesting depth survived sampling
    evs = {ev["name"]: ev for ev in tr.events()}
    assert evs["child"]["depth"] == 1 and evs["grandchild"]["depth"] == 2


def test_sample_rate_one_is_the_default_full_firehose():
    tr = Tracer(enabled=True)
    for i in range(5):
        with tr.span(f"s{i}"):
            pass
    assert len(tr.span_names()) == 5 and tr.sampled_out == 0


def test_invalid_sample_rate_rejected():
    for bad in (0.0, -0.5, 1.5):
        with pytest.raises(ValueError, match="sample_rate"):
            Tracer(sample_rate=bad)
        with pytest.raises(ValueError, match="sample_rate"):
            Tracer().set_sample_rate(bad)


def test_set_sample_rate_restarts_counter():
    tr = Tracer(enabled=True, sample_rate=0.25)
    with tr.span("a"):                  # seq 0: recorded
        pass
    tr.set_sample_rate(0.5)             # counter restarts
    with tr.span("b"):                  # seq 0 again: recorded
        pass
    with tr.span("c"):                  # seq 1: dropped
        pass
    assert tr.span_names() == ["a", "b"]


def test_disabled_sampled_tracer_is_still_allocation_free(monkeypatch):
    """sample_rate must not cost anything while tracing is off — the
    always-on production config is (enabled later, sampled forever)."""
    calls = {"n": 0}
    real_span = trace_mod._Span

    class Spy(real_span):
        def __init__(self, *a, **kw):
            calls["n"] += 1
            real_span.__init__(self, *a, **kw)

    monkeypatch.setattr(trace_mod, "_Span", Spy)
    tr = Tracer(enabled=False, sample_rate=0.01)
    spans = [tr.span(f"s{i}") for i in range(20)]
    assert calls["n"] == 0
    assert all(s is NOOP_SPAN for s in spans)
    assert tr.events() == [] and tr.sampled_out == 0


def test_enable_tracing_reconfigures_sample_rate():
    tr = get_tracer()
    was_enabled, was_rate = tr.enabled, tr.sample_rate
    try:
        from repro.obs import enable_tracing
        enable_tracing(sample_rate=0.5)
        assert tr.enabled and tr.sample_rate == 0.5
        for i in range(4):
            with tr.span(f"g{i}"):
                pass
        assert tr.sampled_out >= 2
    finally:
        tr.set_sample_rate(was_rate)
        tr.enabled = was_enabled
        tr.clear()
        tr.sampled_out = 0
