"""Spectral graph services: resistance, embeddings, harmonic interpolation.

The contracts under test, each against an exact host f64 oracle:

  * batched effective resistances match the dense-pinv quadratic form to
    <= 1e-4 relative error, land in a SINGLE scheduler flush group per
    (graph, config), and replay from the content-keyed result cache,
  * the Fiedler pair matches ``numpy.linalg.eigh`` sign/scale-invariantly
    with residual ||Lv - lambda v|| <= 1e-3, and k=3 embeddings recover
    the bottom nontrivial eigenvalues,
  * harmonic interpolation matches the dense Schur-complement solve,
  * the ``er_exact`` score stage round-trips through ``PipelineConfig``
    serialization and fingerprinting, and its resistances match pinv,
  * the endpoints work identically routed through a ``SolverDaemon``,
  * ``spectral.*`` spans and metrics surface in the telemetry plane.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import Graph, grid2d, mesh2d
from repro.obs import get_metrics, get_tracer
from repro.pipeline import Pipeline, PipelineConfig, pdgrass_config
from repro.serve import SolverDaemon
from repro.solver import SolverService
from repro.spectral import (ResistanceCache, effective_resistance,
                            exact_offtree_resistances, fiedler_vector,
                            harmonic_interpolate, label_propagation,
                            spectral_embedding)


def _dense_lap(g: Graph) -> np.ndarray:
    L = np.zeros((g.n, g.n))
    for s, d, w in zip(g.src, g.dst, g.weight):
        L[s, s] += w
        L[d, d] += w
        L[s, d] -= w
        L[d, s] -= w
    return L


def _pinv_resistances(L: np.ndarray, pairs: np.ndarray) -> np.ndarray:
    P = np.linalg.pinv(L)
    u, v = pairs[:, 0], pairs[:, 1]
    return P[u, u] + P[v, v] - 2 * P[u, v]


def _pairs(n: int, q: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, 3 * q)
    v = rng.integers(0, n, 3 * q)
    keep = u != v
    return np.stack([u[keep], v[keep]], axis=1)[:q]


@pytest.fixture(scope="module")
def svc():
    service = SolverService(alpha=0.1)
    g = mesh2d(8, 8, seed=0)
    h = service.register(g)
    return service, h, g


# -- effective resistance ----------------------------------------------------


def test_resistance_matches_dense_pinv(svc):
    service, h, g = svc
    pairs = _pairs(g.n, 24, seed=1)
    r = effective_resistance(service, h, pairs, tol=1e-7)
    r_exact = _pinv_resistances(_dense_lap(g), pairs)
    rel = np.abs(r - r_exact) / r_exact
    assert rel.max() <= 1e-4, f"max rel err {rel.max():.2e}"


def test_batched_queries_use_one_flush_group_and_cache(svc):
    service, h, g = svc
    pairs = _pairs(g.n, 40, seed=2)
    cache = ResistanceCache()
    before = service.stats()["scheduler"]["groups"]
    solved0 = service.metrics.snapshot().get(
        "spectral.resistance.solved_columns", 0)
    r = effective_resistance(service, h, pairs, tol=1e-6, chunk=8,
                             cache=cache)
    assert service.stats()["scheduler"]["groups"] - before == 1, (
        "chunked submission must resolve into one (graph, config) group")
    assert cache.misses == len(pairs)
    # duplicate queries share a solve column: one column per UNIQUE pair
    solved = service.metrics.snapshot()["spectral.resistance.solved_columns"]
    assert solved - solved0 == len(np.unique(pairs.min(1) * g.n
                                             + pairs.max(1)))
    # full replay: zero new solves, bitwise-identical answers
    r2 = effective_resistance(service, h, pairs, tol=1e-6, cache=cache)
    assert np.array_equal(r, r2)
    assert cache.hits >= len(pairs)
    assert service.metrics.snapshot().get(
        "spectral.resistance.solved_columns", 0) == solved
    # R_eff is symmetric: swapped pairs hit the same entries
    r3 = effective_resistance(service, h, pairs[:, ::-1], tol=1e-6,
                              cache=cache)
    assert np.array_equal(r, r3)


def test_resistance_rejects_malformed_pairs(svc):
    service, h, _ = svc
    with pytest.raises(ValueError, match="pairs"):
        effective_resistance(service, h, np.zeros((3, 4)))


# -- spectral embeddings -----------------------------------------------------


def test_fiedler_matches_eigh(svc):
    service, h, g = svc
    lam2, vec = fiedler_vector(service, h, tol=1e-4)
    L = _dense_lap(g)
    w, V = np.linalg.eigh(L)
    assert abs(lam2 - w[1]) <= 1e-3 * abs(w[1])
    # sign/scale-invariant vector comparison + the residual contract
    align = abs(float(vec @ V[:, 1]))
    assert align >= 1 - 1e-3, f"|cos| to eigh Fiedler vector {align:.6f}"
    resid = np.linalg.norm(L @ vec - lam2 * vec) / np.linalg.norm(vec)
    assert resid <= 1e-3
    assert abs(vec.mean()) <= 1e-5          # deflated against all-ones


def test_k3_embedding_recovers_bottom_eigenvalues(svc):
    service, h, g = svc
    emb = spectral_embedding(service, h, k=3, tol=1e-4)
    w = np.linalg.eigvalsh(_dense_lap(g))
    assert emb.converged
    np.testing.assert_allclose(emb.values, w[1:4], rtol=1e-3)
    # orthonormal, mean-zero columns
    G = emb.vectors.T @ emb.vectors
    np.testing.assert_allclose(G, np.eye(3), atol=1e-5)
    np.testing.assert_allclose(emb.vectors.mean(axis=0), 0, atol=1e-5)


# -- harmonic interpolation --------------------------------------------------


def _dense_harmonic(L: np.ndarray, bmask: np.ndarray,
                    xb: np.ndarray) -> np.ndarray:
    I = ~bmask
    x = np.zeros((L.shape[0],) + xb.shape[1:])
    x[bmask] = xb
    x[I] = np.linalg.solve(L[np.ix_(I, I)], -L[np.ix_(I, bmask)] @ xb)
    return x


def test_harmonic_matches_dense_schur(svc):
    _, _, g = svc
    rng = np.random.default_rng(3)
    bmask = np.zeros(g.n, dtype=bool)
    bmask[rng.choice(g.n, size=g.n // 5, replace=False)] = True
    xb = rng.standard_normal((int(bmask.sum()), 2))
    res = harmonic_interpolate(g, np.flatnonzero(bmask), xb, tol=1e-8)
    assert res.converged.all()
    x_exact = _dense_harmonic(_dense_lap(g), bmask, xb)
    assert np.abs(res.x - x_exact).max() <= 1e-6
    np.testing.assert_allclose(res.x[bmask], xb)  # boundary is clamped


def test_label_propagation_one_hot_scores(svc):
    _, _, g = svc
    rng = np.random.default_rng(4)
    labeled = rng.choice(g.n, size=g.n // 4, replace=False)
    labels = rng.integers(0, 3, labeled.shape[0])
    pred, scores = label_propagation(g, labeled, labels, tol=1e-6)
    assert pred.shape == (g.n,) and scores.shape == (g.n, 3)
    # harmonic average of one-hot boundary data: rows stay a distribution
    np.testing.assert_allclose(scores.sum(axis=1), 1.0, atol=1e-4)
    np.testing.assert_array_equal(pred[labeled], labels)


# -- er_exact score stage ----------------------------------------------------


def test_er_exact_config_roundtrip_and_fingerprint():
    cfg = pdgrass_config(alpha=0.05, score_mode="er_exact")
    d = cfg.to_dict()
    assert d["score"]["kind"] == "er_exact"
    back = PipelineConfig.from_dict(d)
    assert back == cfg and back.fingerprint() == cfg.fingerprint()
    # the solve tolerance is part of the artifact identity
    tighter = dataclasses.replace(
        cfg, score=dataclasses.replace(cfg.score, tol=1e-8))
    assert tighter.fingerprint() != cfg.fingerprint()
    assert (PipelineConfig.from_dict(tighter.to_dict()).fingerprint()
            == tighter.fingerprint())


def test_er_exact_pipeline_and_exact_resistances():
    g = grid2d(7, 6, seed=5)
    sp = Pipeline(pdgrass_config(alpha=0.1, score_mode="er_exact")).run(g)
    assert sp.stats["n_recovered"] > 0
    # the scores it ranked by: exact R_eff of the off-tree endpoints
    in_tree = np.asarray(sp.tree_mask)
    off = ~in_tree
    u, v = g.src[off], g.dst[off]
    r = exact_offtree_resistances(g, in_tree, u, v, tol=1e-8)
    r_exact = _pinv_resistances(_dense_lap(g),
                                np.stack([u, v], axis=1))
    rel = np.abs(r - r_exact) / r_exact
    assert rel.max() <= 1e-4, f"max rel err {rel.max():.2e}"


def test_er_exact_without_graph_context_raises():
    from repro.pipeline.stages import SCORE_STAGES
    from repro.pipeline.config import ScoreConfig
    import jax.numpy as jnp
    with pytest.raises(ValueError, match="graph context"):
        SCORE_STAGES["er_exact"](jnp.ones(3), jnp.ones(3),
                                 ScoreConfig(kind="er_exact"))


# -- daemon routing + telemetry ----------------------------------------------


def test_daemon_routed_spectral_queries(svc):
    service, h, g = svc
    pairs = _pairs(g.n, 12, seed=6)
    r_sync = effective_resistance(service, h, pairs, tol=1e-6,
                                  cache=ResistanceCache())
    with SolverDaemon(service, max_batch_delay_ms=10.0) as d:
        r_async = effective_resistance(d, h, pairs, tol=1e-6,
                                       cache=ResistanceCache(),
                                       result_timeout=60.0)
        lam2, _ = fiedler_vector(d, h, tol=1e-3, result_timeout=60.0)
    np.testing.assert_allclose(r_async, r_sync, rtol=1e-5, atol=1e-9)
    lam_sync, _ = fiedler_vector(service, h, tol=1e-3)
    assert abs(lam2 - lam_sync) <= max(1e-6, 1e-3 * abs(lam_sync))


def test_spectral_spans_and_metrics_surface(svc):
    service, h, g = svc
    tr = get_tracer()
    was = tr.enabled
    tr.enable()
    tr.clear()
    try:
        effective_resistance(service, h, _pairs(g.n, 6, seed=7),
                             cache=ResistanceCache())
        fiedler_vector(service, h, tol=1e-3)
        harmonic_interpolate(g, np.array([0, g.n - 1]),
                             np.array([0.0, 1.0]))
        names = set(tr.span_names())
    finally:
        tr.clear()
        tr.enabled = was
    assert {"spectral.resistance", "spectral.embedding",
            "spectral.harmonic"} <= names
    assert "solver.flush" in names          # the spans wrap real solves
    m = service.stats()["metrics"]
    assert m["spectral.resistance.queries"] >= 6
    assert m["spectral.resistance.solved_columns"] >= 6
    assert m["spectral.embedding.runs"] >= 1
    gm = get_metrics().snapshot()
    assert gm["spectral.harmonic.solves"] >= 1
