"""Sharded solve plane parity suite — 8 forced host devices in a subprocess
(XLA locks the device count at first init; the rest of the suite must see a
single device).

One subprocess covers the whole acceptance surface of the mesh knob:

  * sharded vs single-device batched PCG — re-based solutions within
    tolerance, per-column iteration counts within +-2;
  * sharded vs device hierarchy build — identical level sizes AND
    bit-identical per-level matchings/aggregations (the strict total order
    survives the collectives);
  * ``SolverService(mesh=...)`` end to end, including the v6 cache key
    separating mesh and single-device artifacts;
  * ``recover_mixed`` equivalence on a star-hub graph whose giant subtask
    exercises the inner round engine (static-shard-count path) on the same
    mesh the solve plane uses.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    if hasattr(jax.lax, "axis_size"):
        delattr(jax.lax, "axis_size")   # engines must not rely on it
    from repro.core import mesh2d, barabasi_albert, star_hub, prepare
    from repro.core.distributed import recover_mixed
    from repro.core.recovery import recover_serial
    from repro.launch.mesh import compat_make_mesh
    from repro.pipeline import pdgrass_config
    from repro.solver import SolverService, build_hierarchy

    assert jax.device_count() == 8
    mesh = compat_make_mesh((8,), ("data",))
    cfg = pdgrass_config(alpha=0.05, chunk=256)
    rebase = lambda x: np.asarray(x, np.float64) - np.asarray(x, np.float64)[0]

    for name, g in [("mesh2d-16", mesh2d(16, 16, seed=0)),
                    ("ba-300", barabasi_albert(300, 3, seed=1))]:
        # --- hierarchy build parity: sharded vs device contraction -------
        h_dev = build_hierarchy(g, config=cfg, contraction="device")
        h_sh = build_hierarchy(g, config=cfg, contraction="sharded",
                               mesh=mesh)
        assert h_sh.level_sizes == h_dev.level_sizes, (
            name, h_sh.level_sizes, h_dev.level_sizes)
        assert h_sh.depth == h_dev.depth
        for ld, ls in zip(h_dev.levels, h_sh.levels):
            assert np.array_equal(np.asarray(ld.agg), np.asarray(ls.agg)), (
                name, "aggregation drifted between device and sharded")

        # --- solve parity: SolverService(mesh=...) vs single-device ------
        svc_sh = SolverService(pipeline=cfg, mesh=mesh)
        svc_sd = SolverService(pipeline=cfg)
        h = svc_sh.register(g)
        svc_sd.register(h)
        rng = np.random.default_rng(7)
        B = rng.standard_normal((g.n, 4)).astype(np.float32)
        B -= B.mean(axis=0)
        r_sh = svc_sh.solve(h, B)
        r_sd = svc_sd.solve(h, B)
        assert r_sh.converged and r_sd.converged, name
        np.testing.assert_allclose(rebase(r_sh.x), rebase(r_sd.x),
                                   atol=1e-4)
        d_it = np.abs(np.asarray(r_sh.iters, np.int64)
                      - np.asarray(r_sd.iters, np.int64))
        assert d_it.max() <= 2, (name, r_sh.iters, r_sd.iters)

        # --- v6 cache keys: mesh and single-device never alias -----------
        assert svc_sh._key(h, cfg) != svc_sd._key(h, cfg)
        assert svc_sh.stats()["mesh"]["descriptor"] == ("mesh", "data", 8)
        assert svc_sh.stats()["hierarchy"]["contraction"] == "sharded"
        # warm path stays warm on the mesh too
        assert svc_sh.solve(h, B).cache == "mem"

    # --- unpreconditioned sharded PCG parity (isolates the matvec) -------
    g = mesh2d(12, 12, seed=3)
    svc_sh = SolverService(alpha=0.05, precond="none", mesh=mesh,
                           contraction="device")
    svc_sd = SolverService(alpha=0.05, precond="none")
    rng = np.random.default_rng(9)
    b = rng.standard_normal((g.n, 2)).astype(np.float32)
    b -= b.mean(axis=0)
    r_sh, r_sd = svc_sh.solve(g, b), svc_sd.solve(g, b)
    np.testing.assert_allclose(rebase(r_sh.x), rebase(r_sd.x), atol=1e-4)
    assert np.abs(np.asarray(r_sh.iters, np.int64)
                  - np.asarray(r_sd.iters, np.int64)).max() <= 2

    # --- recovery on the same mesh: giant subtask -> fixed inner engine --
    g = star_hub(300, extra=250, seed=5)
    prep = prepare(g, chunk=256)
    st = recover_mixed(prep, mesh, chunk=256, cutoff=50)
    np.testing.assert_array_equal(recover_serial(prep.problem), st)
    print("SHARDED-PLANE-OK")
""")


@pytest.mark.slow
def test_sharded_plane_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "SHARDED-PLANE-OK" in out.stdout, out.stdout + out.stderr
