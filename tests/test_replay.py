"""Deterministic open-loop replay: schedules, RHS generation, both drivers."""
import numpy as np
import pytest

from repro.core import grid2d
from repro.serve import (SolverDaemon, make_rhs, make_schedule, replay_daemon,
                         replay_sync)
from repro.solver import SolverService


def test_schedule_is_deterministic():
    a = make_schedule(32, 100.0, seed=7, tenants=(("p", 3.0), ("f", 1.0)))
    b = make_schedule(32, 100.0, seed=7, tenants=(("p", 3.0), ("f", 1.0)))
    assert a == b                                   # byte-for-byte identical
    c = make_schedule(32, 100.0, seed=8, tenants=(("p", 3.0), ("f", 1.0)))
    assert a != c
    assert a[0].t == 0.0                            # first arrival at t=0
    assert all(e2.t >= e1.t for e1, e2 in zip(a, a[1:]))
    assert {e.tenant for e in a} <= {"p", "f"}
    # weighted draw: the 3x tenant dominates
    assert sum(e.tenant == "p" for e in a) > sum(e.tenant == "f" for e in a)
    assert len({e.rhs_seed for e in a}) == 32       # unique per event


def test_schedule_validation():
    with pytest.raises(ValueError, match="n_requests"):
        make_schedule(0, 10.0)
    with pytest.raises(ValueError, match="rate_hz"):
        make_schedule(4, 0.0)


def test_make_rhs_deterministic_shapes():
    sched = make_schedule(4, 10.0, seed=1)
    b1 = make_rhs(25, sched[0])
    b2 = make_rhs(25, sched[0])
    np.testing.assert_array_equal(b1, b2)
    assert b1.shape == (25,) and b1.dtype == np.float32
    assert not np.array_equal(b1, make_rhs(25, sched[1]))
    wide = make_schedule(2, 10.0, seed=1, width=3)
    assert make_rhs(25, wide[0]).shape == (25, 3)


def test_replay_sync_and_daemon_agree_on_workload():
    """Both drivers over the same tiny schedule: zero errors, one latency
    sample per request, per-tenant sample counts match the schedule."""
    svc = SolverService(alpha=0.1)
    g = grid2d(5, 5, seed=0)
    h = svc.register(g)
    svc.warmup(h, widths=[1, 2, 4, 8])
    sched = make_schedule(8, 200.0, seed=3, tenants=(("p", 3.0), ("f", 1.0)))

    sync_rep = replay_sync(svc, h, sched)
    with SolverDaemon(svc, max_batch_delay_ms=10.0) as daemon:
        daemon_rep = replay_daemon(daemon, h, sched)

    for rep in (sync_rep, daemon_rep):
        assert rep.errors == 0
        assert rep.n_requests == 8
        assert len(rep.latencies_ms) == 8
        assert all(ms > 0 for ms in rep.latencies_ms)
        assert rep.p99_ms >= rep.p50_ms > 0
        assert rep.throughput_rps > 0
        by_tenant = {t: len(ls) for t, ls in rep.tenant_latencies_ms.items()}
        want = {}
        for e in sched:
            want[e.tenant] = want.get(e.tenant, 0) + 1
        assert by_tenant == want
        rec = rep.to_record()
        assert rec["p50_ms"] > 0 and rec["p99_ms"] >= rec["p50_ms"]
        assert set(rec["tenants"]) == set(want)
    assert sync_rep.mode == "sync" and daemon_rep.mode == "daemon"


def test_report_percentiles_empty_safe():
    from repro.serve import ReplayReport
    rep = ReplayReport(mode="sync", rate_hz=1.0, n_requests=0,
                       latencies_ms=[], duration_s=0.0)
    assert rep.p50_ms == 0.0 and rep.p99_ms == 0.0
    assert rep.throughput_rps == 0.0
    assert rep.to_record()["max_ms"] == 0.0
