"""PCG + sparsifier-quality tests (the paper's downstream metric)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (grid2d, mesh2d, barabasi_albert, pdgrass, fegrass,
                        pcg_host, pcg_jax, quality_iters)


def test_pcg_host_solves():
    g = grid2d(10, 10, seed=0)
    rng = np.random.default_rng(0)
    b = rng.standard_normal(g.n)
    b -= b.mean()
    res = pcg_host(g.laplacian(), b, tol=1e-8, maxiter=5000)
    assert res.converged
    L = g.laplacian()
    assert np.linalg.norm(L @ res.x - b) <= 1e-6 * np.linalg.norm(b)


def test_pcg_jax_matches_host():
    g = mesh2d(7, 7, seed=1)
    L = g.laplacian().toarray()
    A = jnp.asarray(L[1:, 1:])
    rng = np.random.default_rng(1)
    b = rng.standard_normal(g.n)
    b -= b.mean()
    x, it, relres = pcg_jax(A, jnp.asarray(b[1:]), tol=1e-6, maxiter=2000)
    assert float(relres) <= 1e-6
    res = pcg_host(g.laplacian(), b, tol=1e-6, maxiter=2000)
    assert abs(int(it) - res.iters) <= 2  # same algorithm, fp differences


def test_preconditioner_reduces_iterations():
    g = mesh2d(25, 25, seed=2)
    rng = np.random.default_rng(2)
    b = rng.standard_normal(g.n)
    b -= b.mean()
    base = pcg_host(g.laplacian(), b, tol=1e-3).iters
    sp = pdgrass(g, alpha=0.05)
    pre = pcg_host(g.laplacian(), b, sp.laplacian(), tol=1e-3).iters
    assert pre < base


def test_more_alpha_fewer_iters():
    """Paper: quality improves (iters drop) as alpha grows."""
    g = mesh2d(22, 22, seed=3)
    iters = [quality_iters(g, pdgrass(g, alpha=a)) for a in (0.02, 0.10)]
    assert iters[1] <= iters[0]


def test_pcg_jax_with_chol_preconditioner():
    g = grid2d(9, 9, seed=4)
    sp = pdgrass(g, alpha=0.10)
    A = jnp.asarray(g.laplacian().toarray()[1:, 1:])
    M = np.asarray(sp.laplacian().toarray()[1:, 1:])
    chol = jnp.asarray(np.linalg.cholesky(M))
    rng = np.random.default_rng(4)
    b = rng.standard_normal(g.n - 1)
    x, it_pre, _ = pcg_jax(A, jnp.asarray(b), chol, tol=1e-5, maxiter=2000)
    _, it_raw, _ = pcg_jax(A, jnp.asarray(b), None, tol=1e-5, maxiter=2000)
    assert int(it_pre) < int(it_raw)
    assert np.allclose(np.asarray(A @ x), b, atol=1e-4 * np.linalg.norm(b))
