"""Serving request plane: GraphStore/GraphHandle memoized fingerprints,
SolveTicket futures, per-request PipelineConfig overrides and the
mixed-config scheduler, warmup prefetch, bounded disk cache tier."""
import os

import numpy as np
import pytest

from repro.core import mesh2d
from repro.core.graph import build_graph
from repro.pipeline import (PipelineConfig, TreeConfig, fegrass_config,
                            pdgrass_config)
from repro.solver import (GraphHandle, GraphStore, LRUCache, SolveRequest,
                          SolverService, graph_fingerprint)
from repro.solver import cache as cache_mod


def _rhs(g, k=1, seed=0):
    rng = np.random.default_rng(seed)
    b = rng.standard_normal((g.n, k)).astype(np.float32)
    return b - b.mean(axis=0)


def _rebase(x):
    x = np.asarray(x, dtype=np.float64)
    return x - x[0]


def _copy_graph(g):
    """A structurally identical but distinct Graph object."""
    return build_graph(g.n, g.src.copy(), g.dst.copy(), g.weight.copy())


# -- fingerprint memoization -------------------------------------------------

def test_content_hash_computed_once_per_graph_object():
    g = mesh2d(9, 9, seed=1)
    before = cache_mod.HASH_EVENTS
    fp1 = graph_fingerprint(g)
    fp2 = graph_fingerprint(g, extra=("alpha", 0.05))
    fp3 = graph_fingerprint(g, extra=("alpha", 0.1))
    assert cache_mod.HASH_EVENTS == before + 1  # one O(m) pass, three keys
    assert len({fp1, fp2, fp3}) == 3


def test_store_dedupes_by_content_and_handles_key_dicts():
    g = mesh2d(8, 8, seed=2)
    store = GraphStore()
    h1 = store.register(g)
    h2 = store.register(g)                  # same object: memo lookup
    h3 = store.register(_copy_graph(g))     # equal content: same handle
    assert h1 is h2 and h1 is h3
    assert len(store) == 1
    assert g in store and h1 in store and h1.fingerprint in store
    assert store.get(h1.fingerprint) is h1
    other = store.register(mesh2d(8, 8, seed=3))
    assert other != h1 and len(store) == 2
    assert len({h1, h3, other}) == 2        # handles hash by fingerprint
    with pytest.raises(TypeError, match="Graph or GraphHandle"):
        store.register("not a graph")


def test_registered_traffic_never_rehashes():
    g = mesh2d(10, 10, seed=4)
    svc = SolverService(alpha=0.05, precond="none")
    h = svc.register(g)
    b = _rhs(g, seed=5)[:, 0]
    svc.solve(h, b)
    before = cache_mod.HASH_EVENTS
    svc.submit(SolveRequest(graph=h, b=b))
    svc.submit(SolveRequest(graph=h, b=b))
    svc.flush()
    svc.solve(h, b)
    assert cache_mod.HASH_EVENTS == before
    assert svc.stats()["store"]["graphs"] == 1


def test_fingerprinted_arrays_are_frozen_against_silent_mutation():
    g = mesh2d(8, 8, seed=22)
    GraphStore().register(g)
    # the memoized digest must never desync from the content: the hashed
    # arrays become read-only, so an in-place edit raises instead of
    # silently cache-hitting the wrong hierarchy
    with pytest.raises(ValueError, match="read-only"):
        g.weight[0] = 99.0
    assert g.weight.flags.writeable is False


def test_store_counts_only_its_own_hash_events():
    g = mesh2d(8, 8, seed=23)
    store = GraphStore()
    store.register(g)
    store.register(g)
    store.register(_copy_graph(g))
    assert store.stats == {"graphs": 1, "hash_events": 2}  # g + its copy
    other = GraphStore()
    other.register(store.get(content_fingerprint_of(g)))
    assert other.hash_events == 0          # handle path: no hashing


def content_fingerprint_of(g):
    return g.__dict__["_content_fp"]


# -- tickets -----------------------------------------------------------------

def test_tickets_are_stable_across_flushes_and_resolve_out_of_order():
    g = mesh2d(9, 9, seed=6)
    svc = SolverService(alpha=0.05, precond="none")
    h = svc.register(g)
    b = _rhs(g, k=3, seed=7)
    t0 = svc.submit(SolveRequest(graph=h, b=b[:, 0]))
    out0 = svc.flush()
    t1 = svc.submit(SolveRequest(graph=h, b=b[:, 1]))
    t2 = svc.submit(SolveRequest(graph=h, b=b[:, 2]))
    out1 = svc.flush()
    # v1 handed out per-flush list indices (t1 would collide with t0);
    # v2 ids are service-wide monotonic
    assert (int(t0), int(t1), int(t2)) == (0, 1, 2)
    assert t0 in out0 and t1 in out1 and t2 in out1
    # futures resolve in any order, long after their flush
    assert t2.done() and t1.done()
    r2, r1 = t2.result(), t1.result()
    assert r1.converged and r2.converged
    np.testing.assert_array_equal(r1.x, out1[t1].x)


def test_ticket_result_triggers_flush_lazily():
    g = mesh2d(9, 9, seed=8)
    svc = SolverService(alpha=0.05, precond="none")
    t = svc.submit(SolveRequest(graph=g, b=_rhs(g, seed=9)[:, 0]))
    assert not t.done()
    res = t.result()                        # flushes the owning service
    assert t.done() and res.converged
    assert svc.stats()["scheduler"]["pending"] == 0


def test_v1_int_indexing_still_works():
    g = mesh2d(9, 9, seed=10)
    svc = SolverService(alpha=0.05, precond="none")
    t = svc.submit(SolveRequest(graph=g, b=_rhs(g, seed=11)[:, 0]))
    out = svc.flush()
    assert out[t].converged                 # ticket object as key
    assert out[int(t)].converged            # bare int (v1 callers)


# -- request validation ------------------------------------------------------

def test_non_finite_rhs_is_rejected_with_clear_error():
    g = mesh2d(8, 8, seed=12)
    svc = SolverService(alpha=0.05)
    b = _rhs(g, seed=13)[:, 0]
    for bad in (np.nan, np.inf, -np.inf):
        poisoned = b.copy()
        poisoned[3] = bad
        with pytest.raises(ValueError, match="non-finite"):
            svc.submit(SolveRequest(graph=g, b=poisoned))
        with pytest.raises(ValueError, match="non-finite"):
            svc.solve(g, poisoned)


def test_bad_pipeline_override_is_rejected():
    g = mesh2d(8, 8, seed=14)
    svc = SolverService(alpha=0.05)
    b = _rhs(g, seed=15)[:, 0]
    with pytest.raises(TypeError, match="PipelineConfig"):
        svc.submit(SolveRequest(graph=g, b=b, pipeline="pdgrass"))
    bogus = PipelineConfig(tree=TreeConfig(kind="no_such_stage"))
    with pytest.raises(ValueError, match="unknown tree stage"):
        svc.submit(SolveRequest(graph=g, b=b, pipeline=bogus))


def test_f64_rhs_overflowing_f32_is_rejected():
    g = mesh2d(8, 8, seed=33)
    svc = SolverService(alpha=0.05)
    b = np.zeros(g.n, np.float64)
    b[0], b[1] = 1e300, -1e300      # finite in f64, inf after the f32 cast
    with pytest.raises(ValueError, match="f32"):
        svc.solve(g, b)


# -- mixed-config scheduler --------------------------------------------------


def test_group_failure_is_isolated_to_its_config_group(monkeypatch):
    g = mesh2d(10, 10, seed=30)
    pd = pdgrass_config(alpha=0.05, chunk=128)
    fe = fegrass_config(alpha=0.05, chunk=128)
    svc = SolverService(pipeline=pd)
    h = svc.register(g)
    boom = RuntimeError("hierarchy build exploded")
    real_artifacts = svc.artifacts

    def flaky(graph, key=None, pipeline=None):
        if pipeline is not None and pipeline.recovery.kind == "multipass":
            raise boom
        return real_artifacts(graph, key=key, pipeline=pipeline)

    monkeypatch.setattr(svc, "artifacts", flaky)
    b = _rhs(g, k=2, seed=31)
    t_ok = svc.submit(SolveRequest(graph=h, b=b[:, 0]))
    t_bad = svc.submit(SolveRequest(graph=h, b=b[:, 1], pipeline=fe))
    out = svc.flush()
    # the pd group solved and resolved despite the fe group's failure
    assert t_ok in out and out[t_ok].converged and t_ok.result().converged
    # the fe group's ticket settled with the failure, resolvable any time
    assert t_bad not in out and t_bad.done()
    assert t_bad.error() is boom
    with pytest.raises(RuntimeError, match="exploded"):
        t_bad.result()
    sched = svc.stats()["scheduler"]
    assert sched["group_failures"] == 1 and sched["requests_solved"] == 1


def test_solve_surfaces_its_groups_failure(monkeypatch):
    g = mesh2d(9, 9, seed=32)
    svc = SolverService(alpha=0.05)

    def explode(graph, key=None, pipeline=None):
        raise RuntimeError("no artifacts for you")

    monkeypatch.setattr(svc, "artifacts", explode)
    with pytest.raises(RuntimeError, match="no artifacts"):
        svc.solve(g, _rhs(g, seed=33)[:, 0])

def test_mixed_config_flush_groups_and_matches_single_config_services():
    g = mesh2d(12, 12, seed=16)
    pd = pdgrass_config(alpha=0.05, chunk=128)
    fe = fegrass_config(alpha=0.05, chunk=128)
    b = _rhs(g, k=2, seed=17)
    svc = SolverService(pipeline=pd)
    h = svc.register(g)
    assert svc._key(h, pd) != svc._key(h, fe)   # distinct cache keys

    t_pd = svc.submit(SolveRequest(graph=h, b=b[:, 0]))
    t_fe = svc.submit(SolveRequest(graph=h, b=b[:, 1], pipeline=fe))
    out = svc.flush()
    # two (graph, config) groups: both built this flush, separately
    assert svc.cache.stats["misses"] == 2
    assert svc.stats()["scheduler"]["groups"] == 2
    assert out[t_pd].config != out[t_fe].config
    assert out[t_pd].converged and out[t_fe].converged

    # equivalence: each request got the same answer a dedicated
    # single-config service produces
    r_pd = SolverService(pipeline=pd).solve(g, b[:, 0])
    r_fe = SolverService(pipeline=fe).solve(g, b[:, 1])
    np.testing.assert_allclose(_rebase(out[t_pd].x), _rebase(r_pd.x),
                               atol=1e-8)
    np.testing.assert_allclose(_rebase(out[t_fe].x), _rebase(r_fe.x),
                               atol=1e-8)
    np.testing.assert_array_equal(out[t_pd].iters, r_pd.iters)
    np.testing.assert_array_equal(out[t_fe].iters, r_fe.iters)

    # repeat flush: 100% artifact cache hit, zero re-fingerprinting
    before = cache_mod.HASH_EVENTS
    t3 = svc.submit(SolveRequest(graph=h, b=b[:, 0]))
    t4 = svc.submit(SolveRequest(graph=h, b=b[:, 1], pipeline=fe))
    out2 = svc.flush()
    assert out2[t3].cache == "mem" and out2[t4].cache == "mem"
    assert svc.cache.stats["misses"] == 2       # nothing rebuilt
    assert cache_mod.HASH_EVENTS == before
    counts = svc.stats()["solves_by_config"]
    assert counts == {pd.digest(): 2, fe.digest(): 2}


def test_warmup_prefetches_artifacts_for_each_config():
    g = mesh2d(10, 10, seed=18)
    pd = pdgrass_config(alpha=0.05, chunk=128)
    fe = fegrass_config(alpha=0.05, chunk=128)
    svc = SolverService(pipeline=pd)
    h = svc.register(g)
    sources = svc.warmup(h, configs=[pd, fe])
    assert sources == {pd.digest(): "miss", fe.digest(): "miss"}
    # traffic after warmup only ever hits memory
    b = _rhs(g, k=2, seed=19)
    t1 = svc.submit(SolveRequest(graph=h, b=b[:, 0]))
    t2 = svc.submit(SolveRequest(graph=h, b=b[:, 1], pipeline=fe))
    out = svc.flush()
    assert out[t1].cache == "mem" and out[t2].cache == "mem"
    assert svc.warmup(h, configs=[fe]) == {fe.digest(): "mem"}


def test_config_digest_is_stable_and_discriminating():
    pd, fe = pdgrass_config(alpha=0.05), fegrass_config(alpha=0.05)
    assert pd.digest() == pdgrass_config(alpha=0.05).digest()
    assert pd.digest() != fe.digest()
    assert pd.digest() != pdgrass_config(alpha=0.06).digest()
    assert len(pd.digest()) == 12


# -- slot-batch padding invariant --------------------------------------------

def test_padded_batch_columns_are_inert_by_construction():
    """Padding columns carry tol=inf / maxiter=0, so they can never drive
    the batched PCG loop (0 iterations from the start) nor the refinement
    pass — independent of the zero-RHS short-circuit.  Previously pads
    inherited the group's *strictest* tol and *largest* maxiter, which was
    only benign by accident."""
    g = mesh2d(9, 9, seed=40)
    svc = SolverService(alpha=0.05, precond="none")
    h = svc.register(g)
    inner = {}
    real_solver_for = svc._solver_for

    def spying(key, artifacts):
        fn = real_solver_for(key, artifacts)

        def spy(b, tol=1e-5, maxiter=2000):
            res = fn(b, tol=tol, maxiter=maxiter)
            # capture the FIRST (main) solve call; refinement passes reuse
            # the closure with per-column remaining budgets
            inner.setdefault("tol", np.asarray(tol))
            inner.setdefault("maxiter", np.asarray(maxiter))
            inner.setdefault("iters", np.asarray(res.iters))
            return res

        return spy

    svc._solver_for = spying
    b = _rhs(g, k=3, seed=41)
    # three 1-column requests with distinct contracts -> k=3, k_pad=4
    tickets = [svc.submit(SolveRequest(graph=h, b=b[:, j], tol=t, maxiter=m))
               for j, (t, m) in enumerate([(1e-5, 2000), (1e-3, 50),
                                           (1e-6, 3000)])]
    out = svc.flush()
    assert all(out[t].converged for t in tickets)
    # the real columns kept their own contracts ...
    assert np.allclose(inner["tol"][:3],
                       np.maximum([1e-5, 1e-3, 1e-6], 1e-5))
    assert list(inner["maxiter"][:3]) == [2000, 50, 3000]
    # ... and the padding column is inert: tol=inf, maxiter=0, 0 iterations
    assert np.isinf(inner["tol"][3])
    assert inner["maxiter"][3] == 0
    assert inner["iters"][3] == 0


# -- bounded disk tier -------------------------------------------------------

def _disk_keys(path):
    return sorted(f[:-len(".pkl")] for f in os.listdir(path)
                  if f.endswith(".pkl"))


def test_mem_lru_eviction_order_is_recency_not_insertion():
    cache = LRUCache(capacity=2)
    cache.put("a", 1)
    cache.put("b", 2)
    assert cache.get("a") == (1, "mem")     # refresh a's recency
    cache.put("c", 3)                       # evicts b, the LRU entry
    assert cache.get("b") == (None, "miss")
    assert cache.get("a") == (1, "mem") and cache.get("c") == (3, "mem")
    assert cache.evictions == 1


def test_disk_round_trip_and_atomic_writes(tmp_path):
    cache = LRUCache(capacity=1, disk_dir=str(tmp_path))
    payload = {"idx": np.arange(5), "val": np.ones(3)}
    cache.put("k0", payload)
    cache.put("k1", 1)                      # k0 falls out of memory
    got, src = cache.get("k0")
    assert src == "disk"
    np.testing.assert_array_equal(got["idx"], payload["idx"])
    # atomic-write path: only whole pickles in the dir, never .tmp litter
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
    # a torn concurrent write (leftover tmp) is invisible to the cache
    (tmp_path / "torn.tmp").write_bytes(b"\x80garbage")
    fresh = LRUCache(capacity=1, disk_dir=str(tmp_path))
    assert fresh.get("k1") == (1, "disk")
    assert "disk_entries" in fresh.stats and fresh.stats["disk_entries"] == 2
    # a torn/concurrently-evicted pickle reads as a miss, never a crash
    (tmp_path / "torn2.pkl").write_bytes(b"\x80garbage")
    assert fresh.get("torn2") == (None, "miss")


def test_disk_tier_caps_entries_with_oldest_mtime_eviction(tmp_path):
    cache = LRUCache(capacity=8, disk_dir=str(tmp_path), disk_max_entries=2)
    cache.put("k0", 0)
    cache.put("k1", 1)
    # deterministic ages regardless of filesystem timestamp resolution
    os.utime(tmp_path / "k0.pkl", (100, 100))
    os.utime(tmp_path / "k1.pkl", (200, 200))
    cache.put("k2", 2)                      # over cap: k0 (oldest) evicted
    assert _disk_keys(tmp_path) == ["k1", "k2"]
    assert cache.disk_evictions == 1
    stats = cache.stats
    assert stats["disk_entries"] == 2 and stats["disk_max_entries"] == 2


def test_disk_hit_refreshes_recency_for_eviction(tmp_path):
    cache = LRUCache(capacity=1, disk_dir=str(tmp_path), disk_max_entries=2)
    cache.put("k0", 0)
    cache.put("k1", 1)
    os.utime(tmp_path / "k0.pkl", (100, 100))
    os.utime(tmp_path / "k1.pkl", (200, 200))
    assert cache.get("k0")[1] == "disk"     # refreshes k0's mtime to now
    cache.put("k2", 2)                      # k1 is now the oldest: evicted
    assert _disk_keys(tmp_path) == ["k0", "k2"]


def test_disk_tier_caps_bytes_but_never_evicts_fresh_write(tmp_path):
    cache = LRUCache(capacity=8, disk_dir=str(tmp_path), disk_max_bytes=1)
    big = np.zeros(1024)
    cache.put("k0", big)                    # alone over the cap: kept
    assert _disk_keys(tmp_path) == ["k0"]
    os.utime(tmp_path / "k0.pkl", (100, 100))
    cache.put("k1", big)                    # k0 evicted, k1 (fresh) kept
    assert _disk_keys(tmp_path) == ["k1"]
    assert cache.stats["disk_bytes"] > 0


def test_service_surfaces_disk_caps_in_stats(tmp_path):
    g = mesh2d(8, 8, seed=20)
    svc = SolverService(alpha=0.05, precond="none", disk_dir=str(tmp_path),
                        disk_max_entries=4)
    svc.solve(g, _rhs(g, seed=21)[:, 0])
    stats = svc.stats()
    assert stats["cache"]["disk_max_entries"] == 4
    assert stats["cache"]["disk_entries"] == 1


def test_stale_ticket_result_raises_clear_error_without_flushing_others():
    """Regression: ``result()`` on an unresolved ticket that is NOT in its
    service's pending queue used to flush anyway — pointlessly solving
    unrelated pending work and then failing with a baffling "was it
    submitted to this service?" message.  It must diagnose the stale
    ticket immediately and leave other queued work untouched."""
    g = mesh2d(9, 9, seed=20)
    svc = SolverService(alpha=0.05, precond="none")
    h = svc.register(g)
    b = _rhs(g, k=2, seed=21)
    stale = svc.submit(SolveRequest(graph=h, b=b[:, 0]))
    # Simulate the race the bug shipped under: the queue drained without
    # this ticket ever resolving (a consumer dropped its entry).
    with svc._lock:
        svc._pending.clear()
        svc._pending_columns = 0
    live = svc.submit(SolveRequest(graph=h, b=b[:, 1]))
    flushes = svc.stats()["scheduler"]["flushes"]
    with pytest.raises(RuntimeError, match="stale .*or belongs to another"):
        stale.result()
    assert not stale.done()
    # the diagnosis came WITHOUT flushing the unrelated live ticket
    assert svc.stats()["scheduler"]["flushes"] == flushes
    assert not live.done()
    assert live.result().converged          # the live path is unharmed
