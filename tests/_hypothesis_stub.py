"""Deterministic stand-in for the `hypothesis` API surface this suite uses.

Activated by conftest.py ONLY when the real hypothesis is not installed
(hermetic containers); `pip install hypothesis` always wins.  Properties are
exercised over `max_examples` seeded draws, so the property tests still run
many concrete cases — they just lose hypothesis's adaptive shrinking.
"""
from __future__ import annotations

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])


def floats(min_value, max_value):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def booleans():
    return _Strategy(lambda rng: bool(rng.integers(2)))


class strategies:  # accessed as `from hypothesis import strategies as st`
    integers = staticmethod(integers)
    sampled_from = staticmethod(sampled_from)
    floats = staticmethod(floats)
    booleans = staticmethod(booleans)


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def deco(fn):
        fn._stub_settings = {"max_examples": max_examples}
        return fn
    return deco


def given(**named_strategies):
    def deco(fn):
        n = getattr(fn, "_stub_settings", {}).get("max_examples", 20)

        # NOTE: no functools.wraps — pytest follows __wrapped__ to the inner
        # signature and would look for fixtures named after the strategy
        # kwargs.  The wrapper must present a zero-arg signature.
        def wrapper():
            for i in range(n):
                rng = np.random.default_rng(0xC0FFEE + i)
                drawn = {k: s.example(rng)
                         for k, s in named_strategies.items()}
                fn(**drawn)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco
