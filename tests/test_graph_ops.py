"""Unit tests for the reusable device primitives in repro.core.graph_ops:
segment argmax (ties, empties, masked), handshake accepts, pointer-jumping
convergence, label compaction, propose/accept matching vs the sequential
oracle, and segmented edge relabel+coalesce."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import barabasi_albert, build_graph, mesh2d, star_hub
from repro.core.graph_ops import (coalesce_edges, compact_labels, handshake,
                                  pointer_jump, propose_accept_matching,
                                  segment_argmax)
from repro.solver.hierarchy import heavy_edge_matching


# -- segment_argmax ----------------------------------------------------------

def test_segment_argmax_basic_and_ties():
    vals = jnp.asarray([1.0, 5.0, 5.0, 2.0, 7.0])
    segs = jnp.asarray([0, 0, 0, 1, 1])
    pick, best = segment_argmax(vals, segs, 3)
    # segment 0: two elements tie at 5.0 -> the smaller element id wins
    assert pick.tolist() == [1, 4, 5]          # 5 == sentinel (len(vals))
    assert best.tolist()[:2] == [5.0, 7.0]
    assert np.isneginf(np.asarray(best)[2])    # empty segment


def test_segment_argmax_custom_element_ids_and_sentinel():
    # duplicated entries (both directions of an edge) resolve to one winner
    vals = jnp.asarray([3.0, 9.0, 3.0, 9.0])
    segs = jnp.asarray([0, 0, 1, 1])
    eids = jnp.asarray([0, 1, 0, 1], dtype=jnp.int32)
    pick, _ = segment_argmax(vals, segs, 2, element_ids=eids, sentinel=7)
    assert pick.tolist() == [1, 1]
    # all -inf (masked-out) segment gets the sentinel
    pick, _ = segment_argmax(jnp.asarray([-jnp.inf, -jnp.inf]),
                             jnp.asarray([0, 0]), 2, sentinel=9)
    assert pick.tolist() == [9, 9]


def test_segment_argmax_sentinel_below_element_ids():
    # a sentinel smaller than the ids (-1 "no pick") must not shadow winners
    vals = jnp.asarray([3.0, 9.0])
    segs = jnp.asarray([0, 0])
    eids = jnp.asarray([5, 6], dtype=jnp.int32)
    pick, best = segment_argmax(vals, segs, 2, element_ids=eids, sentinel=-1)
    assert pick.tolist() == [6, -1]            # winner id 6; empty seg -> -1
    assert best.tolist()[0] == 9.0


def test_segment_argmax_drops_out_of_range_segments():
    vals = jnp.asarray([4.0, 8.0, 6.0])
    segs = jnp.asarray([0, -1, 1])             # -1 = padding, must be dropped
    pick, best = segment_argmax(vals, segs, 2)
    assert pick.tolist() == [0, 2]
    assert best.tolist() == [4.0, 6.0]


# -- handshake ---------------------------------------------------------------

def test_handshake_requires_mutual_proposal():
    src = jnp.asarray([0, 1, 2])
    dst = jnp.asarray([1, 2, 3])
    # 0 and 1 both propose edge 0; 2 proposes edge 2 but 3 proposes nothing
    prop = jnp.asarray([0, 0, 2, 3])
    assert handshake(prop, src, dst).tolist() == [True, False, False]


# -- pointer_jump ------------------------------------------------------------

def test_pointer_jump_collapses_chains_and_keeps_roots():
    # chain 4 -> 3 -> 2 -> 1 -> 0, plus two self-rooted singletons
    parent = jnp.asarray([0, 0, 1, 2, 3, 5, 6])
    roots = pointer_jump(parent)
    assert roots.tolist() == [0, 0, 0, 0, 0, 5, 6]
    flat = jnp.asarray([1, 1, 1])
    assert pointer_jump(flat).tolist() == [1, 1, 1]


# -- compact_labels ----------------------------------------------------------

def test_compact_labels_dense_and_order_preserving():
    labels = jnp.asarray([7, 2, 7, 9, 2])
    dense, k = compact_labels(labels, 10)
    assert int(k) == 3
    assert dense.tolist() == [1, 0, 1, 2, 0]   # 2 < 7 < 9 order preserved


def test_compact_labels_singleton_and_uniform():
    dense, k = compact_labels(jnp.asarray([4]), 8)
    assert (dense.tolist(), int(k)) == ([0], 1)
    dense, k = compact_labels(jnp.asarray([3, 3, 3]), 5)
    assert (dense.tolist(), int(k)) == ([0, 0, 0], 1)


# -- propose_accept_matching -------------------------------------------------

@pytest.mark.parametrize("make", [
    lambda: mesh2d(13, 13, seed=2),
    lambda: barabasi_albert(250, 3, seed=3),
    lambda: star_hub(200, extra=150, seed=5),
])
def test_matching_equals_sequential_greedy_oracle(make):
    g = make()
    mate = np.asarray(propose_accept_matching(
        g.n, jnp.asarray(g.src), jnp.asarray(g.dst), jnp.asarray(g.weight)))
    np.testing.assert_array_equal(mate, heavy_edge_matching(g))


def test_matching_tie_break_matches_oracle_on_equal_weights():
    # every weight identical: the (weight, -edge id) order is pure edge id
    g = build_graph(6, [0, 1, 2, 3, 4, 0], [1, 2, 3, 4, 5, 5],
                    np.ones(6, np.float32))
    mate = np.asarray(propose_accept_matching(
        g.n, jnp.asarray(g.src), jnp.asarray(g.dst), jnp.asarray(g.weight)))
    np.testing.assert_array_equal(mate, heavy_edge_matching(g))


def test_matching_is_valid_and_maximal():
    g = mesh2d(9, 9, seed=7)
    mate = np.asarray(propose_accept_matching(
        g.n, jnp.asarray(g.src), jnp.asarray(g.dst), jnp.asarray(g.weight)))
    matched = mate >= 0
    # involution: mate[mate[v]] == v for matched vertices
    np.testing.assert_array_equal(mate[mate[matched]],
                                  np.flatnonzero(matched))
    # matched pairs are actual edges
    edges = set(zip(g.src.tolist(), g.dst.tolist()))
    for v in np.flatnonzero(matched & (np.arange(g.n) < mate)):
        assert (v, mate[v]) in edges
    # maximal: no edge has both endpoints free
    free = ~matched
    assert not np.any(free[g.src] & free[g.dst])


# -- coalesce_edges ----------------------------------------------------------

def _coalesce_ref(src, dst, w, labels):
    agg = {}
    for s, d, wt in zip(labels[src], labels[dst], w):
        if s == d:
            continue
        key = (min(s, d), max(s, d))
        agg[key] = agg.get(key, 0.0) + float(wt)
    return agg


def test_coalesce_matches_reference_on_random_labeling():
    g = mesh2d(8, 8, seed=4)
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 20, size=g.n)
    csrc, cdst, cw, mc = coalesce_edges(
        jnp.asarray(g.src), jnp.asarray(g.dst), jnp.asarray(g.weight),
        jnp.asarray(labels), 20)
    mc = int(mc)
    got = {(int(s), int(d)): float(w)
           for s, d, w in zip(np.asarray(csrc[:mc]), np.asarray(cdst[:mc]),
                              np.asarray(cw[:mc]))}
    want = _coalesce_ref(g.src, g.dst, g.weight, labels)
    assert set(got) == set(want)
    for key in want:
        assert np.isclose(got[key], want[key], rtol=1e-5)
    # canonical: src < dst, sorted lexicographically
    pairs = list(got)
    assert all(s < d for s, d in pairs)
    assert pairs == sorted(pairs)


def test_coalesce_all_intra_cluster_yields_empty():
    g = mesh2d(4, 4, seed=1)
    labels = jnp.zeros((g.n,), jnp.int32)      # one big cluster
    _, _, cw, mc = coalesce_edges(
        jnp.asarray(g.src), jnp.asarray(g.dst), jnp.asarray(g.weight),
        labels, 1)
    assert int(mc) == 0
    assert float(jnp.abs(cw).sum()) == 0.0
