"""Distributed recovery tests — run in a subprocess with 8 host devices
(XLA locks the device count at first init, and the rest of the suite must
see a single device)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from repro.core import grid2d, barabasi_albert, star_hub, prepare
    from repro.core.recovery import recover_serial
    from repro.core.distributed import recover_mixed, partition_subtasks
    from repro.launch.mesh import compat_make_mesh

    mesh = compat_make_mesh((8,), ("data",))
    cases = [
        ("grid", grid2d(15, 15, seed=1), None),
        ("ba", barabasi_albert(400, 3, seed=3), None),
        ("star-giant", star_hub(300, extra=250, seed=5), 50),
    ]
    for name, g, cutoff in cases:
        prep = prepare(g, chunk=256)
        st_serial = recover_serial(prep.problem)
        st_mixed = recover_mixed(prep, mesh, chunk=256, cutoff=cutoff)
        assert np.array_equal(st_serial, st_mixed), name
        shard_of, giants, load = partition_subtasks(
            prep.subtask_sizes, 8, cutoff=cutoff)
        if name == "star-giant":
            assert len(giants) >= 1      # hub subtask went to the inner engine
    print("DISTRIBUTED-OK")
""")


@pytest.mark.slow
def test_mixed_distributed_equals_serial():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "DISTRIBUTED-OK" in out.stdout, out.stdout + out.stderr
