"""Distributed recovery tests.

The 8-device equivalence suite runs in a subprocess (XLA locks the device
count at first init, and the rest of the suite must see a single device);
the regression tests for the inner engine's static shard count and the
per-dtype pad fills run in-process on a 1-device mesh.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import grid2d, prepare
from repro.core.distributed import (build_outer_shards, pad_fill_value,
                                    partition_subtasks, recover_mixed)
from repro.core.recovery import recover_serial
from repro.launch.mesh import compat_make_mesh

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    # regression guard: the engines must never rely on jax.lax.axis_size
    # (the shard count is passed statically from the mesh) — delete it so
    # any reintroduced dynamic-axis-size fallback fails loudly here
    if hasattr(jax.lax, "axis_size"):
        delattr(jax.lax, "axis_size")
    from repro.core import grid2d, barabasi_albert, star_hub, prepare
    from repro.core.recovery import recover_serial
    from repro.core.distributed import recover_mixed, partition_subtasks
    from repro.launch.mesh import compat_make_mesh

    mesh = compat_make_mesh((8,), ("data",))
    cases = [
        ("grid", grid2d(15, 15, seed=1), None),
        ("ba", barabasi_albert(400, 3, seed=3), None),
        ("star-giant", star_hub(300, extra=250, seed=5), 50),
    ]
    for name, g, cutoff in cases:
        prep = prepare(g, chunk=256)
        st_serial = recover_serial(prep.problem)
        st_mixed = recover_mixed(prep, mesh, chunk=256, cutoff=cutoff)
        assert np.array_equal(st_serial, st_mixed), name
        shard_of, giants, load = partition_subtasks(
            prep.subtask_sizes, 8, cutoff=cutoff)
        if name == "star-giant":
            assert len(giants) >= 1      # hub subtask went to the inner engine
    print("DISTRIBUTED-OK")
""")


@pytest.mark.slow
def test_mixed_distributed_equals_serial():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "DISTRIBUTED-OK" in out.stdout, out.stdout + out.stderr


# -- inner engine: static shard count ----------------------------------------

def test_inner_engine_works_without_jax_lax_axis_size(monkeypatch):
    """Regression for the n_sh derivation bug: the engine used a
    ``jax.lax.psum(1, axis)`` fallback on jax builds without
    ``jax.lax.axis_size``, which yields a *traced* value — and
    ``jnp.arange(n_sh)`` then fails to trace inside the round loop.  The
    shard count now arrives statically from the ``recover_inner`` wrapper
    (it knows ``mesh.shape[axis]``), so the engine must run with the
    attribute entirely absent."""
    monkeypatch.delattr(jax.lax, "axis_size", raising=False)
    assert not hasattr(jax.lax, "axis_size")
    g = grid2d(9, 9, seed=2)
    prep = prepare(g, chunk=128)
    mesh = compat_make_mesh((1,), ("data",))
    # cutoff=1 routes every subtask through the inner engine
    st_mixed = recover_mixed(prep, mesh, chunk=128, cutoff=1)
    np.testing.assert_array_equal(recover_serial(prep.problem), st_mixed)


# -- pad fills: per-dtype sentinels ------------------------------------------

def test_pad_fill_value_per_dtype():
    assert pad_fill_value(np.float32, lowest=True) == -np.inf
    assert pad_fill_value(np.int32, lowest=True) == np.iinfo(np.int32).min
    assert pad_fill_value(np.int64, lowest=True) == np.iinfo(np.int64).min
    assert pad_fill_value(np.int32) == -1
    assert pad_fill_value(np.float32) == -1.0
    with pytest.raises(TypeError, match="unsigned"):
        pad_fill_value(np.uint32)
    with pytest.raises(TypeError, match="unsigned"):
        pad_fill_value(np.uint8, lowest=True)


def _int_score_prep(g, chunk=128):
    """A Prepared whose problem carries an *integer* score array (rank
    order preserved, so the pre-sorted recovery order is unchanged)."""
    prep = prepare(g, chunk=chunk)
    score = np.asarray(prep.problem.score)
    int_score = np.argsort(np.argsort(score)).astype(np.int32)
    return dataclasses.replace(
        prep, problem=prep.problem._replace(score=int_score))


def test_outer_shards_accept_integer_scores():
    """``np.full(..., -np.inf, dtype=int32)`` raised before the per-dtype
    fill fix; integer-score problems must shard with ``iinfo.min`` pads."""
    g = grid2d(9, 9, seed=3)
    prep = _int_score_prep(g)
    shard_of, giants, _ = partition_subtasks(prep.subtask_sizes, 2)
    sharded = build_outer_shards(prep.problem, prep.subtask_sizes,
                                 shard_of, 2, chunk=128)
    score = np.asarray(sharded.score)
    assert score.dtype == np.int32
    pad = np.asarray(sharded.seg) < 0
    assert pad.any()
    assert (score[pad] == np.iinfo(np.int32).min).all()


def test_recover_mixed_equals_serial_on_integer_scores():
    g = grid2d(9, 9, seed=4)
    prep = _int_score_prep(g)
    mesh = compat_make_mesh((1,), ("data",))
    st_mixed = recover_mixed(prep, mesh, chunk=128)
    np.testing.assert_array_equal(recover_serial(prep.problem), st_mixed)
