"""Cheap structural tests for the dry-run cell definitions (no compiles)."""
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.launch.shapes import SHAPES, applicability, input_specs


def test_40_cells_defined():
    cells = [(a, s) for a in ARCHS for s in SHAPES]
    assert len(cells) == 40


def test_long_500k_skips_match_design():
    runs = {a for a in ARCHS
            if applicability(get_config(a), SHAPES["long_500k"]) is None}
    assert runs == {"falcon-mamba-7b", "hymba-1.5b", "mixtral-8x22b"}


def test_input_specs_shapes():
    for a in ARCHS:
        cfg = get_config(a)
        for s in SHAPES.values():
            if applicability(cfg, s):
                continue
            specs = input_specs(cfg, s)
            if s.kind in ("train", "prefill"):
                B, St = specs["tokens"].shape
                assert B == s.batch
                if cfg.frontend and cfg.enc_layers == 0:
                    assert St + cfg.frontend_len == s.seq
                else:
                    assert St == s.seq
                assert specs["tokens"].dtype == jnp.int32
                if s.kind == "train":
                    assert specs["labels"].shape == specs["tokens"].shape
            else:
                assert specs["token"].shape == (s.batch, 1)
                assert isinstance(specs["caches"], list)
                assert len(specs["caches"]) == cfg.n_layers


def test_decode_cache_sizes_respect_windows():
    cfg = get_config("mixtral-8x22b")   # SWA: rolling caches
    specs = input_specs(cfg, SHAPES["long_500k"])
    for c in specs["caches"]:
        assert c["k"].shape[1] <= cfg.window
    cfg2 = get_config("hymba-1.5b")     # 3 global layers keep full caches
    specs2 = input_specs(cfg2, SHAPES["long_500k"])
    lens = sorted({c["k"].shape[1] for c in specs2["caches"]})
    assert lens == [cfg2.window, SHAPES["long_500k"].seq]
