"""Device-resident hierarchy build: parity of the jit'd propose/accept
contraction against the sequential host oracle (same clustering, same
coarse Laplacian), the build_hierarchy/SolverService contraction knob,
admission control, and jit-warming warmup()."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (DeviceGraph, barabasi_albert, build_graph, grid2d,
                        mesh2d, star_hub)
from repro.solver import (AdmissionError, SolveRequest, SolverService,
                          build_hierarchy, device_contract, device_matching,
                          ell_laplacian, make_solver)
from repro.solver.hierarchy import contract, heavy_edge_matching


def _rhs(g, k=1, seed=0):
    rng = np.random.default_rng(seed)
    b = rng.standard_normal((g.n, k)).astype(np.float32)
    return b - b.mean(axis=0)


# -- matching / contraction parity -------------------------------------------

@pytest.mark.parametrize("make", [
    lambda: grid2d(12, 12, seed=1),            # road-style
    lambda: mesh2d(14, 14, seed=2),            # FEM-style
    lambda: barabasi_albert(300, 3, seed=3),   # skewed degrees
    lambda: star_hub(250, extra=150, seed=5),  # the degenerate hub input
])
def test_device_contract_matches_host_oracle(make):
    g = make()
    dg = DeviceGraph.from_graph(g)
    np.testing.assert_array_equal(np.asarray(device_matching(dg)),
                                  heavy_edge_matching(g))
    agg_h, coarse_h = contract(g)
    agg_d, coarse_d = device_contract(dg)
    # identical clustering (same strict total order), not merely isomorphic
    np.testing.assert_array_equal(np.asarray(agg_d), agg_h)
    assert (coarse_d.n, coarse_d.m) == (coarse_h.n, coarse_h.m)
    np.testing.assert_array_equal(coarse_d.src, coarse_h.src)
    np.testing.assert_array_equal(coarse_d.dst, coarse_h.dst)
    # weights may differ by f32 summation order only
    np.testing.assert_allclose(coarse_d.weight, coarse_h.weight, rtol=1e-5)
    # every cluster holds >= 2 vertices (a pair, plus absorbed singletons)
    assert coarse_d.n <= g.n // 2
    assert np.all(np.bincount(np.asarray(agg_d)) >= 2)


def test_device_contract_parity_on_exact_weight_ties():
    # uniform weights: the order is decided entirely by the tie-breaks
    g = build_graph(8, [0, 1, 2, 3, 4, 5, 6, 0, 2],
                    [1, 2, 3, 4, 5, 6, 7, 7, 5],
                    np.ones(9, np.float32))
    agg_h, coarse_h = contract(g)
    agg_d, coarse_d = device_contract(DeviceGraph.from_graph(g))
    np.testing.assert_array_equal(np.asarray(agg_d), agg_h)
    np.testing.assert_array_equal(coarse_d.src, coarse_h.src)
    np.testing.assert_array_equal(coarse_d.dst, coarse_h.dst)


def test_device_contract_star_collapses_to_single_vertex():
    # equal-weight pure star: one matched pair, everyone else absorbs into
    # the hub's cluster -> a single coarse vertex with no edges
    n = 12
    g = build_graph(n, np.zeros(n - 1, np.int64), np.arange(1, n),
                    np.ones(n - 1, np.float32))
    agg_d, coarse_d = device_contract(DeviceGraph.from_graph(g))
    agg_h, coarse_h = contract(g)
    assert coarse_d.n == coarse_h.n == 1 and coarse_d.m == 0
    np.testing.assert_array_equal(np.asarray(agg_d), agg_h)


# -- hierarchy knob -----------------------------------------------------------

def test_hierarchy_device_and_host_contraction_agree():
    g = mesh2d(20, 20, seed=9)
    hd = build_hierarchy(g, alpha=0.05, coarse_n=32, contraction="device")
    hh = build_hierarchy(g, alpha=0.05, coarse_n=32, contraction="host")
    assert hd.depth == hh.depth
    assert hd.level_sizes == hh.level_sizes
    for ld, lh in zip(hd.levels, hh.levels):
        np.testing.assert_array_equal(np.asarray(ld.agg), np.asarray(lh.agg))
        assert ld.stats["contraction"] == "device"
        assert lh.stats["contraction"] == "host"
    # spectrally equivalent preconditioners: PCG iterations within +-2
    b = jnp.asarray(_rhs(g, k=2, seed=10))
    idx, val = ell_laplacian(g)
    it = []
    for hier in (hd, hh):
        res = make_solver(idx, val, hierarchy=hier, precond="hierarchy")(
            b, tol=1e-5, maxiter=2000)
        assert bool(np.asarray(res.converged).all())
        it.append(int(np.asarray(res.iters).max()))
    assert abs(it[0] - it[1]) <= 2


def test_hierarchy_device_contraction_handles_hub_graphs():
    g = star_hub(500, extra=300, seed=30)
    hier = build_hierarchy(g, alpha=0.05, coarse_n=64, contraction="device")
    sizes = hier.level_sizes
    assert sizes[-1] <= 64
    for a, b in zip(sizes, sizes[1:]):
        assert b <= a // 2 + 1


def test_contraction_knob_validates():
    g = grid2d(5, 5, seed=0)
    with pytest.raises(ValueError, match="contraction"):
        build_hierarchy(g, contraction="gpu")
    with pytest.raises(ValueError, match="contraction"):
        SolverService(alpha=0.05, contraction="gpu")


def test_contraction_modes_never_share_cache_entries():
    g = grid2d(6, 6, seed=0)
    dev = SolverService(alpha=0.05, contraction="device")
    host = SolverService(alpha=0.05, contraction="host")
    hd, hh = dev.register(g), host.register(g)
    assert dev._key(hd, dev.pipeline) != host._key(hh, host.pipeline)
    assert dev.stats()["hierarchy"]["contraction"] == "device"
    assert host.stats()["hierarchy"]["contraction"] == "host"


# -- admission control ---------------------------------------------------------

def test_admission_rejects_over_budget_submits():
    g = grid2d(6, 6, seed=0)
    svc = SolverService(alpha=0.05, precond="none", max_pending_columns=4)
    b = _rhs(g, k=3, seed=1)
    t1 = svc.submit(SolveRequest(graph=g, b=b))              # 3 columns
    svc.submit(SolveRequest(graph=g, b=b[:, 0]))             # 4th column
    with pytest.raises(AdmissionError) as ei:
        svc.submit(SolveRequest(graph=g, b=b[:, 0]))
    assert (ei.value.pending, ei.value.requested, ei.value.budget) == (4, 1, 4)
    sched = svc.stats()["scheduler"]
    assert sched["submitted"] == 2 and sched["rejected"] == 1
    assert sched["pending_columns"] == 4
    # rejected submits never enter the queue; the rest still solve
    out = svc.flush()
    assert out[t1].converged
    assert svc.stats()["scheduler"]["pending_columns"] == 0


def test_admission_budget_resets_after_flush():
    g = grid2d(6, 6, seed=0)
    svc = SolverService(alpha=0.05, precond="none", max_pending_columns=2)
    b = _rhs(g, k=2, seed=2)
    svc.submit(SolveRequest(graph=g, b=b))
    with pytest.raises(AdmissionError):
        svc.submit(SolveRequest(graph=g, b=b[:, 0]))
    svc.flush()
    assert svc.submit(SolveRequest(graph=g, b=b)).result().converged


def test_unbounded_service_never_rejects():
    g = grid2d(5, 5, seed=0)
    svc = SolverService(alpha=0.05, precond="none")
    for _ in range(8):
        svc.submit(SolveRequest(graph=g, b=_rhs(g, k=4, seed=3)))
    sched = svc.stats()["scheduler"]
    assert sched["rejected"] == 0 and sched["pending_columns"] == 32
    svc.flush()


# -- jit-warming warmup --------------------------------------------------------

def test_warmup_widths_precompile_the_flush_buckets():
    g = mesh2d(10, 10, seed=15)
    svc = SolverService(alpha=0.05)
    h = svc.register(g)
    sources = svc.warmup(h, widths=[1, 3])     # buckets {1, 4}
    assert list(sources.values()) == ["miss"]
    timing = svc.stats()["timing"]
    assert timing["warmup_compile_ms"] > 0
    assert timing["solve_ms"] == 0.0
    key = svc._key(h, svc.pipeline)
    solve = svc._solvers[key]
    if hasattr(solve, "_cache_size"):          # newer jax: assert directly
        compiled = solve._cache_size()
        assert compiled >= 2
    res = svc.solve(h, _rhs(g, k=3, seed=16))  # pads to the warmed 4-bucket
    assert res.converged
    if hasattr(solve, "_cache_size"):
        assert solve._cache_size() == compiled  # no new XLA compilation
    timing = svc.stats()["timing"]
    assert timing["solve_ms"] > 0


def test_rewarm_does_not_inflate_compile_split():
    g = mesh2d(8, 8, seed=18)
    svc = SolverService(alpha=0.05, precond="none")
    h = svc.register(g)
    svc.warmup(h, widths=[2])
    first = svc.stats()["timing"]["warmup_compile_ms"]
    svc.warmup(h, widths=[2])                  # bucket already compiled
    assert svc.stats()["timing"]["warmup_compile_ms"] == first


def test_warmup_without_widths_keeps_v2_contract():
    g = mesh2d(8, 8, seed=17)
    svc = SolverService(alpha=0.05)
    h = svc.register(g)
    assert list(svc.warmup(h).values()) == ["miss"]
    assert list(svc.warmup(h).values()) == ["mem"]
    assert svc.stats()["timing"]["warmup_compile_ms"] == 0.0


def test_warmup_rejects_bad_widths():
    g = grid2d(5, 5, seed=0)
    svc = SolverService(alpha=0.05)
    with pytest.raises(ValueError, match="widths"):
        svc.warmup(g, widths=[0])
