"""Unified Pipeline API tests: config round-trip + registry validation,
engine equivalence under one harness, device-resident graph views, and the
score_mode plumbing regression (pdgrass() used to silently drop it)."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (DeviceGraph, barabasi_albert, fegrass, mesh2d,
                        pdgrass, prepare, star_hub)
from repro.pipeline import (Pipeline, PipelineConfig, RecoveryConfig,
                            ScoreConfig, config_diff, fegrass_config,
                            pdgrass_config, run_pipeline)


# -- config tree -------------------------------------------------------------

def test_config_roundtrip_identity():
    for cfg in (PipelineConfig(),
                pdgrass_config(alpha=0.07, c=6, engine="serial",
                               score_mode="r", block_size=4),
                fegrass_config(alpha=0.03, max_passes=17)):
        d = cfg.to_dict()
        assert PipelineConfig.from_dict(d) == cfg
        # canonical serialization is stable and content-keyed
        assert cfg.fingerprint() == PipelineConfig.from_dict(d).fingerprint()
    assert (pdgrass_config(alpha=0.05).fingerprint()
            != fegrass_config(alpha=0.05).fingerprint())


def test_config_rejects_unknown_stage_names():
    with pytest.raises(ValueError, match="unknown recovery stage"):
        pdgrass_config(engine="nope")
    with pytest.raises(ValueError, match="unknown score stage"):
        pdgrass_config(score_mode="nope")
    with pytest.raises(ValueError, match="unknown tree stage"):
        pdgrass_config(tree="nope")
    bad = dataclasses.replace(PipelineConfig(),
                              recovery=RecoveryConfig(kind="bogus"))
    with pytest.raises(ValueError, match="unknown recovery stage 'bogus'"):
        Pipeline(bad)


def test_config_from_dict_rejects_unknown_keys():
    d = PipelineConfig().to_dict()
    d["typo"] = 1
    with pytest.raises(ValueError, match="unknown PipelineConfig keys"):
        PipelineConfig.from_dict(d)
    d = PipelineConfig().to_dict()
    d["recovery"]["typo"] = 1
    with pytest.raises(ValueError, match="unknown RecoveryConfig keys"):
        PipelineConfig.from_dict(d)


def test_config_diff_is_the_fegrass_story():
    diff = config_diff(pdgrass_config(), fegrass_config())
    assert diff["recovery.kind"] == ("rounds", "multipass")
    assert all(k.startswith("recovery.") for k in diff)


# -- engine equivalence under one harness ------------------------------------

def test_rounds_and_serial_pipelines_recover_identical_edges():
    g = barabasi_albert(300, 3, seed=11)
    shared = Pipeline(pdgrass_config()).prepare(g)
    a = Pipeline(pdgrass_config(alpha=0.05, engine="serial")).run(
        g, prepared=shared)
    b = Pipeline(pdgrass_config(alpha=0.05, engine="rounds",
                                stop_at_target=False)).run(g, prepared=shared)
    assert np.array_equal(a.recovered_mask, b.recovered_mask)
    assert np.array_equal(a.tree_mask, b.tree_mask)


def test_fegrass_wrapper_equals_pipeline_config():
    g = star_hub(200, extra=150, seed=5)
    via_wrapper = fegrass(g, alpha=0.10)
    via_pipeline = Pipeline(fegrass_config(alpha=0.10)).run(g)
    assert np.array_equal(via_wrapper.recovered_mask,
                          via_pipeline.recovered_mask)
    assert via_wrapper.stats["passes"] == via_pipeline.stats["passes"] > 1


def test_pdgrass_wrapper_equals_pipeline_config():
    g = mesh2d(14, 14, seed=3)
    assert np.array_equal(
        pdgrass(g, alpha=0.05).edge_mask,
        run_pipeline(g, pdgrass_config(alpha=0.05)).edge_mask)


def test_boruvka_tree_stage_differs_from_low_stretch():
    g = mesh2d(14, 14, seed=7)
    low = Pipeline(pdgrass_config(alpha=0.05)).run(g)
    raw = Pipeline(pdgrass_config(alpha=0.05, tree="boruvka")).run(g)
    assert low.tree_mask.sum() == raw.tree_mask.sum() == g.n - 1
    assert not np.array_equal(low.tree_mask, raw.tree_mask)


def test_pipeline_handles_tree_graph_with_no_offtree_edges():
    """m_off == 0: no subtasks, no recovery, every engine returns the tree."""
    from repro.core import build_graph

    n = 48
    w = np.random.default_rng(0).uniform(1, 10, n - 1)
    g = build_graph(n, np.arange(n - 1), np.arange(1, n), w)
    for cfg in (pdgrass_config(alpha=0.1), fegrass_config(alpha=0.1),
                pdgrass_config(alpha=0.1, engine="serial")):
        sp = Pipeline(cfg).run(g)
        assert sp.stats["n_recovered"] == 0
        assert sp.stats["n_subtasks"] == 0
        assert sp.tree_mask.all() and not sp.recovered_mask.any()


def test_er_sample_score_is_seed_deterministic():
    g = mesh2d(14, 14, seed=4)
    mk = lambda s: Pipeline(  # noqa: E731
        pdgrass_config(alpha=0.10, score_mode="er_sample", seed=s)).run(g)
    assert np.array_equal(mk(1).recovered_mask, mk(1).recovered_mask)
    # different seeds draw a different sample (overwhelmingly likely)
    assert not np.array_equal(mk(1).recovered_mask, mk(2).recovered_mask)


# -- score_mode plumbing regression ------------------------------------------

def test_pdgrass_forwards_score_mode_end_to_end():
    """pdgrass() used to accept prepare()'s score_mode nowhere; now every
    kwarg maps onto PipelineConfig and reaches the stage."""
    g = barabasi_albert(250, 3, seed=9)
    prep_w = prepare(g, score_mode="w_times_r")
    prep_r = prepare(g, score_mode="r")
    # the stage really ran: scores differ between modes
    assert not np.allclose(np.asarray(prep_w.problem.score),
                           np.asarray(prep_r.problem.score), equal_nan=True)
    sp_r = pdgrass(g, alpha=0.05, score_mode="r")
    via_cfg = Pipeline(pdgrass_config(alpha=0.05, score_mode="r")).run(g)
    assert np.array_equal(sp_r.recovered_mask, via_cfg.recovered_mask)


# -- DeviceGraph / device-resident sparsifier views --------------------------

def test_device_graph_matvec_matches_scipy():
    g = mesh2d(11, 11, seed=2)
    dg = DeviceGraph.from_graph(g)
    L = g.laplacian().toarray()
    rng = np.random.default_rng(0)
    x1 = rng.standard_normal(g.n).astype(np.float32)
    xk = rng.standard_normal((g.n, 3)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(dg.laplacian_matvec(jnp.asarray(x1))),
                               L @ x1, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dg.laplacian_matvec(jnp.asarray(xk))),
                               L @ xk, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dg.diag), np.diag(L), rtol=1e-5)


def test_device_graph_matvec_is_jittable():
    g = mesh2d(9, 9, seed=6)
    dg = DeviceGraph.from_graph(g)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(g.n)
                    .astype(np.float32))

    @jax.jit
    def f(dgraph, v):      # DeviceGraph is a pytree: flows through jit
        return dgraph.laplacian_matvec(v)

    np.testing.assert_allclose(np.asarray(f(dg, x)),
                               np.asarray(dg.laplacian_matvec(x)),
                               rtol=1e-6, atol=1e-6)


def test_device_graph_to_ell_matvec_matches_scipy():
    g = barabasi_albert(200, 3, seed=8)
    idx, val = DeviceGraph.from_graph(g).to_ell()
    x = np.random.default_rng(2).standard_normal(g.n).astype(np.float32)
    y = np.asarray(jnp.einsum("nl,nl->n", val, jnp.asarray(x)[idx]))
    np.testing.assert_allclose(y, g.laplacian() @ x, rtol=1e-4, atol=1e-4)


def test_sparsifier_device_views_match_scipy_on_kept_edges():
    g = mesh2d(13, 13, seed=5)
    sp = pdgrass(g, alpha=0.10)
    L = sp.laplacian().toarray()          # scipy reference over edge_mask
    x = np.random.default_rng(3).standard_normal(g.n).astype(np.float32)
    np.testing.assert_allclose(np.asarray(sp.laplacian_matvec(jnp.asarray(x))),
                               L @ x, rtol=1e-4, atol=1e-4)
    idx, val = sp.to_ell()
    y = np.asarray(jnp.einsum("nl,nl->n", val, jnp.asarray(x)[idx]))
    np.testing.assert_allclose(y, L @ x, rtol=1e-4, atol=1e-4)
    # the view is cached device-side state, built once
    assert sp.device_graph is sp.device_graph


def test_host_laplacian_matvec_matches_scipy():
    g = barabasi_albert(150, 3, seed=12)
    L = g.laplacian()
    rng = np.random.default_rng(4)
    x1 = rng.standard_normal(g.n)
    xk = rng.standard_normal((g.n, 2))
    np.testing.assert_allclose(g.laplacian_matvec(x1), L @ x1, rtol=1e-12)
    np.testing.assert_allclose(g.laplacian_matvec(xk), L @ xk, rtol=1e-12)
