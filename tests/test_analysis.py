"""Static-analysis package: planted-violation fixtures + clean tree.

Five planted violations, one per headline rule, each asserted to be
caught by exactly its intended checker with a file:line diagnostic:

1. host sync inside a jit scope           -> trace-host-sync
2. f64 promotion in a declared-f32 path   -> jaxpr-f64-promotion
3. unlocked inventory-field write         -> lock-unguarded-field
4. ``*_locked`` call outside the lock     -> lock-unlocked-call
5. oversized fused-kernel VMEM level      -> vmem-budget

plus pragma semantics (reasoned suppression works, bare suppression is
itself a finding), precision guards (the idioms the tree legitimately
uses must NOT fire), and the acceptance gate: the AST checkers report
zero findings over the real ``src/repro`` tree.  The heavyweight jaxpr
and vmem suite runs stay in the CI ``static-analysis`` job
(``python -m repro.analysis --check all``), not here — tier-1 stays
fast.
"""
import json
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import CHECKS, run_checks
from repro.analysis.findings import (RULES, RULE_IDS, Finding,
                                     apply_pragmas, scan_pragmas,
                                     write_findings_json)
from repro.analysis import lock_lint, trace_lint, vmem_check


def _rules_of(findings):
    return sorted(set(f.rule for f in findings))


# ---------------------------------------------------------------------------
# ruleset sanity
# ---------------------------------------------------------------------------

def test_ruleset_nonempty_and_stable_ids():
    assert len(RULES) >= 10
    for rule in RULES:
        assert rule.id in RULE_IDS
        assert rule.checker in ("jaxpr", "trace", "locks", "vmem", "meta")
    # the five headline fixture rules exist
    for rid in ("trace-host-sync", "jaxpr-f64-promotion",
                "lock-unguarded-field", "lock-unlocked-call",
                "vmem-budget"):
        assert rid in RULE_IDS


# ---------------------------------------------------------------------------
# fixture 1: host sync inside a jit-traced scope
# ---------------------------------------------------------------------------

FIXTURE_HOST_SYNC = textwrap.dedent('''\
    import jax
    import jax.numpy as jnp

    @jax.jit
    def solve(b):
        r = jnp.linalg.norm(b)
        scale = float(r)          # line 7: the violation
        return b / scale
''')


def test_fixture_host_sync_in_jit():
    findings = trace_lint.check_source(FIXTURE_HOST_SYNC, "fix_sync.py")
    assert _rules_of(findings) == ["trace-host-sync"]
    (f,) = findings
    assert f.file == "fix_sync.py" and f.line == 7
    assert "float()" in f.message


def test_fixture_python_branch_and_numpy_on_traced():
    src = textwrap.dedent('''\
        import jax
        import numpy as np

        @jax.jit
        def step(x):
            if x > 0:                  # line 6: python branch on tracer
                return x
            return -np.abs(x)          # line 8: numpy on a traced value
    ''')
    findings = trace_lint.check_source(src, "fix_branch.py")
    rules = {f.rule: f.line for f in findings}
    assert rules == {"trace-python-branch": 6, "trace-numpy-on-traced": 8}


# ---------------------------------------------------------------------------
# fixture 2: f64 promotion inside a declared-f32 jit path
# ---------------------------------------------------------------------------

def test_fixture_f64_promotion_in_jaxpr():
    import jax.numpy as jnp
    from repro.analysis.jaxpr_audit import audit_entry
    from repro.analysis.registry import HotEntry

    def build():
        def accumulate(x):
            # silent mixed-precision bug: the accumulator widens to f64
            acc = x.astype(jnp.float64) * 2.0
            return acc.astype(jnp.float32)
        return accumulate, (jnp.ones((8,), jnp.float32),), None, ()

    findings = audit_entry(HotEntry("planted_f64", "fixture", build))
    assert "jaxpr-f64-promotion" in _rules_of(findings)
    f = next(f for f in findings if f.rule == "jaxpr-f64-promotion")
    assert f.line > 0 and f.file  # located at a real source line


def test_fixture_callback_and_while_transfer():
    import jax
    import jax.numpy as jnp
    from repro.analysis.jaxpr_audit import audit_entry
    from repro.analysis.registry import HotEntry

    def build():
        def noisy_loop(x):
            def body(s):
                jax.debug.print("s={s}", s=s[0])
                return s - 1.0
            return jax.lax.while_loop(lambda s: s[0] > 0, body, x)
        return noisy_loop, (jnp.ones((4,), jnp.float32),), None, ()

    findings = audit_entry(HotEntry("planted_while", "fixture", build))
    assert _rules_of(findings) == ["jaxpr-while-transfer"]


def test_fixture_recompile_hazard():
    import jax.numpy as jnp
    from repro.analysis.jaxpr_audit import audit_entry
    from repro.analysis.registry import HotEntry

    def build():
        def shape_dependent(x):
            if x.shape[1] % 2 == 0:   # structure differs across one bucket
                return x * 2.0
            return x + jnp.sum(x)
        return (shape_dependent, (jnp.ones((4, 6), jnp.float32),),
                (jnp.ones((4, 7), jnp.float32),), ())

    findings = audit_entry(HotEntry("planted_bucket", "fixture", build))
    assert _rules_of(findings) == ["jaxpr-recompile-hazard"]


# ---------------------------------------------------------------------------
# fixtures 3+4: lock discipline
# ---------------------------------------------------------------------------

FIXTURE_LOCKS = textwrap.dedent('''\
    import threading

    class Service:
        def __init__(self):
            # lock: self._lock
            #   _count _items
            self._lock = threading.RLock()
            self._count = 0
            self._items = []

        def good(self):
            with self._lock:
                self._count += 1

        def bad_write(self):
            self._count += 1          # line 16: unlocked field write

        def _drain_locked(self):
            out, self._items = self._items, []
            return out

        def bad_call(self):
            return self._drain_locked()   # line 23: _locked outside lock

        def good_call(self):
            with self._lock:
                return self._drain_locked()
''')


def test_fixture_unlocked_field_write():
    findings = lock_lint.check_source(FIXTURE_LOCKS, "fix_locks.py")
    unguarded = [f for f in findings if f.rule == "lock-unguarded-field"]
    (f,) = unguarded
    assert (f.file, f.line) == ("fix_locks.py", 16)
    assert "_count" in f.message


def test_fixture_locked_call_outside_lock():
    findings = lock_lint.check_source(FIXTURE_LOCKS, "fix_locks.py")
    unlocked = [f for f in findings if f.rule == "lock-unlocked-call"]
    (f,) = unlocked
    assert (f.file, f.line) == ("fix_locks.py", 23)
    assert "_drain_locked" in f.message
    # and nothing else fired: good()/good_call()/__init__ are clean
    assert len(findings) == 2


def test_lock_inventory_parsing():
    invs = lock_lint.parse_inventories(FIXTURE_LOCKS)
    assert len(invs) == 1
    assert invs[0].lock_attr == "_lock"
    assert invs[0].fields == {"_count", "_items"}


# ---------------------------------------------------------------------------
# fixture 5: oversized VMEM level
# ---------------------------------------------------------------------------

def test_fixture_oversized_vmem_level():
    # 2M rows x ELL width 8: slab alone is 2e6*8*8 = 128 MB >> 16 MB
    findings = vmem_check.check_level_triples(
        [(2_000_000, 8, 500_000)], k=16, graph="planted")
    assert _rules_of(findings) == ["vmem-budget"]
    (f,) = findings
    assert "vcycle_fused.py" in f.file
    assert "unfused" in f.message  # tells you the remediation


def test_vmem_within_budget_is_clean():
    # a realistic hierarchy level: 10k rows, width 12
    assert vmem_check.check_level_triples([(10_000, 12, 2_500)]) == []


def test_shard_layout_validator():
    import numpy as np
    ok = vmem_check.validate_shard_layout(
        n_pad=8, n_loc=4, n_sh=2,
        halo=np.array([[4, 5], [0, 1]]),
        idx=np.zeros((8, 3), np.int32))
    assert ok == []
    bad = vmem_check.validate_shard_layout(
        n_pad=9, n_loc=4, n_sh=2,                  # 4*2 != 9
        halo=np.array([[4, 99], [0, 1]]),          # 99 out of range
        idx=np.full((9, 3), 7, np.int32))          # 7 >= n_loc+H = 6
    # n_pad=9 trips both divisibility predicates, plus halo + coords
    assert len(bad) == 4


# ---------------------------------------------------------------------------
# suppression pragmas
# ---------------------------------------------------------------------------

def test_pragma_suppresses_with_reason():
    src = FIXTURE_HOST_SYNC.replace(
        "scale = float(r)          # line 7: the violation",
        "scale = float(r)  # analysis: allow(trace-host-sync): probe only")
    assert trace_lint.check_source(src, "fix.py") == []


def test_bare_pragma_is_itself_a_finding():
    src = FIXTURE_HOST_SYNC.replace(
        "scale = float(r)          # line 7: the violation",
        "scale = float(r)  # analysis: allow(trace-host-sync)")
    findings = trace_lint.check_source(src, "fix.py")
    # the violation is NOT suppressed and the bare pragma is reported
    assert _rules_of(findings) == ["meta-bare-allow", "trace-host-sync"]


def test_unknown_rule_pragma_is_a_finding():
    allowed, findings = scan_pragmas(
        "x = 1  # analysis: allow(no-such-rule): because\n", "p.py")
    assert allowed == {}
    assert _rules_of(findings) == ["meta-bare-allow"]


def test_apply_pragmas_is_line_and_rule_scoped():
    findings = [Finding("f.py", 3, "trace-host-sync", "m"),
                Finding("f.py", 4, "trace-host-sync", "m")]
    out = apply_pragmas(findings, {3: {"trace-host-sync"}})
    assert [f.line for f in out] == [4]


# ---------------------------------------------------------------------------
# precision: legitimate tree idioms must NOT fire
# ---------------------------------------------------------------------------

def test_shape_derived_branch_is_clean():
    src = textwrap.dedent('''\
        import jax
        import jax.numpy as jnp

        @jax.jit
        def padded(x):
            n = x.shape[0]
            pad = (-n) % 256
            if pad:
                x = jnp.pad(x, ((0, pad),))
            return x[:n] if pad else x
    ''')
    assert trace_lint.check_source(src, "clean.py") == []


def test_host_boundary_scalarization_is_clean():
    src = textwrap.dedent('''\
        import jax
        import jax.numpy as jnp
        import numpy as np

        def readback(xs):
            total = jnp.sum(xs)
            host = np.asarray(total)
            a = int(host)
            b = float(jax.device_get(jnp.max(xs)))
            return a + b
    ''')
    assert trace_lint.check_source(src, "clean.py") == []


def test_is_none_branch_in_jit_is_clean():
    src = textwrap.dedent('''\
        import jax

        @jax.jit
        def apply(x, z=None):
            if z is None:
                return x
            return x + z
    ''')
    assert trace_lint.check_source(src, "clean.py") == []


# ---------------------------------------------------------------------------
# the acceptance gate: AST checkers are clean over the real tree
# ---------------------------------------------------------------------------

def test_clean_tree_trace_and_locks():
    per_check = run_checks(["trace", "locks"])
    flat = [f.format() for fs in per_check.values() for f in fs]
    assert flat == []


def test_real_inventories_declared():
    import os
    import repro.solver.service as svc
    import repro.serve.solver_daemon as dmn
    for mod, lock in ((svc, "_lock"), (dmn, "_cond")):
        src = open(mod.__file__).read()
        invs = lock_lint.parse_inventories(src)
        assert [i.lock_attr for i in invs] == [lock]
        assert len(invs[0].fields) >= 5


# ---------------------------------------------------------------------------
# CLI + artifact plumbing
# ---------------------------------------------------------------------------

def test_cli_emits_bench_v1_artifact(tmp_path):
    out = tmp_path / "analysis.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis",
         "--check", "trace", "--check", "locks", "--json", str(out)],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    doc = json.loads(out.read_text())
    assert doc["schema"] == "bench-v1"
    assert doc["bench"] == "analysis"
    rec = doc["records"]
    assert rec["checks_run"] == ["locks", "trace"]
    assert rec["finding_count"] == 0 and rec["findings"] == []
    assert len(rec["ruleset"]) == len(RULES)


def test_cli_rejects_unknown_check():
    with pytest.raises(ValueError, match="unknown check"):
        run_checks(["nope"])
    assert set(CHECKS) == {"jaxpr", "trace", "locks", "vmem"}


def test_findings_json_roundtrip(tmp_path):
    path = tmp_path / "f.json"
    doc = write_findings_json(
        str(path),
        [Finding("a.py", 1, "trace-host-sync", "msg")],
        ["trace"])
    assert doc["records"]["finding_count"] == 1
    loaded = json.loads(path.read_text())
    assert loaded["records"]["findings"][0]["rule"] == "trace-host-sync"
