"""Recovery-engine tests: serial oracle vs JAX round engine vs distributed.

The central invariant (property-tested with hypothesis): the parallel round
engine is *bit-identical* to the sequential per-subtask greedy for every
graph, block size and candidate cap — this is the paper's claim that the
subtask decomposition (Lemmas 6–8) removes all cross-subtask dependencies.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import (build_graph, grid2d, mesh2d, barabasi_albert,
                        star_hub, watts_strogatz, prepare, pdgrass, fegrass)
from repro.core.recovery import (STATUS_OPEN, STATUS_RECOVERED,
                                 STATUS_SKIPPED, recover_rounds,
                                 recover_serial, select_top)


def random_connected_graph(rng, n, extra):
    """Random tree + `extra` random chords; guaranteed connected/simple."""
    perm = rng.permutation(n)
    src = [perm[rng.integers(0, i)] for i in range(1, n)]
    dst = perm[1:].tolist()
    a = rng.integers(0, n, extra * 3)
    b = rng.integers(0, n, extra * 3)
    keep = a != b
    src = np.concatenate([src, a[keep][:extra]])
    dst = np.concatenate([dst, b[keep][:extra]])
    w = rng.uniform(1.0, 10.0, len(src))
    try:
        return build_graph(n, src, dst, w)
    except ValueError:
        return None  # duplicate collapse could disconnect? (cannot — tree kept)


@given(
    seed=st.integers(0, 10_000),
    n=st.integers(8, 64),
    extra=st.integers(4, 80),
    block=st.sampled_from([1, 3, 16]),
    cap=st.sampled_from([8, 64]),
)
@settings(max_examples=25, deadline=None)
def test_rounds_equals_serial_property(seed, n, extra, block, cap):
    rng = np.random.default_rng(seed)
    g = random_connected_graph(rng, n, extra)
    if g is None or g.m <= g.n - 1:
        return
    prep = prepare(g, chunk=256)
    st_serial = recover_serial(prep.problem)
    st_rounds, stats = recover_rounds(
        prep.problem, block_size=block, max_candidates=cap,
        stop_at_target=False, chunk=256)
    assert np.array_equal(st_serial, np.asarray(st_rounds))
    n_rec = int((st_serial == STATUS_RECOVERED).sum())
    # every subtask recovers its first edge, so rounds has progress guarantee
    assert int(stats.rounds) <= max(1, n_rec)


@pytest.mark.parametrize("make", [
    lambda: grid2d(15, 15, seed=1),
    lambda: mesh2d(12, 12, seed=2),
    lambda: barabasi_albert(300, 3, seed=3),
    lambda: watts_strogatz(300, 6, 0.1, seed=4),
    lambda: star_hub(200, extra=150, seed=5),
])
def test_rounds_equals_serial_suite(make):
    g = make()
    prep = prepare(g, chunk=512)
    st_serial = recover_serial(prep.problem)
    for block, cap in [(4, 16), (16, 128)]:
        st_r, _ = recover_rounds(prep.problem, block_size=block,
                                 max_candidates=cap, stop_at_target=False,
                                 chunk=512)
        assert np.array_equal(st_serial, np.asarray(st_r))


def test_recovered_edges_pairwise_dissimilar():
    """No recovered edge may be strictly similar to an earlier recovered one."""
    from repro.core.recovery import strict_similarity_matrix

    g = barabasi_albert(250, 3, seed=7)
    prep = prepare(g, chunk=256)
    status = recover_serial(prep.problem)
    p = prep.problem
    seg = np.asarray(p.seg)
    rec = np.flatnonzero(status == STATUS_RECOVERED)
    sim = np.asarray(strict_similarity_matrix(
        p.sig_u[rec], p.sig_v[rec], p.beta[rec], p.sig_u[rec], p.sig_v[rec]))
    same_seg = seg[rec][:, None] == seg[rec][None, :]
    earlier = np.arange(len(rec))[:, None] < np.arange(len(rec))[None, :]
    # an earlier recovered edge never marks a later recovered edge
    assert not np.any(sim & same_seg & earlier)


def test_skipped_edges_have_witness():
    """Every skipped edge is strictly similar to some earlier recovered edge
    in its subtask (soundness of the skip decisions)."""
    from repro.core.recovery import strict_similarity_matrix

    g = watts_strogatz(200, 6, 0.2, seed=8)
    prep = prepare(g, chunk=256)
    p = prep.problem
    status = recover_serial(p)
    seg = np.asarray(p.seg)
    m_off = prep.m_off
    rec = np.flatnonzero(status == STATUS_RECOVERED)
    skp = np.flatnonzero(status[:m_off] == STATUS_SKIPPED)
    if skp.size == 0:
        return
    sim = np.asarray(strict_similarity_matrix(
        p.sig_u[rec], p.sig_v[rec], p.beta[rec], p.sig_u[skp], p.sig_v[skp]))
    same = seg[rec][:, None] == seg[skp][None, :]
    earlier = rec[:, None] < skp[None, :]
    assert np.all(np.any(sim & same & earlier, axis=0))


def test_select_top_budget():
    score = jnp.asarray(np.array([5.0, 3.0, 9.0, 1.0, 7.0], np.float32))
    status = jnp.asarray(np.array([1, 1, 2, 1, 1], np.int8))
    keep = np.asarray(select_top(status, score, 2))
    assert keep.tolist() == [True, False, False, False, True]


def test_pdgrass_end_to_end_counts():
    g = mesh2d(20, 20, seed=9)
    for alpha in [0.02, 0.05, 0.10]:
        sp = pdgrass(g, alpha=alpha)
        target = int(np.ceil(alpha * g.n))
        assert sp.stats["n_recovered"] == min(target, sp.stats["target"])
        assert sp.tree_mask.sum() == g.n - 1
        assert not np.any(sp.tree_mask & sp.recovered_mask)
        assert sp.stats["passes"] == 1  # single pass, always (paper claim)


def test_fegrass_multipass_on_hub_graph():
    """Worst-case reproduction: hub graphs force feGRASS into many passes."""
    g = star_hub(400, extra=300, seed=10)
    fe = fegrass(g, alpha=0.10)
    pd = pdgrass(g, alpha=0.10)
    assert fe.stats["passes"] > 3          # loose cover: few edges per pass
    assert pd.stats["passes"] == 1         # strict condition: one pass
    assert pd.stats["n_recovered"] >= fe.stats["n_recovered"]


def test_kernel_backend_equals_serial():
    """Round engine with the Pallas similarity kernel (interpret mode)."""
    g = barabasi_albert(300, 3, seed=3)
    prep = prepare(g, chunk=256)
    st_s = recover_serial(prep.problem)
    st_k, _ = recover_rounds(prep.problem, block_size=16, max_candidates=64,
                             stop_at_target=False, chunk=256, use_kernel=True)
    assert np.array_equal(st_s, np.asarray(st_k))


def test_engines_give_same_sparsifier():
    g = barabasi_albert(300, 3, seed=11)
    a = pdgrass(g, alpha=0.05, engine="serial")
    b = pdgrass(g, alpha=0.05, engine="rounds", stop_at_target=False)
    assert np.array_equal(a.recovered_mask, b.recovered_mask)
