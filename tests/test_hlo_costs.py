"""Unit tests for the trip-count-aware HLO cost analyzer — the §Roofline
numbers depend on it, so it gets closed-form validation of its own."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.launch import hlo_costs


def _costs(fn, *sds):
    comp = jax.jit(fn).lower(*sds).compile()
    return hlo_costs.analyze_hlo(comp.as_text())


def test_matmul_flops_exact():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    c = _costs(lambda a, b: a @ b, x, w)
    assert abs(c.flops - 2 * 128 * 256 * 512) / (2 * 128 * 256 * 512) < 0.01


def test_scan_trip_count_multiplied():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(a, b):
        def body(c, _):
            return jnp.tanh(c @ b), None
        y, _ = jax.lax.scan(body, a, None, length=17)
        return y

    c = _costs(f, x, w)
    per = 2 * 64 * 64 * 64
    assert 17 * per <= c.flops <= 17 * per * 1.2  # + elementwise tanh
    assert c.dynamic_whiles == 0


def test_nested_scan_trips_compose():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def f(a, b):
        def outer(c, _):
            def inner(d, _):
                return d @ b, None
            d, _ = jax.lax.scan(inner, c, None, length=5)
            return d, None
        y, _ = jax.lax.scan(outer, a, None, length=3)
        return y

    c = _costs(f, x, w)
    per = 2 * 32 * 32 * 32
    assert 15 * per <= c.flops <= 15 * per * 1.3


def test_dynamic_while_flagged_not_zeroed():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(a):
        def cond(s):
            return jnp.sum(s) > 0  # data-dependent: no static trip count
        def body(s):
            return s @ s * 0.9
        return jax.lax.while_loop(cond, body, a)

    c = _costs(f, x)
    assert c.dynamic_whiles >= 1
    assert c.flops >= 2 * 64 * 64 * 64  # body counted at least once


def test_collective_bytes_counted():
    import os
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import contextlib
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.distributed import _shard_map
        from repro.launch import hlo_costs
        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((8,), ("data",))
        def f(x):
            return jax.lax.psum(x, "data")
        fn = _shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P())
        ctx = (jax.set_mesh(mesh) if hasattr(jax, "set_mesh")
               else contextlib.nullcontext())
        with ctx:
            comp = jax.jit(fn).lower(
                jax.ShapeDtypeStruct((1024,), jnp.float32)).compile()
        c = hlo_costs.analyze_hlo(comp.as_text())
        assert c.coll.get("all-reduce", 0) > 0, c.coll
        print("COLL-OK", c.coll)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=300,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "COLL-OK" in out.stdout, out.stdout + out.stderr
