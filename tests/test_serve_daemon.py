"""Async serving runtime: background flusher, tenant fairness, shutdown.

The contracts under test:

  * a submitted ticket resolves via ``result(timeout=...)`` with **no
    explicit flush() anywhere** — the background flusher's deadline/size
    triggers drive everything,
  * per-group failure isolation survives the thread boundary (one
    (graph, config) group's exception fails only its own tickets),
  * multi-tenant fairness: per-tenant budgets reject with tenant context,
    batch selection is starvation-free (every tenant with queued work is
    in every flush window) and weight-proportional,
  * shutdown is deterministic: ``close(drain=True)`` settles everything,
    ``close(drain=False)`` fails everything queued with
    ``DaemonShutdownError`` — never a hang,
  * N producer threads racing one deadline flusher lose no tickets and
    corrupt no queue accounting,
  * SLO breach counting and the ``serve.*`` telemetry surface.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import grid2d
from repro.obs import get_tracer
from repro.serve import DaemonShutdownError, SolverDaemon, TenantConfig
from repro.solver import (AdmissionError, DeadlineExceededError,
                          SolveRequest, SolverService)
from repro.pipeline import fegrass_config

DELAY_MS = 40.0


def _rhs(n, k=1, seed=0):
    rng = np.random.default_rng(seed)
    b = rng.standard_normal((n, k)).astype(np.float32)
    return b[:, 0] if k == 1 else b


@pytest.fixture(scope="module")
def svc():
    """One warm service for the whole module: artifacts built and every
    small pow2 RHS bucket jit-compiled, so daemon tests time serving, not
    compilation."""
    service = SolverService(alpha=0.1)
    g = grid2d(6, 6, seed=0)
    h = service.register(g)
    service.warmup(h, widths=[1, 2, 4, 8, 16, 32])
    return service, h


def test_ticket_resolves_without_flush(svc):
    service, h = svc
    flushes_before = service.stats()["scheduler"]["flushes"]
    with SolverDaemon(service, max_batch_delay_ms=DELAY_MS) as d:
        t = d.submit(SolveRequest(graph=h, b=_rhs(h.n, seed=1)))
        t0 = time.perf_counter()
        res = t.result(timeout=30.0)
        elapsed = time.perf_counter() - t0
    assert res.converged
    assert t.done()
    # The deadline trigger fired: resolution took ~max_batch_delay_ms plus
    # a warm solve, nowhere near the 30s timeout.
    assert elapsed < 10.0
    # And nothing ever called service.flush() — the daemon hands batches
    # straight to the group scheduler.
    assert service.stats()["scheduler"]["flushes"] == flushes_before
    assert d.stats()["daemon"]["triggers"]["deadline"] >= 1


def test_done_is_nonblocking_and_result_timeout(svc):
    service, h = svc
    d = SolverDaemon(service, max_batch_delay_ms=60_000.0, autostart=True)
    try:
        t = d.submit(SolveRequest(graph=h, b=_rhs(h.n, seed=2)))
        assert not t.done()          # deadline is a minute out
        with pytest.raises(TimeoutError):
            t.result(timeout=0.05)
        assert not t.done()
    finally:
        d.close(drain=True)
    assert t.result(timeout=1.0).converged   # drain settled it


def test_size_trigger_fires_before_deadline(svc):
    service, h = svc
    with SolverDaemon(service, max_batch_delay_ms=60_000.0,
                      max_batch_columns=4) as d:
        tickets = [d.submit(SolveRequest(graph=h, b=_rhs(h.n, seed=10 + i)))
                   for i in range(4)]
        for t in tickets:
            assert t.result(timeout=30.0).converged
        assert d.stats()["daemon"]["triggers"]["size"] >= 1


def test_group_failure_isolation_across_thread_boundary(svc, monkeypatch):
    """One (graph, config) group's exception must fail only that group's
    tickets; the other group still resolves — from the flusher thread."""
    service, h = svc
    fe = fegrass_config(alpha=0.1)
    real = service._solve_group

    def poisoned(entries, config, key):
        if config.fingerprint() == fe.fingerprint():
            raise RuntimeError("poisoned group")
        return real(entries, config, key)

    monkeypatch.setattr(service, "_solve_group", poisoned)
    with SolverDaemon(service, max_batch_delay_ms=DELAY_MS) as d:
        ok = d.submit(SolveRequest(graph=h, b=_rhs(h.n, seed=3)))
        bad = d.submit(SolveRequest(graph=h, b=_rhs(h.n, seed=4),
                                    pipeline=fe))
        assert ok.result(timeout=30.0).converged
        with pytest.raises(RuntimeError, match="poisoned group"):
            bad.result(timeout=30.0)
        assert bad.done() and bad.error() is not None
        assert d.stats()["tenants"]["default"]["failed"] == 1


def test_tenant_budget_rejects_with_tenant_context(svc):
    service, h = svc
    with SolverDaemon(
            service, max_batch_delay_ms=60_000.0,
            tenants={"free": TenantConfig(max_pending_columns=2)}) as d:
        d.submit(SolveRequest(graph=h, b=_rhs(h.n, k=2, seed=5)),
                 tenant="free")
        with pytest.raises(AdmissionError) as ei:
            d.submit(SolveRequest(graph=h, b=_rhs(h.n, seed=6)),
                     tenant="free")
        assert ei.value.tenant == "free"
        assert "free" in str(ei.value)
        assert ei.value.budget == 2 and ei.value.pending == 2
        # another tenant is not blocked by free's budget
        t = d.submit(SolveRequest(graph=h, b=_rhs(h.n, seed=7)),
                     tenant="paid")
        stats = d.stats()["tenants"]
        assert stats["free"]["rejected"] == 1
        assert stats["paid"]["submitted"] == 1
        d.close(drain=True)
        assert t.result(timeout=1.0).converged


def test_starvation_free_selection_under_flood(svc):
    """A heavy tenant floods the queue; the light tenant still lands its
    oldest entry in EVERY size-bounded flush window."""
    service, h = svc
    d = SolverDaemon(service, max_batch_delay_ms=60_000.0,
                     max_batch_columns=3,
                     tenants={"heavy": TenantConfig(weight=8.0),
                              "light": TenantConfig(weight=1.0)},
                     autostart=False)
    heavy = [d.submit(SolveRequest(graph=h, b=_rhs(h.n, seed=20 + i)),
                      tenant="heavy") for i in range(9)]
    light = [d.submit(SolveRequest(graph=h, b=_rhs(h.n, seed=40 + i)),
                      tenant="light") for i in range(3)]
    windows = []
    while True:
        with d._cond:
            if not d._queue:
                break
            batch = d._select_batch_locked()
        windows.append([e.tenant for e in batch])
        d._run_cycle(batch, "size")
    # starvation-freedom: every window formed while 'light' had queued work
    # contains a 'light' entry, flood notwithstanding
    light_remaining = len(light)
    for window in windows:
        if light_remaining > 0:
            assert "light" in window, f"light starved in window {window}"
        light_remaining -= window.count("light")
    assert light_remaining == 0
    # the heavy (weight 8) tenant drains more columns overall
    flat = [t for w in windows for t in w]
    assert flat.count("heavy") == 9 and flat.count("light") == 3
    d.close(drain=True)
    for t in heavy + light:
        assert t.result(timeout=1.0).converged


def test_weighted_fill_prefers_heavier_lane(svc):
    """With equal backlogs, the weighted deficit fill gives the heavier
    lane more slots per window (beyond the one-each starvation floor)."""
    service, h = svc
    d = SolverDaemon(service, max_batch_delay_ms=60_000.0,
                     max_batch_columns=6,
                     tenants={"a": TenantConfig(weight=4.0),
                              "b": TenantConfig(weight=1.0)},
                     autostart=False)
    for i in range(8):
        d.submit(SolveRequest(graph=h, b=_rhs(h.n, seed=60 + i)), tenant="a")
        d.submit(SolveRequest(graph=h, b=_rhs(h.n, seed=80 + i)), tenant="b")
    with d._cond:
        batch = d._select_batch_locked()
    first = [e.tenant for e in batch]
    assert first.count("a") > first.count("b") >= 1
    d._run_cycle(batch, "size")
    d.close(drain=True)


def test_shutdown_drain_resolves_everything(svc):
    service, h = svc
    d = SolverDaemon(service, max_batch_delay_ms=60_000.0)
    tickets = [d.submit(SolveRequest(graph=h, b=_rhs(h.n, seed=100 + i)))
               for i in range(5)]
    assert not any(t.done() for t in tickets)
    d.close(drain=True)
    for t in tickets:
        assert t.done()
        assert t.result(timeout=1.0).converged
    assert not d.running
    assert d.stats()["daemon"]["triggers"]["drain"] >= 1


def test_shutdown_without_drain_fails_deterministically(svc):
    service, h = svc
    d = SolverDaemon(service, max_batch_delay_ms=60_000.0)
    tickets = [d.submit(SolveRequest(graph=h, b=_rhs(h.n, seed=120 + i)))
               for i in range(3)]
    d.close(drain=False)
    for t in tickets:
        assert t.done()
        with pytest.raises(DaemonShutdownError):
            t.result(timeout=1.0)
    with pytest.raises(RuntimeError, match="closed"):
        d.submit(SolveRequest(graph=h, b=_rhs(h.n, seed=130)))
    d.close()   # idempotent


def test_multithreaded_submit_result_race(svc):
    """N producer threads x the deadline flusher: every ticket resolves to
    ITS OWN request's solution (no cross-wiring), queue accounting lands
    on zero, and nothing deadlocks."""
    service, h = svc
    n_threads, per_thread = 4, 5
    with SolverDaemon(service, max_batch_delay_ms=10.0) as d:
        results = {}
        errors = []

        def producer(tid):
            try:
                for i in range(per_thread):
                    seed = 1000 + tid * 100 + i
                    b = _rhs(h.n, seed=seed)
                    t = d.submit(SolveRequest(graph=h, b=b),
                                 tenant=f"t{tid}")
                    res = t.result(timeout=60.0)
                    results[(tid, i)] = (b, res)
            except Exception as e:   # pragma: no cover - failure reporting
                errors.append(e)

        threads = [threading.Thread(target=producer, args=(tid,))
                   for tid in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(120.0)
        assert not errors, errors
        assert len(results) == n_threads * per_thread
        # every response solves its own rhs: L x = b (mean-removed)
        g = h.graph
        for (tid, i), (b, res) in results.items():
            assert res.converged, (tid, i)
            bc = b.astype(np.float64)
            bc = bc - bc.mean()
            x = np.asarray(res.x, dtype=np.float64)
            r = bc - g.laplacian_matvec(x)
            assert np.linalg.norm(r) <= 1e-4 * np.linalg.norm(bc), (tid, i)
    # after close() the flusher has joined: accounting is quiescent
    stats = d.stats()
    assert stats["daemon"]["pending_columns"] == 0
    assert stats["daemon"]["queue_depth"] == 0
    lanes = stats["tenants"]
    for tid in range(n_threads):
        assert lanes[f"t{tid}"]["solved"] == per_thread
        assert lanes[f"t{tid}"]["pending_columns"] == 0


def test_slo_violation_counter(svc):
    """An impossible SLO budget marks every flushed group as a breach; the
    counter shows up in daemon stats AND the service metrics registry."""
    service, h = svc
    before = service.metrics.counter("serve.slo_violations").value
    with SolverDaemon(service, max_batch_delay_ms=20.0,
                      slo_budget_ms=1e-9) as d:
        t = d.submit(SolveRequest(graph=h, b=_rhs(h.n, seed=200)))
        assert t.result(timeout=30.0).converged
        assert d.stats()["daemon"]["slo_violations"] >= 1
    after = service.metrics.counter("serve.slo_violations").value
    assert after - before >= 1
    mstats = service.stats()["metrics"]
    assert mstats["serve.slo_violations"] >= 1


def test_slo_budget_derives_from_delay_knob(svc):
    service, _ = svc
    d = SolverDaemon(service, max_batch_delay_ms=25.0, autostart=False)
    assert d.slo_budget_ms == pytest.approx(100.0)
    d.close()
    d2 = SolverDaemon(service, max_batch_delay_ms=25.0, slo_budget_ms=80.0,
                      autostart=False)
    assert d2.slo_budget_ms == 80.0
    d2.close()


def test_serve_metrics_surface(svc):
    """Queue-depth gauge + latency histograms land in the service metrics
    under the serve.* namespace."""
    service, h = svc
    with SolverDaemon(service, max_batch_delay_ms=10.0) as d:
        t = d.submit(SolveRequest(graph=h, b=_rhs(h.n, seed=300)))
        assert t.result(timeout=30.0).converged
    m = service.stats()["metrics"]
    assert m["serve.queue_depth"] == 0
    assert m["serve.queue_wait_ms"]["count"] >= 1
    assert m["serve.e2e_ms"]["count"] >= 1
    assert m["serve.e2e_ms"]["p50"] > 0
    assert m["serve.cycles"] >= 1


def test_flush_cycle_span_emitted(svc):
    service, h = svc
    tr = get_tracer()
    was = tr.enabled
    tr.enable()
    tr.clear()
    try:
        with SolverDaemon(service, max_batch_delay_ms=10.0) as d:
            t = d.submit(SolveRequest(graph=h, b=_rhs(h.n, seed=400)))
            assert t.result(timeout=30.0).converged
        names = tr.span_names()
        assert "serve.flush_cycle" in names
        assert "solver.group" in names     # nested: the scheduler ran inside
        cycle = next(e for e in tr.events()
                     if e["name"] == "serve.flush_cycle")
        assert cycle["args"]["requests"] == 1
        assert cycle["args"]["trigger"] in ("deadline", "size", "drain")
    finally:
        tr.clear()
        tr.enabled = was


def test_constructor_validation(svc):
    service, _ = svc
    with pytest.raises(ValueError, match="max_batch_delay_ms"):
        SolverDaemon(service, max_batch_delay_ms=0.0)
    with pytest.raises(ValueError, match="max_batch_columns"):
        SolverDaemon(service, max_batch_columns=0)
    with pytest.raises(TypeError, match="TenantConfig"):
        SolverDaemon(service, tenants={"a": {"weight": 2.0}},
                     autostart=False)
    with pytest.raises(ValueError, match="weight"):
        TenantConfig(weight=0.0)


# ---------------------------------------------------------------------------
# Queue-side TTL: SolveRequest(deadline_ms=...) expiry
# ---------------------------------------------------------------------------

def test_expiry_manual_clock_fails_only_deadlined_ticket(svc):
    """Deterministic TTL: with an injected clock, an entry whose
    ``deadline_ms`` has lapsed is expired at the next sweep — the
    drain path included — while deadline-free neighbors still solve."""
    service, h = svc
    now = [0.0]
    d = SolverDaemon(service, max_batch_delay_ms=60_000.0,
                     autostart=False, clock=lambda: now[0])
    doomed = d.submit(SolveRequest(graph=h, b=_rhs(h.n, seed=200),
                                   deadline_ms=50.0))
    safe = d.submit(SolveRequest(graph=h, b=_rhs(h.n, seed=201)))
    now[0] = 0.2                       # 200 ms later: 50 ms TTL long gone
    d.close(drain=True)                # drain sweeps expiries first
    with pytest.raises(DeadlineExceededError) as ei:
        doomed.result(timeout=1.0)
    err = ei.value
    assert err.deadline_ms == 50.0
    assert err.waited_ms >= 50.0
    assert safe.result(timeout=1.0).converged
    st = d.stats()["daemon"]
    assert st["expired"] == 1


def test_expiry_fires_from_live_flusher_before_batch_deadline(svc):
    """The flusher's wait is min(batch deadline, earliest TTL): a 30 ms
    TTL inside a 500 ms batch window expires in ~30 ms, not 500."""
    service, h = svc
    with SolverDaemon(service, max_batch_delay_ms=500.0) as d:
        t = d.submit(SolveRequest(graph=h, b=_rhs(h.n, seed=210),
                                  deadline_ms=30.0), tenant="default")
        t0 = time.perf_counter()
        with pytest.raises(DeadlineExceededError):
            t.result(timeout=5.0)
        elapsed = time.perf_counter() - t0
        assert elapsed < 0.45          # did NOT wait out the batch window
        st = d.stats()
        assert st["daemon"]["expired"] == 1
        assert st["tenants"]["default"]["expired"] == 1
    m = service.stats()["metrics"]
    assert m["serve.expired"] >= 1          # module-scoped service: >=
    assert m["serve.tenant.default.expired"] >= 1


def test_deadline_ms_validation_and_sync_path(svc):
    service, h = svc
    with pytest.raises(ValueError, match="deadline_ms"):
        service.submit(SolveRequest(graph=h, b=_rhs(h.n, seed=220),
                                    deadline_ms=-5.0))
    # the sync service accepts but ignores queue TTLs (no background
    # queue to age in): the solve just runs
    t = service.submit(SolveRequest(graph=h, b=_rhs(h.n, seed=221),
                                    deadline_ms=1e-3))
    service.flush()
    assert t.result().converged
