"""repro.solver tests: hierarchy shape, device PCG numerics parity with the
host solver, preconditioner quality, cache identity, service batching."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import barabasi_albert, grid2d, mesh2d
from repro.core.pcg import pcg_host
from repro.solver import (LRUCache, SolveRequest, SolverService, batched_pcg,
                          build_hierarchy, ell_laplacian, graph_fingerprint,
                          make_matvec, make_solver)
from repro.solver.hierarchy import contract, subgraph


def _rhs(g, k=1, seed=0):
    rng = np.random.default_rng(seed)
    b = rng.standard_normal((g.n, k)).astype(np.float32)
    return b - b.mean(axis=0)


def _rebase(x):
    """Laplacian solutions are defined up to a constant; pin x[0] = 0."""
    x = np.asarray(x, dtype=np.float64)
    return x - x[0]


# -- matvec ------------------------------------------------------------------

def test_matvec_kernel_matches_ref_and_scipy():
    g = mesh2d(11, 11, seed=2)
    idx, val = ell_laplacian(g)
    X = jnp.asarray(_rhs(g, k=4, seed=1))
    ref = make_matvec(idx, val, "ref")(X)
    ker = make_matvec(idx, val, "kernel")(X)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    want = g.laplacian() @ np.asarray(X)
    np.testing.assert_allclose(np.asarray(ref), want, rtol=1e-4, atol=1e-4)


# -- device PCG vs host ------------------------------------------------------

@pytest.mark.parametrize("make", [
    lambda: grid2d(12, 12, seed=1),
    lambda: mesh2d(15, 15, seed=2),
    lambda: barabasi_albert(250, 3, seed=3),
])
def test_device_pcg_matches_host(make):
    g = make()
    b = _rhs(g, k=1, seed=4)
    solve = make_solver(*ell_laplacian(g), precond="none")
    res = solve(jnp.asarray(b), tol=1e-5, maxiter=5000)
    assert bool(np.asarray(res.converged).all())
    assert float(np.asarray(res.relres).max()) <= 1e-5

    host = pcg_host(g.laplacian(), b[:, 0].astype(np.float64),
                    tol=1e-5, maxiter=5000)
    assert host.converged
    # same Krylov method on the same system (projected vs grounded): the
    # iterate counts track each other and the solutions coincide.
    it_dev = int(np.asarray(res.iters)[0])
    assert it_dev <= 2 * host.iters and host.iters <= 2 * it_dev
    xd, xh = _rebase(np.asarray(res.x)[:, 0]), _rebase(host.x)
    scale = max(np.abs(xh).max(), 1.0)
    np.testing.assert_allclose(xd, xh, atol=2e-3 * scale)


def test_batched_pcg_columns_match_single_solves():
    g = mesh2d(13, 13, seed=5)
    idx, val = ell_laplacian(g)
    B = _rhs(g, k=5, seed=6)
    solve = make_solver(idx, val, precond="none")
    res = solve(jnp.asarray(B), tol=1e-5, maxiter=5000)
    for j in range(B.shape[1]):
        one = solve(jnp.asarray(B[:, j:j + 1]), tol=1e-5, maxiter=5000)
        # each column is independent: solving it alone gives the same answer
        np.testing.assert_allclose(_rebase(np.asarray(res.x)[:, j]),
                                   _rebase(np.asarray(one.x)[:, 0]),
                                   atol=1e-3)
        assert int(np.asarray(res.iters)[j]) == int(np.asarray(one.iters)[0])


def test_kernel_and_ref_paths_agree_end_to_end():
    g = grid2d(10, 10, seed=7)
    idx, val = ell_laplacian(g)
    b = jnp.asarray(_rhs(g, k=2, seed=8))
    xr = make_solver(idx, val, precond="none", matvec_impl="ref")(b)
    xk = make_solver(idx, val, precond="none", matvec_impl="kernel")(b)
    np.testing.assert_allclose(np.asarray(xr.x), np.asarray(xk.x),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(xr.iters), np.asarray(xk.iters))


# -- hierarchy ---------------------------------------------------------------

def test_hierarchy_levels_shrink_monotonically():
    g = mesh2d(22, 22, seed=9)
    hier = build_hierarchy(g, alpha=0.05, coarse_n=32)
    sizes = hier.level_sizes
    assert sizes[0] == g.n
    assert all(a > b for a, b in zip(sizes, sizes[1:]))
    assert sizes[-1] <= 32
    # every fine level's sparsifier is sparser than its graph, never denser
    for lev in hier.levels:
        assert lev.stats["m_sparsifier"] <= lev.stats["m"]
        assert int(np.asarray(lev.agg).max()) == lev.n_coarse - 1


def test_contract_preserves_connectivity_and_total_weight():
    g = barabasi_albert(200, 3, seed=10)
    sg = subgraph(g, np.ones(g.m, dtype=bool))
    agg, coarse = contract(sg)
    assert coarse.n < g.n
    # cross-cluster weight is conserved (build_graph sums parallel edges)
    cu, cv = agg[g.src], agg[g.dst]
    want = g.weight[cu != cv].sum()
    np.testing.assert_allclose(coarse.weight.sum(), want, rtol=1e-5)


def test_hierarchy_contracts_hub_graphs_without_stalling():
    """Star-like graphs stall pairwise-only matching (one pair per level);
    cluster aggregation must keep the per-level shrink at >= 2x."""
    from repro.core import star_hub

    g = star_hub(500, extra=300, seed=30)
    hier = build_hierarchy(g, alpha=0.05, coarse_n=64)
    sizes = hier.level_sizes
    assert sizes[-1] <= 64
    for a, b in zip(sizes, sizes[1:]):
        assert b <= a // 2 + 1


def test_hierarchy_preconditioner_reduces_iterations():
    g = mesh2d(24, 24, seed=11)
    idx, val = ell_laplacian(g)
    b = jnp.asarray(_rhs(g, k=2, seed=12))
    hier = build_hierarchy(g, alpha=0.05)
    raw = make_solver(idx, val, precond="none")(b, tol=1e-5, maxiter=5000)
    pre = make_solver(idx, val, hierarchy=hier, precond="hierarchy")(
        b, tol=1e-5, maxiter=5000)
    assert bool(np.asarray(pre.converged).all())
    assert int(np.asarray(pre.iters).max()) < int(np.asarray(raw.iters).max())
    np.testing.assert_allclose(_rebase(np.asarray(pre.x)),
                               _rebase(np.asarray(raw.x)), atol=2e-3)


# -- cache -------------------------------------------------------------------

def test_cache_hit_returns_identical_object_without_recompute():
    g = mesh2d(10, 10, seed=13)
    calls = []

    def build():
        calls.append(1)
        return ell_laplacian(g)

    cache = LRUCache(capacity=4)
    key = graph_fingerprint(g, extra=("alpha", 0.05))
    v1, s1 = cache.get_or_build(key, build)
    v2, s2 = cache.get_or_build(key, build)
    assert (s1, s2) == ("miss", "mem")
    assert len(calls) == 1
    assert v1 is v2  # the very same object, no rebuild


def test_fingerprint_distinguishes_graphs_and_params():
    g1 = mesh2d(10, 10, seed=13)
    g2 = mesh2d(10, 10, seed=14)
    assert graph_fingerprint(g1) == graph_fingerprint(g1)
    assert graph_fingerprint(g1) != graph_fingerprint(g2)
    assert graph_fingerprint(g1, ("a", 0.05)) != graph_fingerprint(g1, ("a", 0.1))


def test_cache_lru_eviction_and_disk_tier(tmp_path):
    cache = LRUCache(capacity=2, disk_dir=str(tmp_path))
    for i in range(3):
        cache.put(f"k{i}", i)
    assert len(cache) == 2 and cache.evictions == 1
    # k0 fell out of memory but survives on disk
    v, src = cache.get("k0")
    assert (v, src) == (0, "disk")
    # a fresh cache (new process) hits the disk tier
    v, src = LRUCache(capacity=2, disk_dir=str(tmp_path)).get("k2")
    assert (v, src) == (2, "disk")


def test_service_cache_hit_skips_pipeline(tmp_path):
    g = mesh2d(12, 12, seed=15)
    svc = SolverService(alpha=0.05, disk_dir=str(tmp_path))
    b = _rhs(g, k=1, seed=16)[:, 0]
    r1 = svc.solve(g, b)
    r2 = svc.solve(g, b)
    assert (r1.cache, r2.cache) == ("miss", "mem")
    assert svc.cache.stats["misses"] == 1 and svc.cache.stats["hits"] == 1
    np.testing.assert_array_equal(r1.x, r2.x)  # same artifacts, same answer
    # a new service instance warm-starts from disk
    r3 = SolverService(alpha=0.05, disk_dir=str(tmp_path)).solve(g, b)
    assert r3.cache == "disk"
    np.testing.assert_allclose(_rebase(r3.x), _rebase(r1.x), atol=1e-4)


# -- service -----------------------------------------------------------------

def test_service_solution_matches_host_pcg():
    g = mesh2d(14, 14, seed=17)
    b = _rhs(g, k=1, seed=18)[:, 0]
    svc = SolverService(alpha=0.05)
    res = svc.solve(g, b, tol=1e-5)
    assert res.converged
    assert float(res.relres.max()) <= 1e-5
    host = pcg_host(g.laplacian(), b.astype(np.float64), tol=1e-5,
                    maxiter=5000)
    scale = max(np.abs(host.x).max(), 1.0)
    np.testing.assert_allclose(_rebase(res.x), _rebase(host.x),
                               atol=2e-3 * scale)


def test_service_flush_groups_requests_into_one_batch():
    g = mesh2d(12, 12, seed=19)
    svc = SolverService(alpha=0.05)
    b1 = _rhs(g, k=1, seed=20)[:, 0]
    b2 = _rhs(g, k=3, seed=21)
    t1 = svc.submit(SolveRequest(graph=g, b=b1))
    t2 = svc.submit(SolveRequest(graph=g, b=b2))
    out = svc.flush()
    assert out[t1].x.shape == (g.n,)
    assert out[t2].x.shape == (g.n, 3)
    assert out[t1].converged and out[t2].converged
    # both tickets were served by the same artifact build (one group)
    assert svc.cache.stats["misses"] == 1
    single = svc.solve(g, b2[:, 1])
    np.testing.assert_allclose(_rebase(out[t2].x[:, 1]), _rebase(single.x),
                               atol=1e-3)


def test_solve_does_not_drain_submitted_tickets():
    g = mesh2d(10, 10, seed=24)
    svc = SolverService(alpha=0.05)
    b = _rhs(g, k=2, seed=25)
    ticket = svc.submit(SolveRequest(graph=g, b=b[:, 0]))
    direct = svc.solve(g, b[:, 1])       # must not consume the queue
    assert direct.converged
    out = svc.flush()
    assert ticket in out and out[ticket].converged
    np.testing.assert_allclose(
        _rebase(out[ticket].x),
        _rebase(svc.solve(g, b[:, 0]).x), atol=1e-3)


def test_mixed_tolerances_keep_their_own_contracts():
    g = mesh2d(10, 10, seed=26)
    svc = SolverService(alpha=0.05)
    b = _rhs(g, k=2, seed=27)
    loose = svc.submit(SolveRequest(graph=g, b=b[:, 0], tol=1e-2))
    strict = svc.submit(SolveRequest(graph=g, b=b[:, 1], tol=1e-5))
    out = svc.flush()
    assert out[loose].converged and float(out[loose].relres.max()) <= 1e-2
    assert out[strict].converged and float(out[strict].relres.max()) <= 1e-5


def test_mixed_maxiter_budgets_are_honored_per_request():
    g = mesh2d(10, 10, seed=31)
    svc = SolverService(alpha=0.05, precond="none")
    b = _rhs(g, k=2, seed=32)
    small = svc.submit(SolveRequest(graph=g, b=b[:, 0], maxiter=5))
    large = svc.submit(SolveRequest(graph=g, b=b[:, 1], maxiter=5000))
    out = svc.flush()
    assert int(out[small].iters.max()) <= 5 and not out[small].converged
    assert out[large].converged


def test_service_rejects_mismatched_rhs():
    g = grid2d(6, 6, seed=28)
    svc = SolverService(alpha=0.05)
    with pytest.raises(ValueError, match="does not match graph"):
        svc.solve(g, np.ones(g.n + 1, np.float32))


def test_solver_closures_bounded_by_cache_capacity():
    svc = SolverService(alpha=0.05, precond="none", cache_capacity=2)
    rng = np.random.default_rng(29)
    for s in range(4):
        g = grid2d(6, 6, seed=s)
        b = rng.standard_normal(g.n).astype(np.float32)
        assert svc.solve(g, b - b.mean()).converged
    assert len(svc._solvers) <= 2


def test_batched_pcg_handles_zero_columns():
    g = grid2d(8, 8, seed=22)
    idx, val = ell_laplacian(g)
    B = np.zeros((g.n, 2), np.float32)
    B[:, 0] = _rhs(g, k=1, seed=23)[:, 0]
    mv = make_matvec(idx, val, "ref")
    res = batched_pcg(mv, jnp.asarray(B), tol=1e-5, maxiter=2000)
    assert bool(np.asarray(res.converged).all())
    assert int(np.asarray(res.iters)[1]) == 0  # zero RHS converges instantly
