"""Persisted GraphStore: atomic writes, rehydration, restart round-trips.

The contract: with ``persist_dir`` set, every registered graph lands on
disk as ``<fingerprint>.npz`` (atomic tmp + rename), a NEW store built on
the same directory rehydrates the handles **without re-hashing** the edge
arrays (it adopts the persisted digest), and a restarted ``SolverService``
therefore hits its disk artifact cache directly — registration costs zero
``hash_events`` and the solve costs zero artifact rebuilds.
"""
import os

import numpy as np
import pytest

from repro.core import build_graph, grid2d
from repro.solver import GraphStore, SolverService
from repro.solver import cache as cache_mod


def _store_dir(tmp_path):
    return str(tmp_path / "graphstore")


def test_register_persists_npz_atomically(tmp_path):
    d = _store_dir(tmp_path)
    store = GraphStore(persist_dir=d)
    g = grid2d(5, 5, seed=0)
    h = store.register(g)
    files = os.listdir(d)
    assert files == [f"{h.fingerprint}.npz"]
    assert not [f for f in files if f.endswith(".tmp")]
    # idempotent: re-registering (object or structural copy) writes nothing
    store.register(g)
    store.register(build_graph(g.n, g.src.copy(), g.dst.copy(),
                               g.weight.copy()))
    assert store.stats["persisted"] == 1
    assert len(os.listdir(d)) == 1


def test_rehydration_restores_handles_without_rehashing(tmp_path):
    d = _store_dir(tmp_path)
    g = grid2d(6, 6, seed=1)
    h = GraphStore(persist_dir=d).register(g)

    before = cache_mod.HASH_EVENTS
    store2 = GraphStore(persist_dir=d)
    assert cache_mod.HASH_EVENTS == before    # adopted digest, no O(m) hash
    assert store2.stats["rehydrated"] == 1
    h2 = store2.get(h.fingerprint)
    assert h2 is not None and h2.fingerprint == h.fingerprint
    g2 = h2.graph
    assert g2.n == g.n
    np.testing.assert_array_equal(g2.src, g.src)
    np.testing.assert_array_equal(g2.dst, g.dst)
    np.testing.assert_array_equal(g2.weight, g.weight)
    # rehydrated arrays are frozen exactly like fingerprinted ones
    for arr in (g2.src, g2.dst, g2.weight):
        assert not arr.flags.writeable
    assert [hh.fingerprint for hh in store2.handles()] == [h.fingerprint]
    # and the handle is live: registering the same content dedups onto it
    assert store2.register(g) is h2


def test_corrupt_and_foreign_files_skipped(tmp_path):
    d = _store_dir(tmp_path)
    store = GraphStore(persist_dir=d)
    h = store.register(grid2d(4, 4, seed=2))
    # torn write
    with open(os.path.join(d, "deadbeef" * 8 + ".npz"), "wb") as f:
        f.write(b"not an npz")
    # digest/filename mismatch (e.g. a renamed file)
    real = os.path.join(d, f"{h.fingerprint}.npz")
    with open(real, "rb") as f:
        blob = f.read()
    with open(os.path.join(d, "0" * len(h.fingerprint) + ".npz"), "wb") as f:
        f.write(blob)
    store2 = GraphStore(persist_dir=d)
    assert store2.stats["rehydrated"] == 1    # only the genuine artifact
    assert store2.get(h.fingerprint) is not None


def test_service_restart_round_trip(tmp_path):
    """register -> kill -> restart -> solve hits the disk artifact cache
    with zero new content hashes: the persisted store + persisted artifact
    tier together make restarts warm."""
    disk = str(tmp_path / "cache")
    g = grid2d(6, 6, seed=3)
    rng = np.random.default_rng(0)
    b = rng.standard_normal(g.n).astype(np.float32)

    svc1 = SolverService(alpha=0.1, disk_dir=disk)
    h1 = svc1.register(g)
    assert svc1.solve(h1, b).converged        # builds + persists artifacts
    assert svc1.store.stats["persisted"] == 1
    del svc1

    svc2 = SolverService(alpha=0.1, disk_dir=disk)   # the "restart"
    assert svc2.store.stats["rehydrated"] == 1
    h2 = svc2.store.get(h1.fingerprint)
    assert h2 is not None
    before = cache_mod.HASH_EVENTS
    sources = svc2.warmup(h2)
    assert list(sources.values()) == ["disk"]  # artifacts straight from disk
    res = svc2.solve(h2, b)
    assert res.converged
    assert cache_mod.HASH_EVENTS == before     # no re-fingerprinting anywhere
    assert svc2.stats()["store"]["rehydrated"] == 1


def test_store_without_persist_dir_unchanged(tmp_path):
    store = GraphStore()
    h = store.register(grid2d(4, 4, seed=4))
    assert "persisted" not in store.stats
    assert store.get(h.fingerprint) is h
    # a service without disk_dir gets an in-memory store
    svc = SolverService(alpha=0.1)
    assert svc.store.persist_dir is None


def test_persist_failure_leaves_no_tmp(tmp_path, monkeypatch):
    d = _store_dir(tmp_path)
    store = GraphStore(persist_dir=d)

    def boom(*a, **k):
        raise OSError("disk full")

    import repro.solver.requests as req_mod
    monkeypatch.setattr(req_mod.np, "savez", boom)
    with pytest.raises(OSError, match="disk full"):
        store.register(grid2d(4, 4, seed=5))
    assert [f for f in os.listdir(d) if f.endswith(".tmp")] == []


# ---------------------------------------------------------------------------
# Bounded persist tier: entries/bytes caps with mtime-LRU eviction
# ---------------------------------------------------------------------------

def _graphs(k, seed0=10):
    return [grid2d(4 + i, 4, seed=seed0 + i) for i in range(k)]


def test_gc_max_entries_evicts_oldest(tmp_path):
    d = _store_dir(tmp_path)
    store = GraphStore(persist_dir=d, max_entries=2)
    handles = []
    for i, g in enumerate(_graphs(5)):
        os.utime(d, None)
        handles.append(store.register(g))
        # deterministic mtime ordering without sleeping
        os.utime(os.path.join(d, f"{handles[-1].fingerprint}.npz"),
                 (i, i))
    store.register(grid2d(12, 4, seed=99))          # triggers final prune
    files = {f for f in os.listdir(d) if f.endswith(".npz")}
    assert len(files) == 2
    # newest mtimes survive; the file just written is among them
    st = store.stats
    assert st["persist_entries"] == 2
    assert st["persist_evictions"] == 4             # 6 persisted, 2 kept
    assert st["max_entries"] == 2 and st["max_bytes"] is None
    # live handles are untouched by disk eviction
    for h in handles:
        assert store.get(h.fingerprint) is h


def test_gc_max_bytes_and_oversized_single_graph(tmp_path):
    d = _store_dir(tmp_path)
    store = GraphStore(persist_dir=d, max_bytes=1)   # everything is over
    h = store.register(grid2d(6, 6, seed=20))
    # the just-written file is never the victim: it stays despite the cap
    assert os.path.exists(os.path.join(d, f"{h.fingerprint}.npz"))
    assert store.stats["persist_evictions"] == 0
    # the next register evicts the old one but keeps the new one
    h2 = store.register(grid2d(7, 7, seed=21))
    files = {f for f in os.listdir(d) if f.endswith(".npz")}
    assert files == {f"{h2.fingerprint}.npz"}
    assert store.stats["persist_evictions"] == 1


def test_gc_reregister_refreshes_recency(tmp_path):
    d = _store_dir(tmp_path)
    store = GraphStore(persist_dir=d, max_entries=2)
    g_old, g_mid = grid2d(5, 5, seed=30), grid2d(6, 5, seed=31)
    h_old = store.register(g_old)
    h_mid = store.register(g_mid)
    os.utime(os.path.join(d, f"{h_old.fingerprint}.npz"), (1, 1))
    os.utime(os.path.join(d, f"{h_mid.fingerprint}.npz"), (2, 2))
    store.register(g_old)                            # touch -> now newest
    h_new = store.register(grid2d(7, 5, seed=32))    # prune runs
    files = {f for f in os.listdir(d) if f.endswith(".npz")}
    assert files == {f"{h_old.fingerprint}.npz", f"{h_new.fingerprint}.npz"}


def test_gc_service_caps_and_store_conflict(tmp_path):
    disk = str(tmp_path / "cache")
    svc = SolverService(alpha=0.1, disk_dir=disk, store_max_entries=1)
    svc.register(grid2d(4, 4, seed=40))
    svc.register(grid2d(5, 4, seed=41))
    st = svc.stats()["store"]
    assert st["persist_entries"] == 1
    assert st["persist_evictions"] == 1
    with pytest.raises(ValueError, match="set the caps on it"):
        SolverService(alpha=0.1, store=GraphStore(), store_max_entries=3)
