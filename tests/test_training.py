"""Training substrate tests: optimizer, data, checkpoint/restart (fault
tolerance), gradient compression, loss-goes-down integration."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.dist.compress import (compress_grads, dequantize,
                                 init_error_feedback, quantize)
from repro.models import init_params, loss_fn
from repro.train import checkpoint as ckpt
from repro.train.data import batches, host_slice, make_batch
from repro.train.optimizer import (AdamWConfig, adamw_update, init_opt_state,
                                   schedule)
from repro.train.trainer import ResilientTrainer, TrainConfig


def test_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    lrs = [float(schedule(cfg, jnp.int32(s))) for s in [0, 5, 10, 55, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, abs=1e-6)


def test_adamw_reduces_quadratic():
    p = {"w": jnp.ones((8, 8)) * 3.0}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=1000, min_lr_frac=1.0)
    st = init_opt_state(p, cfg)
    for _ in range(200):
        g = {"w": 2 * p["w"]}
        p, st, m = adamw_update(p, g, st, cfg)
    assert float(jnp.abs(p["w"]).max()) < 0.2


def test_data_determinism_and_host_slicing():
    cfg = reduced(get_config("qwen3-4b"))
    a = make_batch(cfg, 8, 16, step=3, seed=7)
    b = make_batch(cfg, 8, 16, step=3, seed=7)
    assert np.array_equal(a["tokens"], b["tokens"])
    c = make_batch(cfg, 8, 16, step=4, seed=7)
    assert not np.array_equal(a["tokens"], c["tokens"])
    s0 = host_slice(a, 0, 4)
    s3 = host_slice(a, 3, 4)
    assert s0["tokens"].shape == (2, 16)
    assert np.array_equal(np.concatenate(
        [host_slice(a, i, 4)["tokens"] for i in range(4)]), a["tokens"])


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1000,)).astype(np.float32)) * 5
    z = quantize(x)
    y = dequantize(z)
    blk_max = 5 * 3.5  # loose bound
    assert float(jnp.abs(y - x).max()) <= blk_max / 127.0
    assert z.q.dtype == jnp.int8


def test_error_feedback_preserves_signal():
    """Sum of compressed grads + final error == sum of true grads."""
    rng = np.random.default_rng(1)
    g_true = [jnp.asarray(rng.standard_normal((64,)).astype(np.float32))
              for _ in range(20)]
    ef = {"g": jnp.zeros((64,), jnp.bfloat16)}
    acc = jnp.zeros((64,))
    for g in g_true:
        gq, ef = compress_grads({"g": g}, ef)
        acc = acc + gq["g"]
    total_true = sum(g_true)
    resid = acc + ef["g"].astype(jnp.float32) - total_true
    scale = float(jnp.abs(total_true).max())
    assert float(jnp.abs(resid).max()) < 0.05 * max(scale, 1.0)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    ckpt.save(str(tmp_path), 5, tree)
    assert ckpt.latest_step(str(tmp_path)) == 5
    out = ckpt.restore(str(tmp_path), 5, tree)
    assert np.array_equal(np.asarray(out["a"]), np.arange(10))
    ckpt.save(str(tmp_path), 7, tree)
    ckpt.prune(str(tmp_path), keep=1)
    assert ckpt.latest_step(str(tmp_path)) == 7
    assert not os.path.isdir(os.path.join(str(tmp_path), "step_00000005"))


def test_loss_decreases_small_model():
    cfg = reduced(get_config("qwen3-4b"))
    tr = ResilientTrainer(cfg, TrainConfig(
        opt=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60),
        remat=False), ckpt_dir="/tmp/_no_ckpt_a", ckpt_every=10_000)
    data_fn = lambda s: batches(cfg, 8, 16, seed=0, start_step=s)  # noqa: E731
    _, _, losses = tr.run(data_fn, steps=40, resume=False)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5


def test_crash_restart_bit_identical(tmp_path):
    """Fault tolerance: crash at step 12, restart, trajectory matches an
    uninterrupted run exactly (checkpoint + deterministic data rewind)."""
    cfg = reduced(get_config("gemma2-2b"))
    tc = TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50),
                     remat=False)
    data_fn = lambda s: batches(cfg, 4, 16, seed=3, start_step=s)  # noqa: E731

    d1 = str(tmp_path / "run_uninterrupted")
    tr1 = ResilientTrainer(cfg, tc, ckpt_dir=d1, ckpt_every=5)
    p1, _, losses1 = tr1.run(data_fn, steps=20, resume=False, seed=4)

    d2 = str(tmp_path / "run_crashy")
    tr2 = ResilientTrainer(cfg, tc, ckpt_dir=d2, ckpt_every=5)
    with pytest.raises(RuntimeError, match="simulated node failure"):
        tr2.run(data_fn, steps=20, fail_at=12, resume=False, seed=4)
    # restart: resumes from step 10 checkpoint
    tr3 = ResilientTrainer(cfg, tc, ckpt_dir=d2, ckpt_every=5)
    p3, _, losses3 = tr3.run(data_fn, steps=20, resume=True, seed=4)
    assert losses3 == losses1[10:]
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grad_accumulation_matches_big_batch():
    cfg = reduced(get_config("starcoder2-15b"))
    params = init_params(cfg, jax.random.key(0))
    from repro.train.trainer import make_train_step
    from repro.train.optimizer import init_opt_state

    tc1 = TrainConfig(opt=AdamWConfig(lr=1e-3), microbatches=1, remat=False)
    tc2 = TrainConfig(opt=AdamWConfig(lr=1e-3), microbatches=2, remat=False)
    b = make_batch(cfg, 8, 16, step=0, seed=0)
    b = {k: jnp.asarray(v) for k, v in b.items()}
    ef = jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params)

    s1 = make_train_step(cfg, tc1)
    s2 = make_train_step(cfg, tc2)
    copy = lambda t: jax.tree.map(jnp.copy, t)  # noqa: E731  (donated bufs)
    p1, _, _, m1 = s1(copy(params), init_opt_state(params, tc1.opt), copy(ef), b)
    p2, _, _, m2 = s2(copy(params), init_opt_state(params, tc2.opt), copy(ef), b)
    # same data; accumulated grads average over microbatches
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-3)
    for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32),
                                   rtol=2e-3, atol=2e-5)


def test_compressed_training_still_learns():
    cfg = reduced(get_config("qwen3-4b"))
    tr = ResilientTrainer(cfg, TrainConfig(
        opt=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60),
        remat=False, compress_grads=True),
        ckpt_dir="/tmp/_no_ckpt_b", ckpt_every=10_000)
    data_fn = lambda s: batches(cfg, 8, 16, seed=0, start_step=s)  # noqa: E731
    _, _, losses = tr.run(data_fn, steps=40, resume=False)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5
