"""Suite-wide fixtures/gates.

If `hypothesis` is missing (hermetic container — no network installs), wire
the deterministic stub in its place BEFORE test modules import it.  The real
package, when installed, always takes precedence.
"""
import importlib.util
import os
import sys

if importlib.util.find_spec("hypothesis") is None:
    _here = os.path.dirname(__file__)
    spec = importlib.util.spec_from_file_location(
        "hypothesis", os.path.join(_here, "_hypothesis_stub.py"))
    stub = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(stub)
    sys.modules["hypothesis"] = stub
    sys.modules["hypothesis.strategies"] = stub.strategies
