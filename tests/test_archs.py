"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs — plus decode-step consistency."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, reduced
from repro.models import (init_params, loss_fn, decode_step, init_cache,
                          prefill, param_count, vocab_padded)


def tiny_batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.frontend and cfg.enc_layers == 0:
        batch["frontend"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_len, cfg.frontend_dim)),
            jnp.float32)
    if cfg.enc_layers:
        batch["src"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.frontend_dim or cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_forward_and_grad(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.key(0))
    assert param_count(params) > 0
    batch = tiny_batch(cfg)

    loss, metrics = loss_fn(params, cfg, batch, remat=False)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0

    grads = jax.grad(lambda p: loss_fn(p, cfg, batch, remat=True)[0])(params)
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_decode_step(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.key(1))
    B, C = 2, 32
    rng = np.random.default_rng(1)
    src_len = 8 if cfg.enc_layers else 0
    caches = init_cache(cfg, B, C, src_len=src_len)
    if cfg.enc_layers:
        # populate cross k/v via prefill on a short prompt
        src = jnp.asarray(rng.standard_normal((B, src_len, cfg.frontend_dim)),
                          jnp.float32)
        _, caches = prefill(params, cfg,
                            jnp.asarray(rng.integers(0, cfg.vocab, (B, 4)),
                                        jnp.int32), C, src=src)
        start = 4
    else:
        start = 0
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
    logits, caches = decode_step(params, cfg, caches, tok,
                                 jnp.int32(start))
    assert logits.shape == (B, vocab_padded(cfg))
    assert np.all(np.isfinite(np.asarray(logits))), arch
    # a second step advances without shape churn
    logits2, _ = decode_step(params, cfg, caches, tok, jnp.int32(start + 1))
    assert np.all(np.isfinite(np.asarray(logits2))), arch


@pytest.mark.parametrize("arch", ["qwen3-4b", "mixtral-8x22b",
                                  "falcon-mamba-7b", "hymba-1.5b"])
def test_prefill_matches_decode(arch):
    """Greedy continuation after prefill == token-by-token decode."""
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.key(2))
    rng = np.random.default_rng(2)
    B, S, C = 1, 8, 32
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    lp, caches = prefill(params, cfg, toks, C)
    # same tokens fed step-by-step
    caches2 = init_cache(cfg, B, C)
    for t in range(S):
        ld, caches2 = decode_step(params, cfg, caches2, toks[:, t:t + 1],
                                  jnp.int32(t))
    np.testing.assert_allclose(np.asarray(lp), np.asarray(ld),
                               rtol=2e-2, atol=2e-2)
