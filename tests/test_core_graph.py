"""Unit tests: graph substrate, spanning tree, lifting primitives."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import build_graph, grid2d, mesh2d, barabasi_albert, star_hub
from repro.core.spanning_tree import bfs_dist, build_spanning_tree
from repro.core import lifting as lf


def nx_graph(g):
    import networkx as nx

    gx = nx.Graph()
    gx.add_nodes_from(range(g.n))
    for s, d, w in zip(g.src, g.dst, g.weight):
        gx.add_edge(int(s), int(d), weight=float(w))
    return gx


def test_build_graph_dedup_and_validate():
    g = build_graph(4, [0, 1, 0, 2], [1, 2, 1, 3], [1.0, 2.0, 3.0, 1.0])
    assert g.m == 3  # (0,1) deduped
    w01 = g.weight[(g.src == 0) & (g.dst == 1)]
    assert np.isclose(w01, 4.0)  # weights summed
    with pytest.raises(ValueError):
        build_graph(3, [0, 1], [0, 2], [1.0, 1.0])  # self loop
    with pytest.raises(ValueError):
        build_graph(4, [0, 1], [1, 0], [1.0, 1.0])  # disconnected (node 2,3)


def test_bfs_matches_networkx():
    import networkx as nx

    g = mesh2d(7, 9, seed=0)
    usrc = jnp.concatenate([jnp.asarray(g.src), jnp.asarray(g.dst)])
    udst = jnp.concatenate([jnp.asarray(g.dst), jnp.asarray(g.src)])
    dist = np.asarray(bfs_dist(g.n, usrc, udst, 5))
    ref = nx.single_source_shortest_path_length(nx_graph(g), 5)
    for v, d in ref.items():
        assert dist[v] == d


def test_spanning_tree_is_max_weight_tree():
    import networkx as nx

    for g in [grid2d(8, 8, seed=1), barabasi_albert(120, 3, seed=2)]:
        tree = build_spanning_tree(g.n, jnp.asarray(g.src), jnp.asarray(g.dst),
                                   jnp.asarray(g.weight))
        mask = np.asarray(tree.in_tree)
        assert mask.sum() == g.n - 1
        # acyclic + connected via networkx
        gx = nx.Graph()
        gx.add_nodes_from(range(g.n))
        for s, d in zip(g.src[mask], g.dst[mask]):
            gx.add_edge(int(s), int(d))
        assert nx.is_tree(gx)
        # maximum total effective weight vs networkx MST on same weights
        from repro.core.spanning_tree import bfs_dist, effective_weights
        deg = np.zeros(g.n, np.int32)
        np.add.at(deg, g.src, 1)
        np.add.at(deg, g.dst, 1)
        root = int(np.argmax(deg))
        usrc = jnp.concatenate([jnp.asarray(g.src), jnp.asarray(g.dst)])
        udst = jnp.concatenate([jnp.asarray(g.dst), jnp.asarray(g.src)])
        rd = bfs_dist(g.n, usrc, udst, root)
        eff = np.asarray(effective_weights(
            g.n, jnp.asarray(g.src), jnp.asarray(g.dst), jnp.asarray(g.weight),
            jnp.asarray(deg), rd))
        gx2 = nx.Graph()
        for i, (s, d) in enumerate(zip(g.src, g.dst)):
            gx2.add_edge(int(s), int(d), weight=float(eff[i]))
        ref = nx.maximum_spanning_tree(gx2)
        ref_w = sum(d["weight"] for _, _, d in ref.edges(data=True))
        ours = float(eff[mask].sum())
        assert np.isclose(ours, ref_w, rtol=1e-5)


def test_parent_depth_consistency():
    g = mesh2d(6, 6, seed=3)
    tree = build_spanning_tree(g.n, jnp.asarray(g.src), jnp.asarray(g.dst),
                               jnp.asarray(g.weight))
    parent = np.asarray(tree.parent)
    depth = np.asarray(tree.depth)
    root = int(tree.root)
    assert parent[root] == root and depth[root] == 0
    for v in range(g.n):
        if v != root:
            assert depth[v] == depth[parent[v]] + 1


def test_lca_and_resistance_vs_networkx():
    import networkx as nx

    g = barabasi_albert(80, 2, seed=4)
    tree = build_spanning_tree(g.n, jnp.asarray(g.src), jnp.asarray(g.dst),
                               jnp.asarray(g.weight))
    lift = lf.build_lifting(g.n, tree.parent, tree.parent_w, tree.depth)
    mask = np.asarray(tree.in_tree)
    gx = nx.Graph()
    for s, d, w in zip(g.src[mask], g.dst[mask], g.weight[mask]):
        gx.add_edge(int(s), int(d), r=1.0 / float(w))
    root = int(tree.root)
    rng = np.random.default_rng(0)
    us = rng.integers(0, g.n, 50)
    vs = rng.integers(0, g.n, 50)
    lcas = np.asarray(lf.lca(lift, jnp.asarray(us), jnp.asarray(vs)))
    rt = np.asarray(lf.resistance_distance(
        lift, jnp.asarray(us), jnp.asarray(vs), jnp.asarray(lcas)))
    import networkx.algorithms.lowest_common_ancestors as nxl
    tree_d = nx.bfs_tree(gx, root)
    pairs = list(zip(us.tolist(), vs.tolist()))
    ref_lca = dict(nxl.tree_all_pairs_lowest_common_ancestor(
        tree_d, root=root, pairs=pairs))
    for (u, v), l_ref in ref_lca.items():
        i = pairs.index((u, v))
        assert lcas[i] == l_ref
        ref_r = nx.shortest_path_length(gx, u, v, weight="r")
        assert np.isclose(rt[i], ref_r, rtol=1e-5), (u, v)


def test_ancestor_signature_distance_check():
    """match_table(u, v, beta) must equal tree-dist(u,v) <= beta exactly."""
    import networkx as nx

    g = barabasi_albert(60, 2, seed=5)
    tree = build_spanning_tree(g.n, jnp.asarray(g.src), jnp.asarray(g.dst),
                               jnp.asarray(g.weight))
    c = 8
    sig = np.asarray(lf.ancestor_signatures(tree.parent, c))
    mask = np.asarray(tree.in_tree)
    gx = nx.Graph()
    gx.add_nodes_from(range(g.n))
    for s, d in zip(g.src[mask], g.dst[mask]):
        gx.add_edge(int(s), int(d))
    dist = dict(nx.all_pairs_shortest_path_length(gx))
    from repro.core.recovery import match_table
    rng = np.random.default_rng(1)
    us = rng.integers(0, g.n, 40)
    vs = rng.integers(0, g.n, 40)
    for beta in [0, 1, 3, 8]:
        got = np.asarray(match_table(
            jnp.asarray(sig[us]), jnp.asarray(sig[vs]),
            jnp.full((len(us),), beta)))
        for i, u in enumerate(us):
            for j, v in enumerate(vs):
                want = dist[int(u)][int(v)] <= beta
                assert got[i, j] == want, (u, v, beta)
