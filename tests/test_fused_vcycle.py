"""Fused V-cycle kernel suite: parity with the unfused composition.

The fused kernels (`repro.kernels.vcycle_fused`) share the polynomial
definition (`cheby_recurrence`), the einsum contraction, and the
segment-sum with the unfused jnp path, so under interpret mode the two
agree to f32 rounding (the kernels jit separately, so XLA may reassociate
reductions differently — ulp-level, not bitwise).  The serving contract
asserted here: identical PCG iteration counts (±0) across the suite
graphs.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.graph import (barabasi_albert, grid2d, mesh2d, star_hub,
                              watts_strogatz)
from repro.kernels.vcycle_fused import (cheby_coeffs, make_fused_chebyshev,
                                        make_fused_restrict_residual,
                                        resolve_interpret)
from repro.pipeline import pdgrass_config
from repro.solver.device_pcg import (ell_laplacian, estimate_dinv_rho,
                                     make_chebyshev_smoother, make_matvec,
                                     make_solver, make_vcycle)
from repro.solver.hierarchy import build_hierarchy


def _suite_graphs():
    return {
        "grid": grid2d(10, 10, seed=1),
        "mesh": mesh2d(10, 10, seed=2),
        "ba": barabasi_albert(150, 3, seed=3),
        "star": star_hub(100, extra=60, seed=5),
    }


_GRAPHS = _suite_graphs()


def _level0(g):
    hier = build_hierarchy(g, config=pdgrass_config(alpha=0.05, chunk=256))
    return hier, hier.levels[0]


def _rhs(n, k, seed=0):
    rng = np.random.default_rng(seed)
    r = rng.standard_normal((n, k)).astype(np.float32)
    r -= r.mean(axis=0)
    return jnp.asarray(r)


# ---------------------------------------------------------------------------
# per-kernel parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(_GRAPHS))
@pytest.mark.parametrize("degree", [2, 3])
def test_fused_smoother_matches_unfused(name, degree):
    g = _GRAPHS[name]
    _, lev = _level0(g)
    mv = make_matvec(lev.idx, lev.val, "ref")
    rho = estimate_dinv_rho(mv, lev.diag)
    smooth_ref = make_chebyshev_smoother(mv, lev.diag, rho, degree=degree)
    smooth_fused = make_fused_chebyshev(lev.idx, lev.val, lev.diag, rho,
                                        degree=degree)
    r = _rhs(lev.n, 4, seed=degree)
    # zero initial iterate (pre-smooth form)
    np.testing.assert_allclose(np.asarray(smooth_fused(r)),
                               np.asarray(smooth_ref(r)),
                               rtol=1e-5, atol=1e-6)
    # warm-start form (post-smooth): z argument threads through
    z0 = _rhs(lev.n, 4, seed=degree + 10) * 0.1
    np.testing.assert_allclose(np.asarray(smooth_fused(r, z0)),
                               np.asarray(smooth_ref(r, z0)),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("name", sorted(_GRAPHS))
def test_fused_restrict_residual_matches_unfused(name):
    g = _GRAPHS[name]
    _, lev = _level0(g)
    mv = make_matvec(lev.idx, lev.val, "ref")
    fused = make_fused_restrict_residual(lev.idx, lev.val, lev.agg,
                                         lev.n_coarse)
    r = _rhs(lev.n, 4, seed=3)
    z = _rhs(lev.n, 4, seed=4) * 0.1
    want = jax.ops.segment_sum(r - mv(z), lev.agg,
                               num_segments=lev.n_coarse)
    np.testing.assert_allclose(np.asarray(fused(r, z)), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("tile_n", [32, 64, 256])
@pytest.mark.parametrize("k", [1, 4])
def test_batched_spmv_tile_sweep(tile_n, k):
    g = _GRAPHS["mesh"]
    idx, val = ell_laplacian(g)
    mv_ref = make_matvec(idx, val, "ref")
    mv_fused = make_matvec(idx, val, "fused", tile_n=tile_n)
    x = _rhs(g.n, k, seed=tile_n)
    np.testing.assert_allclose(np.asarray(mv_fused(x)),
                               np.asarray(mv_ref(x)),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# whole-V-cycle parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(_GRAPHS))
@pytest.mark.parametrize("degree", [2, 3])
def test_fused_vcycle_matches_unfused(name, degree):
    g = _GRAPHS[name]
    hier, _ = _level0(g)
    r = _rhs(g.n, 4, seed=degree)
    z_ref = np.asarray(make_vcycle(hier, degree=degree,
                                   matvec_impl="ref")(r))
    z_fused = np.asarray(make_vcycle(hier, degree=degree,
                                     matvec_impl="fused")(r))
    scale = np.abs(z_ref).max()
    np.testing.assert_allclose(z_fused, z_ref, rtol=1e-5,
                               atol=1e-5 * max(scale, 1.0))


@pytest.mark.parametrize("name", sorted(_GRAPHS))
def test_fused_pcg_iteration_counts_identical(name):
    """The serving contract: the fused preconditioner changes HBM traffic,
    not the math — per-column PCG iteration counts match the unfused
    solver exactly (±0)."""
    g = _GRAPHS[name]
    hier, _ = _level0(g)
    idx, val = ell_laplacian(g)
    b = _rhs(g.n, 3, seed=7)
    res_ref = make_solver(idx, val, hierarchy=hier, matvec_impl="ref")(b)
    res_fused = make_solver(idx, val, hierarchy=hier,
                            matvec_impl="fused")(b)
    np.testing.assert_array_equal(np.asarray(res_ref.iters),
                                  np.asarray(res_fused.iters))
    assert bool(np.asarray(res_fused.converged).all())
    # and the solutions agree after re-basing (defined up to a constant)
    x_r = np.asarray(res_ref.x)
    x_f = np.asarray(res_fused.x)
    np.testing.assert_allclose(x_f - x_f[0], x_r - x_r[0],
                               rtol=1e-4, atol=1e-4)


def test_fused_sharded_solver_matches_ref():
    """matvec_impl='fused' on the sharded plane: the per-shard batched
    Pallas contraction must reproduce the jnp shard contraction."""
    from jax.sharding import Mesh

    g = _GRAPHS["mesh"]
    hier, _ = _level0(g)
    idx, val = ell_laplacian(g)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    b = _rhs(g.n, 2, seed=11)
    res_ref = make_solver(idx, val, hierarchy=hier, mesh=mesh,
                          matvec_impl="ref")(b)
    res_fused = make_solver(idx, val, hierarchy=hier, mesh=mesh,
                            matvec_impl="fused")(b)
    np.testing.assert_array_equal(np.asarray(res_ref.iters),
                                  np.asarray(res_fused.iters))
    x_r, x_f = np.asarray(res_ref.x), np.asarray(res_fused.x)
    np.testing.assert_allclose(x_f - x_f[0], x_r - x_r[0],
                               rtol=1e-4, atol=1e-4)


def test_sharded_rejects_kernel_impl():
    from repro.solver.sharded import make_sharded_solver

    with pytest.raises(ValueError, match="fused"):
        make_sharded_solver(jnp.zeros((4, 2), jnp.int32),
                            jnp.zeros((4, 2), jnp.float32),
                            precond="none", mesh=None,
                            matvec_impl="kernel")


# ---------------------------------------------------------------------------
# interpret auto-selection + cache key separation
# ---------------------------------------------------------------------------

def test_resolve_interpret_priority(monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_INTERPRET", raising=False)
    # explicit bool wins over everything
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    # env var wins over backend sniffing
    monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "0")
    assert resolve_interpret(None) is False
    monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "1")
    assert resolve_interpret(None) is True
    # backend default: interpret everywhere but TPU (this container: CPU)
    monkeypatch.delenv("REPRO_KERNEL_INTERPRET")
    assert resolve_interpret(None) is (jax.default_backend() != "tpu")


def test_default_matvec_impl_tracks_interpret(monkeypatch):
    from repro.solver.device_pcg import default_matvec_impl

    monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "1")
    assert default_matvec_impl() == "ref"
    monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "0")
    assert default_matvec_impl() == "fused"


def test_cheby_coeffs_interval():
    theta, delta, sigma = cheby_coeffs(2.0)
    lmax = 1.1 * 2.0
    assert theta == pytest.approx(0.5 * (lmax + lmax / 4))
    assert delta == pytest.approx(0.5 * (lmax - lmax / 4))
    assert sigma == pytest.approx(theta / delta)


def test_service_key_separates_matvec_impl():
    """matvec_impl joins the artifact fingerprint (schema v7): fused- and
    ref-configured services must never alias cache entries."""
    from repro.solver.service import SolverService

    g = _GRAPHS["grid"]
    svc_ref = SolverService(alpha=0.05, matvec_impl="ref")
    svc_fused = SolverService(alpha=0.05, matvec_impl="fused")
    h_ref = svc_ref.register(g)
    h_fused = svc_fused.register(g)
    k_ref = svc_ref._key(h_ref, svc_ref.pipeline)
    k_fused = svc_fused._key(h_fused, svc_fused.pipeline)
    assert k_ref != k_fused


def test_service_fused_end_to_end():
    """A fused-configured service solves and converges through the full
    request plane (artifacts, jit closure cache, refinement)."""
    from repro.solver.service import SolverService

    g = _GRAPHS["grid"]
    svc = SolverService(alpha=0.05, matvec_impl="fused")
    rng = np.random.default_rng(13)
    b = rng.standard_normal(g.n).astype(np.float32)
    resp = svc.solve(g, b)
    assert resp.converged
    lap = g.laplacian()
    x = np.asarray(resp.x, np.float64)
    bn = np.linalg.norm(b - b.mean())
    assert np.linalg.norm((b - b.mean()) - lap @ x) / bn < 1e-4
