"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py).

Kernels run in interpret mode (CPU container; TPU is the target)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels import ops


def _rand_problem(rng, K, m, c1, n_seg=5):
    sig = lambda r: rng.integers(0, 30, size=(r, c1)).astype(np.int32)
    csu, csv = sig(K), sig(K)
    esu, esv = sig(m), sig(m)
    cbeta = rng.integers(-1, c1, size=K).astype(np.int32)
    cseg = rng.integers(0, n_seg, size=K).astype(np.int32)
    eseg = rng.integers(0, n_seg, size=m).astype(np.int32)
    eseg[rng.random(m) < 0.1] = -1  # padding rows
    return map(jnp.asarray, (csu, csv, cbeta, cseg, esu, esv, eseg))


@pytest.mark.parametrize("K,m,c1,tile_m", [
    (8, 64, 9, 32),
    (16, 512, 9, 512),
    (128, 1024, 9, 256),
    (4, 100, 5, 64),      # non-multiple m -> wrapper pads
    (32, 96, 13, 32),     # larger c
])
def test_similarity_kernel_matches_ref(K, m, c1, tile_m):
    rng = np.random.default_rng(K * m)
    csu, csv, cbeta, cseg, esu, esv, eseg = _rand_problem(rng, K, m, c1)
    got = np.asarray(ops.similarity_mark(csu, csv, cbeta, cseg, esu, esv,
                                         eseg, tile_m=tile_m))
    want = np.asarray(ops.similarity_mark_ref(csu, csv, cbeta, cseg,
                                              esu, esv, eseg))
    np.testing.assert_array_equal(got, want)


@given(seed=st.integers(0, 10_000), K=st.sampled_from([1, 8, 33]),
       m=st.sampled_from([32, 200]), c1=st.sampled_from([3, 9]))
@settings(max_examples=10, deadline=None)
def test_similarity_kernel_property(seed, K, m, c1):
    rng = np.random.default_rng(seed)
    csu, csv, cbeta, cseg, esu, esv, eseg = _rand_problem(rng, K, m, c1)
    got = np.asarray(ops.similarity_mark(csu, csv, cbeta, cseg, esu, esv,
                                         eseg, tile_m=32))
    want = np.asarray(ops.similarity_mark_ref(csu, csv, cbeta, cseg,
                                              esu, esv, eseg))
    np.testing.assert_array_equal(got, want)


def test_similarity_kernel_agrees_with_recovery_predicate():
    """Kernel == the engine's strict_similarity_matrix on a real problem."""
    from repro.core import barabasi_albert, prepare
    from repro.core.recovery import strict_similarity_matrix

    g = barabasi_albert(200, 3, seed=0)
    prep = prepare(g, chunk=256)
    p = prep.problem
    K = 16
    csu, csv = p.sig_u[:K], p.sig_v[:K]
    cbeta, cseg = p.beta[:K], p.seg[:K]
    got = np.asarray(ops.similarity_mark(csu, csv, cbeta, cseg,
                                         p.sig_u, p.sig_v, p.seg, tile_m=256))
    sim = strict_similarity_matrix(csu, csv, cbeta, p.sig_u, p.sig_v)
    want = np.asarray(jnp.any(sim & (cseg[:, None] == p.seg[None, :]), 0))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("B,S,di,state,blk", [
    (2, 16, 8, 4, 8),
    (1, 64, 32, 16, 16),
    (3, 32, 64, 8, 64),
])
def test_ssm_scan_kernel_matches_ref(B, S, di, state, blk):
    from repro.kernels.ssm_scan import ssm_scan, ssm_scan_ref

    rng = np.random.default_rng(B * S + di)
    x1 = jnp.asarray(rng.standard_normal((B, S, di)).astype(np.float32))
    dt = jnp.asarray(0.1 * rng.random((B, S, di)).astype(np.float32))
    Bm = jnp.asarray(rng.standard_normal((B, S, state)).astype(np.float32))
    Cm = jnp.asarray(rng.standard_normal((B, S, state)).astype(np.float32))
    A = jnp.asarray(-np.abs(rng.standard_normal((di, state))).astype(np.float32))
    h0 = jnp.asarray(rng.standard_normal((B, di, state)).astype(np.float32))
    y, hT = ssm_scan(x1, dt, Bm, Cm, A, h0, blk=blk)
    y_ref, h_ref = ssm_scan_ref(x1, dt, Bm, Cm, A, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(h_ref),
                               rtol=1e-5, atol=1e-5)


def test_ssm_scan_kernel_matches_model_layer():
    """Kernel == the model's chunked scan path (same recurrence)."""
    from repro.kernels.ssm_scan import ssm_scan
    from repro.models.layers import mamba_scan

    rng = np.random.default_rng(7)
    B, S, di, state = 2, 32, 16, 4
    x1 = jnp.asarray(rng.standard_normal((B, S, di)).astype(np.float32))
    dt = jnp.asarray(0.1 * rng.random((B, S, di)).astype(np.float32))
    Bm = jnp.asarray(rng.standard_normal((B, S, state)).astype(np.float32))
    Cm = jnp.asarray(rng.standard_normal((B, S, state)).astype(np.float32))
    A = jnp.asarray(-np.abs(rng.standard_normal((di, state))).astype(np.float32))
    D = jnp.zeros((di,), jnp.float32)
    h0 = jnp.zeros((B, di, state), jnp.float32)
    y_k, h_k = ssm_scan(x1, dt, Bm, Cm, A, h0, blk=16)
    y_m, h_m = mamba_scan(x1, dt, Bm, Cm, A, D, h0, chunk=8)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_m),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,L,tile_n,dtype", [
    (64, 5, 32, np.float32),
    (256, 9, 256, np.float32),
    (100, 4, 64, np.float32),   # pad path
    (128, 7, 32, np.float64),
])
def test_spmv_matches_ref(n, L, tile_n, dtype):
    rng = np.random.default_rng(n * L)
    idx = jnp.asarray(rng.integers(0, n, size=(n, L)).astype(np.int32))
    val = jnp.asarray(rng.standard_normal((n, L)).astype(dtype))
    x = jnp.asarray(rng.standard_normal(n).astype(dtype))
    got = np.asarray(ops.spmv(idx, val, x, tile_n=tile_n))
    want = np.asarray(ops.spmv_ref(idx, val, x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,tile_n", [
    (100, 64),    # pad = 28
    (257, 256),   # pad = 255 (worst case: one extra row)
    (31, 32),     # n < tile_n
])
def test_spmv_ell_direct_non_multiple_n(n, tile_n):
    """Regression: spmv_ell itself (not just the ops wrapper) must accept
    row counts that are not a multiple of tile_n — it used to assert."""
    from repro.kernels.spmv_ell import spmv_ell

    L = 5
    rng = np.random.default_rng(n)
    idx = jnp.asarray(rng.integers(0, n, size=(n, L)).astype(np.int32))
    val = jnp.asarray(rng.standard_normal((n, L)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    got = np.asarray(spmv_ell(idx, val, x, tile_n=tile_n, interpret=True))
    assert got.shape == (n,)
    want = np.asarray(ops.spmv_ref(idx, val, x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,L,k,tile_n", [
    (64, 5, 1, 32),
    (256, 9, 8, 256),
    (100, 4, 4, 64),    # pad path
    (31, 3, 2, 32),     # n < tile_n
])
def test_spmv_batched_matches_ref_columns(n, L, k, tile_n):
    rng = np.random.default_rng(n * L + k)
    idx = jnp.asarray(rng.integers(0, n, size=(n, L)).astype(np.int32))
    val = jnp.asarray(rng.standard_normal((n, L)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((n, k)).astype(np.float32))
    got = np.asarray(ops.spmv_batched(idx, val, x, tile_n=tile_n))
    assert got.shape == (n, k)
    for j in range(k):
        want = np.asarray(ops.spmv_ref(idx, val, x[:, j]))
        np.testing.assert_allclose(got[:, j], want, rtol=1e-5, atol=1e-5)


def test_spmv_batched_extended_x_rows():
    """The sharded plane gathers from [n_loc + halo] extended vectors: x
    may have more rows than the slab — extra rows only matter through
    idx references."""
    n, L, k, extra = 48, 4, 3, 16
    rng = np.random.default_rng(9)
    idx = jnp.asarray(rng.integers(0, n + extra, (n, L)).astype(np.int32))
    val = jnp.asarray(rng.standard_normal((n, L)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((n + extra, k)).astype(np.float32))
    got = np.asarray(ops.spmv_batched(idx, val, x, tile_n=32))
    want = np.einsum("nl,nlk->nk", np.asarray(val), np.asarray(x)[np.asarray(idx)])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_spmv_laplacian_equals_scipy():
    from repro.core import mesh2d
    from repro.kernels.spmv_ell import to_ell

    g = mesh2d(9, 9, seed=1)
    idx, val = to_ell(g)
    rng = np.random.default_rng(2)
    x = rng.standard_normal(g.n).astype(np.float32)
    got = np.asarray(ops.spmv(idx, val, jnp.asarray(x), tile_n=32))
    want = g.laplacian() @ x
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
